"""Distributed batch hybrid search on a multi-device mesh (shard_map).

Demonstrates the production topology at laptop scale: the packed index is
sharded over the "model" axis, the query stream over "data", each device
runs the fused masked-top-k, and a k-sized all-gather merges shard results.

Run with 8 simulated devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_search.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.distributed import make_search_step  # noqa: E402
from repro.core.predicates import Contains, evaluate_filter, make_filter  # noqa: E402
from repro.kernels.ref import masked_topk_ref  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402

from repro.core import Column, VectorDatabase  # noqa: E402

rng = np.random.default_rng(0)
n, d, m = 64_000, 32, 512
mesh = make_test_mesh((2, 4), ("data", "model"))
print(f"devices: {len(jax.devices())}, mesh: {dict(mesh.shape)}")

membership = rng.random((n, 4)) < 0.3
membership[np.arange(n), rng.integers(0, 4, n)] = True
db = VectorDatabase(
    vectors=rng.normal(size=(n, d)).astype(np.float32),
    columns={"type": Column.setcat("type", membership)},
    metric="ip",
)
bitmap = evaluate_filter(make_filter(Contains("type", 2)), db)
queries = rng.normal(size=(m, d)).astype(np.float32)

step = make_search_step(mesh, k=10, metric="ip")
with mesh:
    scores, ids = step(jnp.asarray(db.vectors), jnp.asarray(bitmap), jnp.asarray(queries))
scores, ids = np.asarray(scores), np.asarray(ids)

# verify against the single-device oracle
s_ref, i_ref = masked_topk_ref(jnp.asarray(queries), jnp.asarray(db.vectors), jnp.asarray(bitmap), 10, "ip")
np.testing.assert_allclose(scores, np.asarray(s_ref), rtol=1e-5, atol=1e-5)
print(f"searched {m} hybrid queries against {n} vectors across {len(jax.devices())} devices")
print("top-3 of query 0:", ids[0][:3].tolist(), "scores", np.round(scores[0][:3], 3).tolist())
print("OK")
