"""Distributed batch hybrid search on a multi-device mesh (sharded engine).

Demonstrates the production topology at laptop scale: the packed arena is
sharded over the "model" axis (contiguous posting-list slices per rank), the
workload is planned ONCE and its work units route to the rank storing their
posting list, every bucket executes as one shard_map dispatch with bitmap
pushdown intact, and the only cross-rank traffic is the k·|model| per-query
candidate all-gather. Results are bit-identical to the single-device engine.

Run with 8 simulated devices:
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_search.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import Column, VectorDatabase  # noqa: E402
from repro.core.ivf import IVFIndex  # noqa: E402
from repro.core.planner import batch_search_ivf  # noqa: E402
from repro.core.predicates import Contains, evaluate_filter, make_filter  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402

rng = np.random.default_rng(0)
n, d, m, k = 64_000, 32, 512, 10
mesh = make_test_mesh((2, 4), ("data", "model"))
print(f"devices: {len(jax.devices())}, mesh: {dict(mesh.shape)}")

membership = rng.random((n, 4)) < 0.3
membership[np.arange(n), rng.integers(0, 4, n)] = True
db = VectorDatabase(
    vectors=rng.normal(size=(n, d)).astype(np.float32),
    columns={"type": Column.setcat("type", membership)},
    metric="ip",
)
bitmap = evaluate_filter(make_filter(Contains("type", 2)), db)
queries = rng.normal(size=(m, d)).astype(np.float32)

ivf = IVFIndex.build(db.vectors, metric="ip", n_centroids=64, seed=0)
scores, ids = batch_search_ivf(
    ivf, queries, nprobe=16, k=k, bitmap=bitmap, mesh=mesh
)

# verify against the single-device engine: results must be bit-identical
s_ref, i_ref = batch_search_ivf(ivf, queries, nprobe=16, k=k, bitmap=bitmap)
assert np.array_equal(scores, s_ref) and np.array_equal(ids, i_ref)
print(f"searched {m} hybrid queries against {n} vectors across {mesh.shape['model']} model ranks")
print("top-3 of query 0:", ids[0][:3].tolist(), "scores", np.round(scores[0][:3], 3).tolist())
print("OK — sharded == single-device, bit-exact")
