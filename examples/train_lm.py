"""End-to-end training driver: train a ~100M-param LM for a few hundred steps

with the full production loop — microbatched train step, WSD schedule,
async checkpointing, restart-safe deterministic data.

    PYTHONPATH=src python examples/train_lm.py            # ~100M params
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-speed
"""
import argparse
import dataclasses

import jax

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig
from repro.models import api
from repro.models.transformer import ModelConfig
from repro.train.fault_tolerance import LoopConfig, TrainLoop
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

if args.tiny:
    cfg = get_reduced("minicpm-2b")
    steps = args.steps or 30
    batch, seq = 8, 32
else:
    # ~100M-param llama-style LM (minicpm family wiring, scaled down)
    cfg = ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=2048, vocab=32_000, q_chunk=256, kv_chunk=256,
    )
    steps = args.steps or 300
    batch, seq = 16, 256

n_params = api.count_params(api.init_model(cfg, jax.random.key(0)))
print(f"model: {cfg.name}  params={n_params/1e6:.1f}M")

tcfg = TrainConfig(
    opt=OptConfig(name="adamw", schedule="wsd", peak_lr=3e-4,
                  warmup_steps=max(10, steps // 20), total_steps=steps),
    microbatches=2,
)
dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
loop = TrainLoop(cfg, tcfg, dcfg,
                 LoopConfig(ckpt_dir="/tmp/repro_train_lm", ckpt_every=max(50, steps // 4),
                            log_every=max(1, steps // 20)))
loop.maybe_restore()
hist = loop.run(steps)
first, last = hist[0]["loss"], hist[-1]["loss"]
print(f"loss: {first:.3f} -> {last:.3f} over {steps} steps")
assert last < first, "training must reduce the loss"
print("OK")
