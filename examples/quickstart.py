"""Quickstart: build an HQI over a toy KG and run hybrid queries.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    Column, Contains, HQIConfig, HQIIndex, NotNull, VectorDatabase, Workload,
    exhaustive_search, make_filter, recall_at_k,
)

rng = np.random.default_rng(0)

# --- a tiny "knowledge graph": 5k entities, typed, with embeddings ----------
n, d, n_types = 5_000, 32, 6
type_of = rng.integers(0, n_types, n)
centers = rng.normal(size=(n_types, d)).astype(np.float32) * 2
vectors = (centers[type_of] + rng.normal(size=(n, d))).astype(np.float32)
membership = np.zeros((n, n_types), dtype=bool)
membership[np.arange(n), type_of] = True
height = Column.numeric("height", rng.random(n), null_mask=(type_of != 0) | (rng.random(n) < 0.2))

db = VectorDatabase(
    vectors=vectors,
    columns={"type": Column.setcat("type", membership), "height": height},
    metric="ip",
)

# --- a workload: "find entities similar to X that are Persons with height" --
person_with_height = make_filter(Contains("type", 0), NotNull("height"))
any_song = make_filter(Contains("type", 1))
queries = rng.integers(0, n, 200)
workload = Workload(
    vectors=vectors[queries] + 0.05 * rng.normal(size=(200, d)).astype(np.float32),
    templates=[person_with_height, any_song],
    template_of=(queries % 2).astype(np.int32),
    k=10,
)

# --- build the workload-aware index and run the batch -----------------------
hqi = HQIIndex.build(db, workload, HQIConfig(min_partition_size=512, max_leaves=16))
result = hqi.search(workload, nprobe=8)
truth = exhaustive_search(db, workload)

print(f"partitions: {hqi.tree.n_leaves}, sizes: {hqi.partition_sizes().tolist()}")
print(f"recall@10 vs exhaustive: {recall_at_k(result, truth):.3f}")
print(f"tuples scanned: {result.tuples_scanned:,} "
      f"(exhaustive would scan {db.n * workload.m:,})")
print("first query's top-5 ids:", result.ids[0][:5].tolist())
assert recall_at_k(result, truth) > 0.7
print("OK")
