"""Related-KG-queries, end to end — the paper's flagship scenario, including

the model layer: a small LM is TRAINED (examples/train_lm.py's loop inline,
fewer steps), entity embeddings are pooled from its hidden states, HQI
indexes them against a Table-1-style template workload, and the batch is
served with the full pipeline (routing → bitmap pushdown → batched matmul
top-k). Compares HQI vs PreFilter on time and tuples scanned.

    PYTHONPATH=src python examples/related_queries.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core import (
    Column, Contains, HQIConfig, HQIIndex, NotNull, PreFilterIndex,
    VectorDatabase, Workload, exhaustive_search, make_filter, recall_at_k,
    tune_nprobe,
)
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import api
from repro.models.transformer import lm_hidden_embed
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

# --- 1. train a small LM (the embedding producer) ---------------------------
cfg = get_reduced("minicpm-2b")
tcfg = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=40))
params, opt_state = init_train_state(cfg, tcfg, jax.random.key(0))
step = jax.jit(make_train_step(cfg, tcfg))
data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8))
for s in range(40):
    batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
    params, opt_state, m = step(params, opt_state, batch)
print(f"LM trained 40 steps; final loss {float(m['loss']):.3f}")

# --- 2. embed "entities" (token sequences) with the trained model -----------
rng = np.random.default_rng(0)
n_entities, n_types = 4_000, 5
type_of = rng.integers(0, n_types, n_entities)
# entities of a type share a token motif → embeddings correlate with type
motifs = rng.integers(2, cfg.vocab, size=(n_types, 16))
seqs = np.tile(motifs[type_of], 1)
seqs[:, 8:] = rng.integers(2, cfg.vocab, size=(n_entities, 8))
embed_fn = jax.jit(lambda t: lm_hidden_embed(params, cfg, t))
vecs = []
for s in range(0, n_entities, 256):
    vecs.append(np.asarray(embed_fn(jnp.asarray(seqs[s : s + 256], jnp.int32))))
vectors = np.concatenate(vecs).astype(np.float32)
vectors /= np.linalg.norm(vectors, axis=1, keepdims=True) + 1e-6

# --- 3. attributes + the hybrid query workload -------------------------------
membership = np.zeros((n_entities, n_types), dtype=bool)
membership[np.arange(n_entities), type_of] = True
height = Column.numeric(
    "height", rng.random(n_entities), null_mask=(type_of != 0) | (rng.random(n_entities) < 0.1)
)
db = VectorDatabase(
    vectors=vectors,
    columns={"type": Column.setcat("type", membership), "height": height},
    metric="ip",
)
templates = [
    make_filter(Contains("type", 0), NotNull("height")),  # "How tall is <Person>?"
    make_filter(Contains("type", 1)),
    make_filter(NotNull("height")),
]
m_q = 600
t_of = rng.choice(3, size=m_q, p=[0.6, 0.3, 0.1]).astype(np.int32)
q_ent = rng.integers(0, n_entities, m_q)
workload = Workload(vectors=vectors[q_ent], templates=templates, template_of=t_of, k=10)

# --- 4. index + batch serve ---------------------------------------------------
truth = exhaustive_search(db, workload)
hqi = HQIIndex.build(db, workload, HQIConfig(min_partition_size=256, max_leaves=32))
pre = PreFilterIndex.build(db)
np_h = tune_nprobe(lambda w, np_: hqi.search(w, nprobe=np_), workload, truth)
np_p = tune_nprobe(lambda w, np_: pre.search(w, nprobe=np_), workload, truth)

t0 = time.perf_counter(); res_h = hqi.search(workload, nprobe=np_h); t_h = time.perf_counter() - t0
t0 = time.perf_counter(); res_p = pre.search(workload, nprobe=np_p); t_p = time.perf_counter() - t0
print(f"HQI:       {t_h*1e3:7.1f} ms  recall={recall_at_k(res_h, truth):.2f} "
      f"tuples={res_h.tuples_scanned:,}")
print(f"PreFilter: {t_p*1e3:7.1f} ms  recall={recall_at_k(res_p, truth):.2f} "
      f"tuples={res_p.tuples_scanned:,}")
print(f"scan reduction: {1 - res_h.tuples_scanned / max(res_p.tuples_scanned,1):.0%}")
assert recall_at_k(res_h, truth) >= 0.8
print("OK")
