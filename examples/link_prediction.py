"""Link prediction (LP) batch workload — the paper's second industrial task.

No historical query log exists, so the workload-aware qd-tree is skipped and
the win comes purely from Algorithm-3 batching (attribute-template grouping
+ per-posting-list matmuls): the configuration the paper reports 19× for.

    PYTHONPATH=src python examples/link_prediction.py
"""
import time

import numpy as np

from repro.core import PreFilterIndex, exhaustive_search, recall_at_k, tune_nprobe
from repro.core.workload import lp_style

db, workload = lp_style(n=30_000, d=32, n_queries=1_500)
truth = exhaustive_search(db, workload)
index = PreFilterIndex.build(db)

np_t = tune_nprobe(lambda w, np_: index.search(w, nprobe=np_, batch_vec=True), workload, truth)

t0 = time.perf_counter()
res_b = index.search(workload, nprobe=np_t, batch_vec=True)   # Algorithm 3
t_batch = time.perf_counter() - t0

t0 = time.perf_counter()
res_s = index.search(workload, nprobe=np_t, batch_vec=False)  # per-query scans
t_single = time.perf_counter() - t0

t0 = time.perf_counter()
res_1 = index.search(workload, nprobe=np_t, batch_attr=False)  # one-at-a-time
t_one = time.perf_counter() - t0

print(f"one-at-a-time:       {t_one*1e3:8.1f} ms   recall={recall_at_k(res_1, truth):.2f}")
print(f"attr-batched:        {t_single*1e3:8.1f} ms   recall={recall_at_k(res_s, truth):.2f}")
print(f"attr+vector batched: {t_batch*1e3:8.1f} ms   recall={recall_at_k(res_b, truth):.2f}")
print(f"batching speedup vs one-at-a-time: {t_one/t_batch:.1f}x")
assert recall_at_k(res_b, truth) >= 0.8
print("OK")
