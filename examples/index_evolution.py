"""Index evolution walkthrough: drift-triggered rebuild + blue/green hot swap.

    PYTHONPATH=src python examples/index_evolution.py

Serves a KG-style query stream whose template mix shifts mid-stream, lets
the Tuner detect the drift and rebuild the qd-tree off to the side on the
live traffic, then blue/green-swaps the new generation in — with writes
landing throughout, zero dropped queries, and an instant rollback path.
"""
import shutil
import tempfile

import numpy as np

from repro.core import HQIConfig, HQIIndex
from repro.core.workload import kg_style
from repro.store import init_store, list_generations, pinned_generations
from repro.store.snapshot import current_generation
from repro.service import ServiceConfig
from repro.tuner import Tuner, TunerConfig

rng = np.random.default_rng(0)

# --- a KG-style service, persisted (snapshot + WAL) -------------------------
kg = kg_style(n=6_000, d=32, queries_per_split=160, seed=0)
wl_early, wl_late = kg.splits[0], kg.splits[3]
hqi = HQIIndex.build(
    kg.db, wl_early, HQIConfig(min_partition_size=256, max_leaves=32)
)
root = tempfile.mkdtemp(prefix="hqi_evolve_")
svc = init_store(root, hqi, cfg=ServiceConfig(k=10, nprobe=8, max_batch=32))
tuner = Tuner(svc, root, cfg=TunerConfig(share_shift=0.3, min_window=32))


def stream(wl, rows):
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]]) for i in rows
    ]
    svc.drain()
    assert all(h.ok for h in handles), "no query may be dropped"
    return handles


# 1) the early era: selective head templates dominate; the tuner sees a
#    stationary mix and does nothing
stream(wl_early, np.where(wl_early.template_of <= 4)[0])
assert tuner.tune_once() is None
print(f"early era served; drift share_shift "
      f"{svc.drift_report().share_shift:.2f} -> no rebuild")

# 2) the mix shifts: broad templates take over, and writes keep landing
#    (they are what the swap's WAL-tail replay must carry across)
acked = svc.insert(kg.db.vectors[rng.integers(0, kg.db.n, 40)])
stream(wl_late, np.where(wl_late.template_of >= 5)[0])

# 3) one tuner cycle: capture -> rebuild off to the side (serving continues)
#    -> persist the candidate generation -> drain + swap -> promote + pin
rec = tuner.tune_once()
assert rec is not None
print(f"drift tripped ({rec.reason}): rebuilt {rec.n_rows} rows in "
      f"{rec.build_s:.2f}s, swapped in {rec.swap_s*1e3:.1f}ms as "
      f"{rec.generation}, WAL tail replayed {rec.replayed} records")
print(f"generations on disk: {list_generations(root)}; current "
      f"{current_generation(root)}; pinned for rollback {sorted(pinned_generations(root))}")

# 4) the acknowledged writes survived the swap, and the stream never stopped
h = svc.submit(kg.db.vectors[int(acked[0]) % kg.db.n], wl_late.templates[9])
svc.drain()
assert svc.health().index_swaps == 1
print(f"post-swap health: swaps={svc.health().index_swaps}, "
      f"queries still answering (h.ok={h.ok})")

# 5) instant rollback keeps even post-swap writes (in production you'd
#    instead forget_rollback() once the new layout proves itself out)
post = svc.insert(kg.db.vectors[:3])
tuner.rollback()
assert svc.health().index_swaps == 2
h = svc.submit(kg.db.vectors[0], wl_late.templates[9])
svc.drain()
assert h.ok
print(f"rolled back to {current_generation(root)}; post-swap insert "
      f"{[int(i) for i in post]} still live; zero queries dropped end-to-end")

if svc.wal is not None:
    svc.wal.close()
shutil.rmtree(root)
print("OK")
