"""Online serving walkthrough: stream hybrid queries with live inserts/deletes.

    PYTHONPATH=src python examples/online_serving.py

Builds an HQI over a toy KG, wraps it in HQIService, and walks the serving
lifecycle: micro-batched queries, an insert visible to the very next flush,
a tombstone delete, and a refresh() that folds the delta into the index
partitions without a rebuild.
"""
import numpy as np

from repro.core import (
    Column, Contains, HQIConfig, HQIIndex, NotNull, VectorDatabase, make_filter,
)
from repro.service import HQIService, ServiceConfig

rng = np.random.default_rng(0)

# --- a tiny "knowledge graph": 5k entities, typed, with embeddings ----------
n, d, n_types = 5_000, 32, 6
type_of = rng.integers(0, n_types, n)
centers = rng.normal(size=(n_types, d)).astype(np.float32) * 2
vectors = (centers[type_of] + rng.normal(size=(n, d))).astype(np.float32)
membership = np.zeros((n, n_types), dtype=bool)
membership[np.arange(n), type_of] = True
height = Column.numeric(
    "height", rng.random(n), null_mask=(type_of != 0) | (rng.random(n) < 0.2)
)
db = VectorDatabase(
    vectors=vectors,
    columns={"type": Column.setcat("type", membership), "height": height},
    metric="ip",
)

# --- historical workload sample (what the qd-tree is mined from) ------------
person_with_height = make_filter(Contains("type", 0), NotNull("height"))
any_song = make_filter(Contains("type", 1))
from repro.core import Workload

hist = rng.integers(0, n, 200)
sample = Workload(
    vectors=vectors[hist] + 0.05 * rng.normal(size=(200, d)).astype(np.float32),
    templates=[person_with_height, any_song],
    template_of=(hist % 2).astype(np.int32),
    k=10,
)
hqi = HQIIndex.build(db, sample, HQIConfig(min_partition_size=512, max_leaves=16))

# --- wrap it in a service: flush every 64 queries or 5 ms -------------------
svc = HQIService(
    hqi,
    ServiceConfig(k=10, nprobe=8, max_batch=64, deadline_s=0.005, queue_bound=1024),
)

# 1) stream a burst of online queries and flush
handles = [
    svc.submit(vectors[int(e)] + 0.05 * rng.normal(size=d).astype(np.float32),
               person_with_height if e % 2 == 0 else any_song)
    for e in rng.integers(0, n, 96)
]
answered = svc.drain()
ids0, scores0 = handles[0].result()
print(f"answered {answered} queries; first query's top-3 ids: {ids0[:3].tolist()}")

# 2) insert a brand-new "Person" entity right next to an existing vector —
#    it must appear in the next flush's answers (no rebuild, no refresh)
probe_vec = vectors[0]
new_ids = svc.insert(
    probe_vec[None, :],
    columns={"type": np.eye(n_types, dtype=bool)[0][None, :],
             "height": np.array([0.5], dtype=np.float32)},
)
h = svc.submit(probe_vec, person_with_height)
svc.drain()
assert int(new_ids[0]) in h.ids.tolist(), "live insert must be served immediately"
print(f"inserted id {int(new_ids[0])} surfaced in the very next flush")

# 3) tombstone it again — gone from the following flush
svc.delete(new_ids)
h = svc.submit(probe_vec, person_with_height)
svc.drain()
assert int(new_ids[0]) not in h.ids.tolist(), "tombstoned row must disappear"
print("tombstoned the insert; it no longer appears")

# 4) refresh(): fold buffered rows into the index partitions incrementally
svc.insert(np.repeat(probe_vec[None, :], 5, axis=0))
folded = svc.refresh()
print(f"refresh folded {folded} rows into {len(hqi.partitions)} partitions "
      f"(db is now {hqi.db.n} tuples; no rebuild)")

# 5) telemetry
s = svc.telemetry.summary()
print(f"served {s['queries']:.0f} queries in {s['flushes']:.0f} flushes; "
      f"p50 {s['p50_latency_s']*1e3:.1f} ms, p99 {s['p99_latency_s']*1e3:.1f} ms, "
      f"{s['merge_dispatches_per_flush']:.1f} merge dispatches/flush")

# 6) persistence: save -> "kill" -> recover -> verify (repro.store)
#    init_store snapshots the (refreshed) index and attaches a WAL, so every
#    insert/delete is durable BEFORE it is acknowledged
import shutil
import tempfile

from repro.store import init_store, open_service

root = tempfile.mkdtemp(prefix="hqi_store_")
store_svc = init_store(root, hqi)
acked = store_svc.insert(
    probe_vec[None, :],
    columns={"type": np.eye(n_types, dtype=bool)[0][None, :],
             "height": np.array([0.7], dtype=np.float32)},
)
h = store_svc.submit(probe_vec, person_with_height)
store_svc.drain()
before_ids, before_scores = h.ids.copy(), h.scores.copy()
del store_svc  # "kill -9": the delta buffer lived only in RAM — and the WAL

# 7) warm restart: mmap the snapshot, replay the WAL tail, resume serving
recovered = open_service(root)
h = recovered.submit(probe_vec, person_with_height)
recovered.drain()
assert int(acked[0]) in h.ids.tolist(), "acknowledged insert must survive"
assert np.array_equal(before_ids, h.ids) and np.array_equal(before_scores, h.scores), \
    "recovery must answer bit-identically to the uncrashed process"
print(f"recovered from {root}: acknowledged insert {int(acked[0])} survived "
      f"the crash; answers bit-identical to the uncrashed service")
shutil.rmtree(root)

# 8) observability: re-run a traced burst and export a Perfetto-loadable
#    timeline — submit markers, per-query queue waits, flush/dispatch/merge
#    spans — plus the unified metrics snapshot and a workload-drift reading
from repro.obs import trace
from repro.obs.metrics import get_registry

tracer = trace.enable()  # tracing is off by default and costs nothing until now
handles = [
    svc.submit(vectors[int(e)] + 0.05 * rng.normal(size=d).astype(np.float32),
               person_with_height if e % 2 == 0 else any_song)
    for e in rng.integers(0, n, 64)
]
svc.drain()
trace_path = tracer.export("trace.json")
trace.disable()
snap = get_registry().snapshot()
rep = svc.drift_report()
print(f"traced {tracer.span_count} spans -> {trace_path} "
      f"(open in https://ui.perfetto.dev); "
      f"queue-wait p50 {snap['service.queue_wait_s']['p50']*1e3:.2f} ms; "
      f"drift share_shift {rep.share_shift:.2f} over {rep.n_window} queries")
print("OK")
