"""Fault-injection cost + chaos smoke: the failpoint zero-cost contract.

Failpoints are compiled into the hottest serving paths (WAL stage/fsync,
scheduler tick, flush pipeline, delta apply), so their disarmed cost must be
indistinguishable from not having them. Reports:

  * fault/ns_per_call_disarmed — the raw ``failpoint()`` fast path (one
                                 module-global load + falsy branch)
  * fault/qps_disarmed         — serving stream, registry empty
  * fault/evals_per_pass       — failpoint evaluations one serving pass
                                 actually executes (counted with every site
                                 armed at probability 0.0)
  * fault/overhead_ratio       — 1 + (evals x ns_per_call) / pass_time: the
                                 disarmed instrumentation cost of the serving
                                 stream; CI gates at 1.02 via
                                 ``benchmarks/check_fault.py``
  * fault/qps_armed_p0         — the armed-at-p0 pass itself (every
                                 evaluation takes the registry lock) —
                                 informational, not gated: a single ~100 ms
                                 serving pass has several percent of kernel
                                 dispatch jitter, far above the true cost
  * fault/chaos_*              — a seeded in-process chaos run (no writer
                                 kill — ``repro.fault.chaos --smoke`` in CI
                                 covers that): the three standing invariants
                                 as 0/1 rows the checker asserts on

The gate is deliberately NOT an end-to-end A/B ratio: the disarmed fast
path costs ~60 ns x O(10) evaluations per flush against ~10 ms of kernel
work, so any honest measurement of it through QPS is dominated by noise.
Counting evaluations and pricing them at the microbenched per-call cost
measures the same contract with none of the flake.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import HQIConfig, HQIIndex
from repro.core.workload import kg_style
from repro.fault import failpoints
from repro.fault.chaos import ChaosConfig, run_chaos
from repro.service import HQIService, ServiceConfig
from repro.store.wal import WriteAheadLog

from .common import FAST, N, D, Q, emit


def _arm_all_p0() -> None:
    for site in failpoints.SITES:
        failpoints.arm(site, "failpoint", prob=0.0)


def main() -> None:
    failpoints.disarm_all()

    # --- raw fast-path cost (median of 5 timing loops) ----------------------
    reps = 200_000 if FAST else 1_000_000
    fp = failpoints.failpoint

    def _loop() -> float:
        t0 = time.perf_counter()
        for _ in range(reps):
            fp("wal.fsync")
        return time.perf_counter() - t0

    _loop()  # warm the loop itself
    ns_per_call = float(np.median([_loop() for _ in range(5)])) / reps * 1e9
    emit(
        "fault/ns_per_call_disarmed",
        ns_per_call / 1e3,
        f"{ns_per_call:.1f} ns/call over {reps} disarmed evaluations",
    )

    # --- serving overhead: disarmed vs every site armed at prob 0 -----------
    n = min(N, 10_000 if FAST else 50_000)
    kg = kg_style(n=n, d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(1024, n // 16), max_leaves=32)
    )
    tmp = tempfile.mkdtemp(prefix="bench_fault_")
    wal = WriteAheadLog(os.path.join(tmp, "wal"))
    svc = HQIService(
        hqi,
        ServiceConfig(k=wl.k, nprobe=8, max_batch=64, deadline_s=0.002),
        wal=wal,
    )
    rng = np.random.default_rng(2)
    n_new = 50 if FAST else 200

    def one_pass() -> float:
        newv = kg.db.vectors[rng.integers(0, kg.db.n, n_new)]
        t0 = time.perf_counter()
        for i in range(wl.m):
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        svc.drain()
        svc.insert(newv)
        svc.delete(rng.integers(0, kg.db.n, n_new // 2))
        svc.drain()
        return time.perf_counter() - t0

    one_pass()  # warmup: compile every flush shape before timing
    dis_s = float(np.median([one_pass() for _ in range(3 if FAST else 5)]))

    # count what one pass actually evaluates: arm everything at p=0 (never
    # fires, but every evaluation is tallied) and diff the counters
    _arm_all_p0()
    before = {s: failpoints.evaluated(s) for s in failpoints.SITES}
    arm_s = one_pass()
    evals = sum(
        failpoints.evaluated(s) - before[s] for s in failpoints.SITES
    )
    failpoints.disarm_all()
    wal.close()

    overhead_s = evals * ns_per_call / 1e9
    ratio = 1.0 + overhead_s / dis_s
    emit("fault/qps_disarmed", dis_s / wl.m * 1e6,
         f"{wl.m / dis_s:.0f} qps, registry empty")
    emit("fault/evals_per_pass", float(evals),
         f"{evals} failpoint evaluations per {dis_s * 1e3:.0f} ms pass")
    emit("fault/overhead_ratio", ratio,
         f"{ratio:.5f}x disarmed instrumentation cost "
         f"({evals} evals x {ns_per_call:.0f} ns / pass; gate: 1.02)")
    emit("fault/qps_armed_p0", arm_s / wl.m * 1e6,
         f"{wl.m / arm_s:.0f} qps, {len(failpoints.SITES)} sites armed at p=0"
         f" (informational)")

    # --- chaos smoke: the standing invariants as gateable rows --------------
    root = tempfile.mkdtemp(prefix="bench_fault_chaos_")
    cfg = ChaosConfig(
        seed=0,
        rounds=2,
        queries_per_round=25,
        writes_per_round=4,
        n0=800,
        poison_rounds=(1,),
        kill_writer=False,
    )
    rep = run_chaos(root, cfg)
    emit("fault/chaos_queries", float(rep.queries_submitted),
         f"{rep.answered_ok} ok + {rep.failed_typed} failed typed "
         f"of {rep.queries_submitted} submitted")
    emit("fault/chaos_hung", float(rep.hung),
         f"{rep.hung} hung queries (must be 0)")
    emit("fault/chaos_lost_acked", float(rep.recovery_violations),
         f"{rep.recovery_violations} lost acked writes across "
         f"{rep.recovery_checks} recovery checks (must be 0)")
    emit("fault/chaos_parity", float(rep.parity_mismatches),
         f"{rep.parity_mismatches} non-degraded answer mismatches (must be 0)")
    emit("fault/chaos_sites", float(len(rep.sites_fired)),
         "fired: " + " ".join(sorted(rep.sites_fired)))


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
