"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table3_4   # one asset
    PYTHONPATH=src python -m benchmarks.run --smoke    # CI-speed smoke subset
    REPRO_BENCH_FAST=1 ...                             # small sizes, any suite

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py) and writes
each suite's rows to ``BENCH_<suite>.json`` in the working directory — the
machine-readable artifact CI uploads so the perf trajectory (engine QPS,
pq-vs-f32 bytes/recall, serving throughput) is tracked across PRs.
"""
import os
import sys

SMOKE_SUITES = [
    "engine", "kernels", "service", "distributed", "store", "obs", "fault",
    "tuner", "perf",
]


def main() -> None:
    args = sys.argv[1:]
    if "--smoke" in args:
        args = [a for a in args if a != "--smoke"]
        os.environ["REPRO_BENCH_FAST"] = "1"
        args = args or SMOKE_SUITES

    from . import (
        bench_distributed, bench_engine, bench_fault, bench_fig4_5, bench_fig6,
        bench_fig7, bench_kernels, bench_perf, bench_service, bench_store,
        bench_table3_4, bench_table5, bench_tuner, common,
    )

    suites = {
        "table3_4": bench_table3_4.main,
        "table5": bench_table5.main,
        "fig4_5": bench_fig4_5.main,
        "fig6": bench_fig6.main,
        "fig7": bench_fig7.main,
        "kernels": bench_kernels.main,
        "engine": bench_engine.main,
        "service": bench_service.main,
        "distributed": bench_distributed.main,
        "store": bench_store.main,
        "obs": bench_service.main_obs,
        "fault": bench_fault.main,
        "tuner": bench_tuner.main,
        "perf": bench_perf.main,
    }
    picks = args or list(suites)
    print("name,us_per_call,derived")
    for p in picks:
        n0 = len(common.rows())
        suites[p]()
        path = common.write_suite_json(p, common.rows()[n0:])
        print(f"# wrote {path}", flush=True)


if __name__ == '__main__':
    main()
