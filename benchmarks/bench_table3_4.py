"""Tables 3 + 4: end-to-end workload execution time and index generation time

for HQI vs PreFilter / PostFilter / Range across the five dataset shapes
(RelatedQS, LP, and the three synthetic BIGANN-style sets). All approaches
are tuned per-template to Recall ≥ 0.8 @ k=10 (the paper's protocol); Range
is NA on RelatedQS/LP (IN / IS NOT NULL constraints — Table 3 footnote 2).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (
    HQIConfig, HQIIndex, PostFilterIndex, PreFilterIndex, RangeIndex,
    exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import kg_style, lp_style, synthetic_bigann_style

from .common import D, FAST, N, Q, emit, timed


def _tuned_time(search_fn, workload, truth, label, dataset):
    try:
        nprobe = tune_nprobe(search_fn, workload, truth, target_recall=0.8)
    except Exception as e:  # pragma: no cover
        emit(f"table3.{dataset}.{label}", 0.0, f"error={e}")
        return None
    t = timed(lambda: search_fn(workload, nprobe), warmup=1, iters=1)
    res = search_fn(workload, nprobe)
    rec = recall_at_k(res, truth)
    return t, rec, res.tuples_scanned


def run_dataset(dataset: str):
    min_part = max(256, N // 64)
    if dataset == "relatedqs":
        kg = kg_style(n=N, d=D, queries_per_split=Q)
        db, wl = kg.db, kg.splits[0]
        train_wl = kg.splits[0]
    elif dataset == "lp":
        db, wl = lp_style(n=N, d=D, n_queries=Q)
        train_wl = None  # no historical log → batching-only HQI (paper §6.2)
    else:
        seed = {"msturing": 1, "sift": 2, "yandext2i": 3}[dataset]
        metric = "ip" if dataset == "yandext2i" else "l2"
        db, wl, _ = synthetic_bigann_style(
            n=N, d=D, n_query_vecs=max(10, Q // 20), metric=metric, seed=seed
        )
        train_wl = wl

    truth = exhaustive_search(db, wl)

    # --- index builds (Table 4) ---------------------------------------------
    t0 = time.perf_counter()
    hqi = HQIIndex.build(
        db, train_wl if train_wl is not None else wl.subset(np.array([], dtype=np.int64)),
        HQIConfig(min_partition_size=min_part, max_leaves=64),
    ) if train_wl is not None else None
    hqi_build = time.perf_counter() - t0
    pre = PreFilterIndex.build(db)
    post = PostFilterIndex.build(db)

    if hqi is None:
        # LP: no log → HQI degenerates to PreFilter + vector batching
        hqi_build = pre.build_seconds

    emit(f"table4.{dataset}.hqi_build", hqi_build * 1e6, "1.00x")
    emit(f"table4.{dataset}.prefilter_build", pre.build_seconds * 1e6,
         f"{pre.build_seconds / max(hqi_build, 1e-9):.2f}x")

    # --- workload execution (Table 3) ----------------------------------------
    if hqi is not None:
        fn_hqi = lambda w, np_: hqi.search(w, nprobe=np_)
    else:
        fn_hqi = lambda w, np_: pre.search(w, nprobe=np_, batch_vec=True)
    r = _tuned_time(fn_hqi, wl, truth, "hqi", dataset)
    t_hqi, rec, scanned = r
    emit(f"table3.{dataset}.hqi", t_hqi * 1e6, f"1.00x,recall={rec:.2f},scanned={scanned}")

    fn_pre = lambda w, np_: pre.search(w, nprobe=np_)
    r = _tuned_time(fn_pre, wl, truth, "prefilter", dataset)
    if r:
        t, rec, scanned = r
        emit(f"table3.{dataset}.prefilter", t * 1e6,
             f"{t / t_hqi:.2f}x,recall={rec:.2f},scanned={scanned}")

    fn_post = lambda w, np_: post.search(w, nprobe=np_, expansion=10)
    r = _tuned_time(fn_post, wl, truth, "postfilter", dataset)
    if r:
        t, rec, scanned = r
        emit(f"table3.{dataset}.postfilter", t * 1e6,
             f"{t / t_hqi:.2f}x,recall={rec:.2f},scanned={scanned}")

    if RangeIndex.applicable(wl):
        rng_idx = RangeIndex.build(db, "A", n_buckets=16)
        emit(f"table4.{dataset}.range_build", rng_idx.build_seconds * 1e6,
             f"{rng_idx.build_seconds / max(hqi_build, 1e-9):.2f}x")
        fn_rng = lambda w, np_: rng_idx.search(w, nprobe=np_)
        r = _tuned_time(fn_rng, wl, truth, "range", dataset)
        if r:
            t, rec, scanned = r
            emit(f"table3.{dataset}.range", t * 1e6,
                 f"{t / t_hqi:.2f}x,recall={rec:.2f},scanned={scanned}")
    else:
        emit(f"table3.{dataset}.range", 0.0, "NA(IN/NOTNULL constraints)")


def main():
    datasets = ["relatedqs", "lp"] if FAST else ["relatedqs", "lp", "msturing", "sift", "yandext2i"]
    for ds in datasets:
        run_dataset(ds)


if __name__ == "__main__":
    main()
