"""Figures 4 + 5: per-template runtime breakdown and fraction of tuples

scanned, HQI (m=0, m=10) vs PreFilter on the RelatedQS-shaped workload.
Templates are ordered by selectivity (T1 most selective).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    HQIConfig, HQIIndex, PreFilterIndex, exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import kg_style

from .common import D, N, Q, emit, timed


def main():
    kg = kg_style(n=N, d=D, queries_per_split=Q)
    db, wl = kg.db, kg.splits[0]
    truth = exhaustive_search(db, wl)
    min_part = max(256, N // 64)

    hqi0 = HQIIndex.build(db, wl, HQIConfig(m=0, min_partition_size=min_part, max_leaves=64))
    hqi10 = HQIIndex.build(
        db, wl, HQIConfig(m=10, n_coarse_centroids=32, min_partition_size=min_part, max_leaves=64)
    )
    pre = PreFilterIndex.build(db)

    np_h0 = tune_nprobe(lambda w, np_: hqi0.search(w, nprobe=np_), wl, truth)
    np_h10 = tune_nprobe(lambda w, np_: hqi10.search(w, nprobe=np_), wl, truth)
    np_pre = tune_nprobe(lambda w, np_: pre.search(w, nprobe=np_), wl, truth)

    order = np.argsort([kg.selectivities[t] for t in range(len(wl.templates))])
    t1_time = None
    for rank, ti in enumerate(order):
        qidx = wl.queries_for_template(int(ti))
        if len(qidx) == 0:
            continue
        sub = wl.subset(qidx)
        sub_truth_ids = truth.ids[qidx]
        for label, idx, np_t in (
            ("hqi_m0", hqi0, np_h0), ("hqi_m10", hqi10, np_h10), ("prefilter", pre, np_pre),
        ):
            fn = (lambda: idx.search(sub, nprobe={0: np_t[int(ti)]}))
            t = timed(fn)
            res = fn()
            frac = res.tuples_scanned / (db.n * sub.m)
            if t1_time is None:
                t1_time = t
            emit(
                f"fig4_5.T{rank+1}.{label}", t / sub.m * 1e6,
                f"norm_t={t/t1_time:.2f},scan_frac={frac:.4f},sel={kg.selectivities[int(ti)]:.5f}",
            )


if __name__ == "__main__":
    main()
