"""CI guard for the observability layer (rides the bench-smoke job).

    PYTHONPATH=src python -m benchmarks.check_obs [BENCH_obs.json] [trace.json]

Fails the build when
  * the tracing-enabled/disabled QPS overhead ratio from the obs bench
    exceeds ``REPRO_OBS_MAX_OVERHEAD`` (default 1.05 — the "tracing costs
    < 5%" contract), or
  * the exported ``trace.json`` fails Chrome-trace schema validation, or
  * the trace is missing the span names the serving pipeline must emit
    (queue wait, dispatch, merge, flush, WAL fsync) — a silent
    instrumentation regression would otherwise pass the ratio gate by
    tracing nothing.

The overhead gate is a ratio of two medians measured interleaved on the
same machine in the same process, so it is far more stable than an absolute
QPS floor; still, noisy shared runners can exceed 1.05 on a fair build —
bump ``REPRO_OBS_MAX_OVERHEAD`` explicitly in the workflow rather than
deleting the gate.
"""
from __future__ import annotations

import json
import os
import sys

from repro.obs.trace import validate_chrome_trace

# every serving trace must show these stages end-to-end; dispatch/merge span
# names carry stage suffixes (dispatch.scan, merge.segmented, merge.final,
# merge.gather) so those two are prefix-matched. profile.* instants come
# from the kernel profiler, which main_obs runs alongside tracing in the
# enabled arm — their absence means the profiler lost its dispatch hook.
REQUIRED_SPANS = ["queue.wait", "flush", "wal.fsync"]
REQUIRED_PREFIXES = ["dispatch.", "merge.", "profile."]


def check(bench_path: str, trace_path: str, max_ratio: float) -> list:
    errors = []

    with open(bench_path) as f:
        bench = json.load(f)
    rows = {r["name"]: r for r in bench.get("rows", [])}
    row = rows.get("obs/overhead_ratio")
    if row is None:
        errors.append(f"{bench_path}: no obs/overhead_ratio row")
    else:
        # derived leads with the full-precision ratio ("0.987x ...");
        # us_per_call goes through emit's %.1f and is only a fallback
        try:
            ratio = float(row["derived"].split("x", 1)[0])
        except (ValueError, IndexError):
            ratio = float(row["us_per_call"])
        if ratio > max_ratio:
            errors.append(
                f"tracing overhead {ratio:.3f}x exceeds gate {max_ratio:.2f}x"
                f" ({row['derived']})"
            )
        else:
            print(f"overhead ratio {ratio:.3f}x <= {max_ratio:.2f}x  OK")

    try:
        with open(trace_path) as f:
            doc = json.load(f)
        n = validate_chrome_trace(doc)
        print(f"{trace_path}: {n} events, schema OK")
    except (OSError, ValueError, json.JSONDecodeError) as e:
        errors.append(f"{trace_path}: {e}")
        return errors  # no events to check names against

    names = {e["name"] for e in (doc["traceEvents"] if isinstance(doc, dict) else doc)}
    for want in REQUIRED_SPANS:
        if want not in names:
            errors.append(f"trace missing required span {want!r}")
    for pre in REQUIRED_PREFIXES:
        if not any(n.startswith(pre) for n in names):
            errors.append(f"trace has no span named {pre}*")
    if not errors:
        print(f"required spans present ({len(names)} distinct names)")
    return errors


def main() -> int:
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_obs.json"
    trace_path = sys.argv[2] if len(sys.argv) > 2 else "trace.json"
    max_ratio = float(os.environ.get("REPRO_OBS_MAX_OVERHEAD", "1.05"))
    errors = check(bench_path, trace_path, max_ratio)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
