"""Online serving throughput: micro-batched HQIService vs per-query loop.

Streams a KG-style query log (Table-1 template mix) through ``HQIService``
with one interleaved insert/delete + ``refresh()`` cycle at the midpoint —
the serving scenario the offline benchmarks can't measure. Reports:

  * service/qps            — sustained queries/second of the full stream
                             (submit → micro-batch flush → delta merge)
  * service/p50, p99       — submit→answer latency percentiles
  * naive/qps              — the same index driven one query at a time
                             (``search_online`` loop, measured on a subsample)
  * service/speedup        — service QPS / naive QPS (target: ≥ 5×)
  * service/parity_exact   — fraction of a subsample answered identically to
                             exhaustive search over the final live DB state
                             (exact mode; must be 1.000)

``main_obs`` (suite "obs") measures the observability layer itself:
tracing-enabled vs -disabled serving passes interleaved A/B/A/B, the
enabled/disabled overhead ratio (CI gates at 1.05 via check_obs.py), span
counts, a schema-validated ``trace.json`` export, and the drift monitor's
reading of a template shift injected at the stream midpoint.

"derived" holds the paper-comparable figure for each row.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import HQIConfig, HQIIndex, exhaustive_search
from repro.core.workload import kg_style
from repro.service import HQIService, ServiceConfig

from .common import FAST, N, D, Q, emit, timed


def _submit_range(svc: HQIService, wl, lo: int, hi: int) -> list:
    return [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        for i in range(lo, hi)
    ]


def main() -> None:
    n = min(N, 20_000 if FAST else 100_000)
    kg = kg_style(n=n, d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(1024, n // 16), max_leaves=32)
    )
    svc = HQIService(
        hqi,
        ServiceConfig(
            k=wl.k, nprobe=8, max_batch=256, deadline_s=0.005
        ),
    )

    # --- sustained stream with a live insert/delete + refresh at midpoint ---
    rng = np.random.default_rng(1)
    n_new = 100 if FAST else 500
    half = wl.m // 2

    import time

    def stream() -> Tuple[float, float]:
        """One pass: (query seconds, write+refresh seconds)."""
        newv = kg.db.vectors[rng.integers(0, kg.db.n, n_new)] + 0.01 * rng.normal(
            size=(n_new, D)
        ).astype(np.float32)
        t0 = time.perf_counter()
        _submit_range(svc, wl, 0, half)
        svc.drain()
        t1 = time.perf_counter()
        ids = svc.insert(newv)  # all-NULL attrs: visible to pure-vector templates
        svc.delete(rng.integers(0, kg.db.n, n_new // 2))
        svc.delete(ids[: n_new // 10])
        svc.refresh()
        t2 = time.perf_counter()
        _submit_range(svc, wl, half, wl.m)
        svc.drain()
        t3 = time.perf_counter()
        return (t1 - t0) + (t3 - t2), t2 - t1

    # warmup pass compiles every flush shape; the measured passes are
    # steady-state serving (each pass runs its own insert/delete + refresh
    # cycle); medians tame scheduler noise on small machines
    stream()
    passes = [stream() for _ in range(2 if FAST else 1)]
    query_s = float(np.median([p[0] for p in passes]))
    write_s = float(np.median([p[1] for p in passes]))
    qps = wl.m / query_s

    s = svc.telemetry.summary()
    emit("service/qps", query_s / wl.m * 1e6, f"{qps:.0f} qps sustained, {wl.m} queries")
    emit(
        "service/refresh_cycle",
        write_s * 1e6,
        f"{n_new} inserts + {n_new // 2 + n_new // 10} deletes folded in {write_s*1e3:.0f} ms",
    )
    emit("service/p50", s["p50_latency_s"] * 1e6, f"{s['p50_latency_s']*1e3:.1f} ms p50")
    emit("service/p99", s["p99_latency_s"] * 1e6, f"{s['p99_latency_s']*1e3:.1f} ms p99")
    emit(
        "service/dispatches_per_flush",
        0.0,
        f"{s['knn_dispatches_per_flush']:.1f} knn + "
        f"{s['merge_dispatches_per_flush']:.1f} merge over {s['flushes']:.0f} flushes",
    )

    # --- naive baseline: one query at a time through the same index ----------
    sub = min(wl.m, 50 if FAST else 200)
    live = svc._live.copy()  # post-refresh: covers every indexed row

    def naive_loop() -> None:
        for i in range(sub):
            hqi.search_online(wl.subset(np.array([i])), nprobe=8, live_mask=live)

    t_naive = timed(naive_loop, warmup=1, iters=2)
    naive_qps = sub / t_naive
    emit("naive/qps", t_naive / sub * 1e6, f"{naive_qps:.0f} qps per-query loop")
    emit("service/speedup", 0.0, f"{qps / naive_qps:.1f}x over per-query loop (target >=5x)")

    # --- exact-mode parity vs the final live DB state ------------------------
    n_par = min(wl.m, 32 if FAST else 64)
    svc.cfg.nprobe = 10_000  # exhaustive within routing: exact answers
    handles = _submit_range(svc, wl, 0, n_par)
    svc.drain()
    sub_wl = wl.subset(np.arange(n_par))
    snap = svc.snapshot_db()
    live_ids = svc.live_ids()
    truth = exhaustive_search(snap, sub_wl)
    tids = np.where(truth.ids >= 0, live_ids[np.maximum(truth.ids, 0)], -1)
    same = sum(
        set(h.ids[h.ids >= 0].tolist()) == set(tids[i][tids[i] >= 0].tolist())
        for i, h in enumerate(handles)
    )
    emit("service/parity_exact", 0.0, f"{same / n_par:.3f} of {n_par} queries identical")


def main_obs() -> None:
    """Observability overhead + drift detection on a WAL-backed service.

    Interleaves observability-enabled and -disabled passes (A/B/A/B) over the
    same service so machine noise hits both arms equally, then reports the
    enabled/disabled median ratio — the number ci.yml gates at 1.05 via
    ``benchmarks/check_obs.py``. The enabled arm runs tracing AND the kernel
    dispatch profiler together (the gate covers the full observability
    stack, and the trace must carry the ``profile.dispatch`` instants
    check_obs requires). The enabled pass also exports ``trace.json``
    (Chrome trace, schema-validated here) and feeds the drift monitor a
    template shift at the stream midpoint that ``obs/drift_shift`` must see.
    """
    import os
    import tempfile
    import time

    from repro.obs import trace
    from repro.obs.metrics import get_registry
    from repro.obs.profile import disable_profiler, enable_profiler
    from repro.store.wal import WriteAheadLog

    n = min(N, 10_000 if FAST else 50_000)
    kg = kg_style(n=n, d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(1024, n // 16), max_leaves=32)
    )
    # template split for the injected drift: first half of the stream draws
    # from the low-numbered templates, second half from the high-numbered —
    # the share shift the drift monitor must report
    tcut = max(1, len(wl.templates) // 2)
    rows_a = np.where(wl.template_of < tcut)[0]
    rows_b = np.where(wl.template_of >= tcut)[0]
    if len(rows_a) == 0 or len(rows_b) == 0:  # degenerate split: no shift
        rows_a = rows_b = np.arange(wl.m)
    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    wal = WriteAheadLog(os.path.join(tmp, "wal"))
    svc = HQIService(
        hqi,
        ServiceConfig(
            # batch_vec=True: even smoke-sized flushes go through the engine,
            # so the trace carries the dispatch.scan/merge.* spans the CI
            # guard requires (the "auto" crossover would route tiny batches
            # per-query and trace nothing from the plan executor)
            k=wl.k, nprobe=8, max_batch=64, deadline_s=0.002, batch_vec=True,
            # window exactly one pass: at report time the older half is the
            # rows_a traffic and the recent half rows_b, so the injected
            # shift isn't washed out by the earlier timing passes
            drift_window=len(rows_a) + len(rows_b),
        ),
        wal=wal,
    )
    rng = np.random.default_rng(2)
    n_new = 50 if FAST else 200

    def stream_half(rows) -> None:
        for i in rows:
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        svc.drain()

    def one_pass() -> float:
        newv = kg.db.vectors[rng.integers(0, kg.db.n, n_new)]
        t0 = time.perf_counter()
        stream_half(rows_a)
        svc.insert(newv)
        svc.delete(rng.integers(0, kg.db.n, n_new // 2))
        svc.refresh()
        stream_half(rows_b)
        return time.perf_counter() - t0

    one_pass()  # warmup: compile every flush shape before either arm times
    t_dis, t_en = [], []
    for _ in range(2 if FAST else 3):
        trace.disable()
        disable_profiler()
        t_dis.append(one_pass())
        trace.enable()  # fresh Tracer per enabled pass (bounded ring)
        prof = enable_profiler()
        t_en.append(one_pass())
    m_queries = len(rows_a) + len(rows_b)
    dis_s = float(np.median(t_dis))
    en_s = float(np.median(t_en))
    ratio = en_s / dis_s

    tracer = trace.get_tracer()
    doc = tracer.to_chrome_trace()
    n_events = trace.validate_chrome_trace(doc)
    trace_path = os.path.abspath("trace.json")
    tracer.export(trace_path)
    span_names = {e["name"] for e in doc["traceEvents"]}
    rep = svc.drift_report()
    reg_keys = sorted(get_registry().snapshot().keys())
    prof_snap = prof.snapshot()
    trace.disable()
    disable_profiler()

    emit("obs/qps_disabled", dis_s / m_queries * 1e6,
         f"{m_queries / dis_s:.0f} qps, tracing off")
    emit("obs/qps_enabled", en_s / m_queries * 1e6,
         f"{m_queries / en_s:.0f} qps, tracing+profiler on "
         f"({tracer.span_count} spans)")
    emit("obs/overhead_ratio", ratio,
         f"{ratio:.3f}x enabled/disabled (gate: 1.05)")
    phases = {
        k: v["dispatches"] for k, v in prof_snap.items() if isinstance(v, dict)
    }
    emit("obs/profile", 0.0,
         f"{prof_snap.get('attributed', 0)} dispatches attributed in enabled "
         f"arm: {phases}")
    emit("obs/trace_events", float(n_events),
         f"{n_events} events, {len(span_names)} distinct names -> {trace_path}")
    emit("obs/drift_shift", rep.share_shift,
         f"TV distance {rep.share_shift:.3f} across injected template shift "
         f"({rep.n_window} queries windowed)")
    emit("obs/registry", 0.0, f"{len(reg_keys)} entries: {' '.join(reg_keys)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
