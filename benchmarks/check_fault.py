"""CI guard for the fault-injection layer (rides the chaos-smoke job).

    PYTHONPATH=src python -m benchmarks.check_fault [BENCH_fault.json]

Fails the build when
  * the disarmed-failpoint overhead ratio from the fault bench exceeds
    ``REPRO_FAULT_MAX_OVERHEAD`` (default 1.02 — the "failpoints left in
    production paths cost < 2%" contract). The bench computes it as
    1 + (evaluations per serving pass x microbenched ns-per-call) / pass
    time — an exact pricing of the disarmed fast path, immune to the
    several-percent kernel-dispatch jitter an end-to-end A/B would gate on;
  * the bench's in-process chaos smoke violated a standing invariant:
    a hung query, a lost acknowledged write, or a parity mismatch on a
    non-degraded answer (all three rows must read exactly 0).

A regression trips the gate through either factor: a slower fast path
(someone put a lock or a dict lookup before the _ACTIVE check) or an
evaluation-count explosion (someone put a failpoint inside a per-row loop).
The invariant rows are exact and never environment-dependent: any nonzero
value is a real bug.
"""
from __future__ import annotations

import json
import os
import sys

# chaos rows that must be exactly zero, whatever the machine
ZERO_ROWS = ["fault/chaos_hung", "fault/chaos_lost_acked", "fault/chaos_parity"]


def check(bench_path: str, max_ratio: float) -> list:
    errors = []
    with open(bench_path) as f:
        bench = json.load(f)
    rows = {r["name"]: r for r in bench.get("rows", [])}

    row = rows.get("fault/overhead_ratio")
    if row is None:
        errors.append(f"{bench_path}: no fault/overhead_ratio row")
    else:
        try:
            ratio = float(row["derived"].split("x", 1)[0])
        except (ValueError, IndexError):
            ratio = float(row["us_per_call"])
        if ratio > max_ratio:
            errors.append(
                f"failpoint overhead {ratio:.3f}x exceeds gate {max_ratio:.2f}x"
                f" ({row['derived']})"
            )
        else:
            print(f"overhead ratio {ratio:.3f}x <= {max_ratio:.2f}x  OK")

    for name in ZERO_ROWS:
        row = rows.get(name)
        if row is None:
            errors.append(f"{bench_path}: no {name} row")
        elif float(row["us_per_call"]) != 0.0:
            errors.append(f"chaos invariant violated: {name} ({row['derived']})")
    if not any(e.startswith("chaos") or e.endswith("row") for e in errors):
        print("chaos invariants hold (0 hung / 0 lost acked / 0 parity)")
    return errors


def main() -> int:
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_fault.json"
    max_ratio = float(os.environ.get("REPRO_FAULT_MAX_OVERHEAD", "1.02"))
    errors = check(bench_path, max_ratio)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
