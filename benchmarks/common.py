"""Shared benchmark scaffolding.

Scale knobs via env (laptop-scale defaults; the paper runs 100M vectors):
    REPRO_BENCH_N        database size            (default 100_000)
    REPRO_BENCH_D        vector dims              (default 64)
    REPRO_BENCH_Q        queries per split        (default 2_000)
    REPRO_BENCH_FAST=1   tiny sizes for CI smoke

Every benchmark prints ``name,us_per_call,derived`` CSV rows; "derived" holds
the paper-comparable figure (speedup ×, recall, tuples-scanned fraction, …).
``benchmarks.run`` additionally writes each suite's rows to a machine-readable
``BENCH_<suite>.json`` (via ``write_suite_json``) so the perf trajectory can
be tracked across PRs as a CI artifact.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Callable, Dict, List, Optional

import numpy as np

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
N = int(os.environ.get("REPRO_BENCH_N", "20000" if FAST else "100000"))
D = int(os.environ.get("REPRO_BENCH_D", "16" if FAST else "64"))
Q = int(os.environ.get("REPRO_BENCH_Q", "300" if FAST else "2000"))

_ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def write_suite_json(suite: str, rows_csv: List[str], out_dir: str = ".") -> str:
    """Write one suite's emitted rows as ``BENCH_<suite>.json``; returns path.

    Schema: {"suite", "env": scale knobs, "rows": [{"name", "us_per_call",
    "derived"}]} — stable keys so a dashboard can diff runs across PRs.
    """
    parsed = []
    for row in rows_csv:
        name, us, derived = row.split(",", 2)
        parsed.append({"name": name, "us_per_call": float(us), "derived": derived})
    doc = {
        "suite": suite,
        "env": {
            "N": N, "D": D, "Q": Q, "fast": FAST,
            "python": platform.python_version(),
            "use_pallas": os.environ.get("REPRO_USE_PALLAS", "0"),
        },
        "rows": parsed,
    }
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
    return path


def timed(fn: Callable, *, warmup: int = 1, iters: int = 1) -> float:
    """Seconds per call (median of iters after warmup)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def rows():
    return list(_ROWS)
