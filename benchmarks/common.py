"""Shared benchmark scaffolding.

Scale knobs via env (laptop-scale defaults; the paper runs 100M vectors):
    REPRO_BENCH_N        database size            (default 100_000)
    REPRO_BENCH_D        vector dims              (default 64)
    REPRO_BENCH_Q        queries per split        (default 2_000)
    REPRO_BENCH_FAST=1   tiny sizes for CI smoke

Every benchmark prints ``name,us_per_call,derived`` CSV rows; "derived" holds
the paper-comparable figure (speedup ×, recall, tuples-scanned fraction, …).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, Optional

import numpy as np

FAST = os.environ.get("REPRO_BENCH_FAST", "0") == "1"
N = int(os.environ.get("REPRO_BENCH_N", "20000" if FAST else "100000"))
D = int(os.environ.get("REPRO_BENCH_D", "16" if FAST else "64"))
Q = int(os.environ.get("REPRO_BENCH_Q", "300" if FAST else "2000"))

_ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    _ROWS.append(row)
    print(row, flush=True)


def timed(fn: Callable, *, warmup: int = 1, iters: int = 1) -> float:
    """Seconds per call (median of iters after warmup)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def rows():
    return list(_ROWS)
