"""Kernel-level microbench: the fused masked-KNN work-unit throughput

(CPU wall-clock of the jnp path; the Pallas path is TPU-targeted and runs
interpret-mode for correctness only) + roofline-derived intensity figures.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels import ops

from .common import emit, timed


def pq_bench():
    from repro.core.pq import PQIndex

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(50_000, 64)).astype(np.float32)
    idx = PQIndex.build(vecs, m=8)
    q = rng.normal(size=(64, 64)).astype(np.float32)

    t = timed(lambda: idx.search(q, k=10), warmup=1, iters=2)
    emit("kernel.pq_adc_scan.n50k_m8", t / 64 * 1e6,
         f"compression={idx.compression_ratio:.0f}x")
    t2 = timed(lambda: idx.search(q, k=10, rerank=8), warmup=1, iters=2)
    emit("kernel.pq_adc_rerank8.n50k_m8", t2 / 64 * 1e6, "")


def main():
    pq_bench()
    rng = np.random.default_rng(0)
    for (w, tq, tv, d, k) in [(8, 64, 256, 64, 10), (32, 64, 512, 128, 10)]:
        q = jnp.asarray(rng.normal(size=(w, tq, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(w, tv, d)).astype(np.float32))
        valid = jnp.asarray(rng.random((w, tv)) > 0.3)

        def call():
            s, i = ops.batched_masked_topk(q, v, valid, k, metric="ip", use_pallas=False)
            jax.block_until_ready(s)

        t = timed(call, warmup=2, iters=3)
        flops = 2 * w * tq * tv * d
        ai = flops / (4 * w * (tq * d + tv * d + tq * k * 2))  # arithmetic intensity
        emit(
            f"kernel.masked_topk.w{w}q{tq}v{tv}d{d}", t * 1e6,
            f"gflops={flops/t/1e9:.1f},intensity={ai:.1f}",
        )


if __name__ == "__main__":
    main()
