"""Sharded engine accounting: per-rank scan traffic, gather width, parity.

Reports, for one HQI workload on a |model|-rank mesh (8 virtual host devices
on CPU — the same harness the mesh-parity tests use):

  * distributed/parity_exact       — sharded vs single-device engine results
                                     (must be 1.000: bit-identical)
  * distributed/search_meshR       — wall time of the sharded search
  * distributed/per_rank_bytes     — mean bytes scanned per rank vs the
                                     single-device scan (~1/|model| each)
  * distributed/gathered_per_query — candidate columns all-gathered per
                                     query: O(k·|model|), independent of N
  * distributed/balance            — max/mean per-rank scan bytes (skew)

jax must see the virtual device pool BEFORE first import, so ``main()``
re-execs this module as a subprocess with XLA_FLAGS set when the current
process has too few devices, and re-emits the child's CSV rows into the
suite (BENCH_distributed.json still lands in the parent).
"""
from __future__ import annotations

import os
import subprocess
import sys

from .common import FAST, N, D, Q, emit, timed

DEVICES = 8


def _run() -> None:
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.core import HQIConfig, HQIIndex
    from repro.core.plan import PlanConfig
    from repro.core.workload import kg_style

    kg = kg_style(n=min(N, 5000 if FAST else 50_000), d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64,
                             plan=PlanConfig(use_pallas=False))
    )
    nprobe = 8

    ref = hqi.search(wl, nprobe=nprobe, batch_vec=True)
    t_single = timed(lambda: hqi.search(wl, nprobe=nprobe, batch_vec=True), warmup=1, iters=2)
    emit("distributed/search_single", t_single * 1e6, f"{wl.m / t_single:.0f} qps")

    R = min(DEVICES, len(jax.devices()))
    hqi.cfg.mesh = Mesh(np.asarray(jax.devices()[:R]), ("model",))
    res = hqi.search(wl, nprobe=nprobe, batch_vec=True)
    t_shard = timed(lambda: hqi.search(wl, nprobe=nprobe, batch_vec=True), warmup=1, iters=2)

    exact = float(np.array_equal(ref.scores, res.scores) and np.array_equal(ref.ids, res.ids))
    st = res.shard_stats
    single = int(ref.bytes_scanned)  # the INDEPENDENT mesh-less measurement
    mean_rank = int(st.per_rank_bytes.sum()) / max(1, R)
    emit("distributed/parity_exact", 0.0, f"{exact:.3f}")
    emit(
        f"distributed/search_mesh{R}", t_shard * 1e6,
        f"{wl.m / t_shard:.0f} qps on {R} host ranks",
    )
    emit(
        "distributed/per_rank_bytes", 0.0,
        f"{mean_rank:.0f} B/rank = {mean_rank / max(single, 1):.3f} of the "
        f"single-device scan ({single} B; target 1/{R} = {1 / R:.3f})",
    )
    emit(
        "distributed/gathered_per_query", 0.0,
        f"{st.gathered_per_query} candidate cols (k={wl.k} x {R} ranks; O(k·|model|), not O(n))",
    )
    emit(
        "distributed/balance", 0.0,
        f"max/mean per-rank bytes = {st.per_rank_bytes.max() / max(mean_rank, 1):.2f}",
    )

    # --- segmented vs dense merge footprint at mesh R (skewed routing) -------
    # the dense sharded merge stacks [R, m, n_slots, k] before the gather, so
    # the ragged win compounds with mesh size; ci.yml requires >= 4x here
    from repro.kernels import ops as kops

    nprobe_skew = {ti: (12 if ti == 0 else 1) for ti in range(len(wl.templates))}
    peaks, results = {}, {}
    for layout in ("dense", "segmented"):
        hqi.cfg.plan.merge_layout = layout
        kops.reset_dispatch_stats()
        results[layout] = hqi.search(wl, nprobe=nprobe_skew, batch_vec=True)
        peaks[layout] = int(kops.dispatch_stats().peak_candidate_bytes)
    hqi.cfg.plan.merge_layout = "segmented"
    parity = float(
        np.array_equal(results["dense"].scores, results["segmented"].scores)
        and np.array_equal(results["dense"].ids, results["segmented"].ids)
    )
    ratio = peaks["dense"] / max(peaks["segmented"], 1)
    emit(
        "distributed/skewed_peak_dense_bytes", float(peaks["dense"]),
        f"R={R} stacked dense merge buffer, skewed routing",
    )
    emit(
        "distributed/skewed_peak_segmented_bytes", float(peaks["segmented"]),
        f"per-rank ragged gather ({ratio:.1f}x smaller at R={R})",
    )
    emit("distributed/skewed_parity_exact", 0.0, f"{parity:.3f}")


def main() -> None:
    import jax

    if len(jax.devices()) >= DEVICES:
        _run()
        return
    # jax is already initialized single-device: re-exec with the virtual pool
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + f" --xla_force_host_platform_device_count={DEVICES}"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    env.get("PYTHONPATH", "")] if p
    )
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_distributed"],
        capture_output=True, text=True, env=env, timeout=1800,
    )
    if r.returncode != 0:
        raise RuntimeError(f"bench_distributed child failed:\n{r.stdout}\n{r.stderr}")
    for line in r.stdout.splitlines():
        # re-emit the child's CSV rows so the parent's suite JSON sees them
        if line.startswith("distributed/"):
            name, us, derived = line.split(",", 2)
            emit(name, float(us), derived)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    _run()
