"""Persistence & recovery: snapshot load vs. rebuild, WAL replay, parity.

The operational numbers behind the store subsystem (repro.store) on the
KG-style workload:

  * store/build            — full ``HQIIndex.build`` from raw tuples (the
                             only restart path before the store existed)
  * store/save             — one snapshot generation (manifest + .npy blobs)
  * store/load             — mmap'd snapshot load (zero-copy; metadata-bound)
  * store/load_speedup     — build / load (derived; target: ≥ 10×)
  * store/loaded_parity    — fraction of queries the loaded index answers
                             bit-identically to the in-memory original
                             (derived; must be 1.000)
  * store/wal_append       — per committed insert record (fsync'd)
  * store/wal_replay       — recovery replay throughput (derived: rows/s)
  * store/recovery_parity  — crash simulation (torn WAL tail): fraction of
                             queries a recovered service answers identically
                             to the uncrashed process (derived; must be 1.000)

"derived" holds the paper-comparable figure for each row.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import HQIConfig, HQIIndex
from repro.core.workload import kg_style
from repro.service import ServiceConfig
from repro.store import init_store, load_snapshot, open_service, save_snapshot
from repro.store.wal import _HEADER, _MAGIC

from .common import FAST, N, D, Q, emit, timed


def main() -> None:
    n = min(N, 20_000 if FAST else 100_000)
    kg = kg_style(n=n, d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    cfg = HQIConfig(min_partition_size=max(1024, n // 16), max_leaves=32)

    # --- snapshot save/load vs. full rebuild --------------------------------
    t0 = time.perf_counter()
    hqi = HQIIndex.build(kg.db, wl, cfg)
    build_s = time.perf_counter() - t0
    hqi.search(wl, nprobe=8)  # warm arena + bitmap cache (what a snapshot ships)
    emit("store/build", build_s * 1e6, f"{build_s:.2f}s rebuild from raw tuples")

    root = tempfile.mkdtemp(prefix="bench_store_")
    try:
        save_s = timed(lambda: save_snapshot(root, hqi), warmup=0, iters=1)
        emit("store/save", save_s * 1e6, "one generation (manifest + npy)")

        load_s = timed(lambda: load_snapshot(root), warmup=1, iters=3)
        speedup = build_s / load_s
        emit("store/load", load_s * 1e6, "mmap load (zero-copy)")
        emit("store/load_speedup", load_s * 1e6, f"{speedup:.1f}x vs rebuild")

        loaded = load_snapshot(root).index
        r0 = hqi.search(wl, nprobe=8)
        r1 = loaded.search(wl, nprobe=8)
        same = np.all(r0.ids == r1.ids, axis=1) & np.all(
            r0.scores == r1.scores, axis=1
        )
        emit("store/loaded_parity", 0.0, f"parity_exact {same.mean():.3f}")
    finally:
        shutil.rmtree(root, ignore_errors=True)

    # --- WAL append / replay rate ------------------------------------------
    n_rec = 50 if FAST else 200
    batch = 16
    rng = np.random.default_rng(1)
    new_rows = rng.normal(size=(n_rec * batch, D)).astype(np.float32)
    root = tempfile.mkdtemp(prefix="bench_store_")
    try:
        svc = init_store(
            root, hqi, cfg=ServiceConfig(k=wl.k, nprobe=8, delta_pq_threshold=None)
        )
        t0 = time.perf_counter()
        for r in range(n_rec):
            svc.insert(new_rows[r * batch : (r + 1) * batch])
        append_s = (time.perf_counter() - t0) / n_rec
        emit("store/wal_append", append_s * 1e6, f"batch={batch}, fsync per commit")

        t0 = time.perf_counter()
        svc2 = open_service(root, cfg=svc.cfg)
        replay_s = time.perf_counter() - t0
        rate = (n_rec * batch) / replay_s
        assert svc2.n_live == svc.n_live
        emit("store/wal_replay", replay_s * 1e6, f"{rate:.0f} rows/s replayed")

        # --- crash recovery parity (torn tail dropped, acks identical) ------
        svc.delete(np.arange(0, 50, 7))
        svc.wal.close()
        seg = os.path.join(root, "wal", svc.wal.segments()[-1])
        with open(seg, "ab") as f:
            f.write(_HEADER.pack(_MAGIC, 10**6, 1, 400, 0) + b"z" * 11)  # torn
        t0 = time.perf_counter()
        svc3 = open_service(root, cfg=svc.cfg)
        recover_s = time.perf_counter() - t0

        sub = min(wl.m, 128 if FAST else 512)
        handles_a = [
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
            for i in range(sub)
        ]
        svc.drain()
        handles_b = [
            svc3.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
            for i in range(sub)
        ]
        svc3.drain()
        same = np.array(
            [
                np.array_equal(a.ids, b.ids) and np.array_equal(a.scores, b.scores)
                for a, b in zip(handles_a, handles_b)
            ]
        )
        emit(
            "store/recovery_parity",
            recover_s * 1e6,
            f"parity_exact {same.mean():.3f} after simulated crash",
        )
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
