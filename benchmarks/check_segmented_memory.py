"""CI guard: the segmented candidate pipeline must keep beating the dense
merge bound.

Parses BENCH_engine.json / BENCH_distributed.json (written by
``python -m benchmarks.run --smoke``) and fails the build if

  * the skewed-routing peak candidate-buffer bytes regress back to the dense
    ``m·n_slots·k`` bound (engine: segmented < dense strictly; mesh: dense
    must stay >= 4x segmented — the stacked [R, m, n_slots, k] layout is the
    memory cliff this PR removed),
  * the segmented pq path ever materializes a [W, TQ, M, 256] LUT operand
    (lut_expand_segmented_bytes must be exactly 0), or
  * either layout-parity row reports anything but bit-identical results.

The guarded rows are host-side shape accounting, not timings — they are
deterministic for a given workload, so a hard threshold cannot flake.
"""
from __future__ import annotations

import json
import sys


def _row(doc: dict, name: str) -> dict:
    for r in doc["rows"]:
        if r["name"] == name:
            return r
    sys.exit(f"FAIL: bench row {name!r} missing from BENCH_{doc['suite']}.json")


def main() -> None:
    eng = json.load(open("BENCH_engine.json"))
    dense = _row(eng, "engine/skewed_peak_dense_bytes")["us_per_call"]
    seg = _row(eng, "engine/skewed_peak_segmented_bytes")["us_per_call"]
    if _row(eng, "engine/skewed_parity_exact")["derived"] != "1.000":
        sys.exit("FAIL: segmented != dense results (engine)")
    if not seg < dense:
        sys.exit(
            f"FAIL: segmented peak {seg:.0f} B regressed to the dense "
            f"m*n_slots*k bound ({dense:.0f} B) on the skewed engine suite"
        )
    if _row(eng, "engine/lut_expand_segmented_bytes")["us_per_call"] != 0.0:
        sys.exit("FAIL: segmented pq dispatch materialized a [W,TQ,M,256] LUT operand")

    dist = json.load(open("BENCH_distributed.json"))
    d = _row(dist, "distributed/skewed_peak_dense_bytes")["us_per_call"]
    s = _row(dist, "distributed/skewed_peak_segmented_bytes")["us_per_call"]
    if _row(dist, "distributed/skewed_parity_exact")["derived"] != "1.000":
        sys.exit("FAIL: segmented != dense results (sharded)")
    if not d >= 4 * s:
        sys.exit(
            f"FAIL: mesh skewed peak dense {d:.0f} B < 4x segmented {s:.0f} B "
            "— the ragged per-rank gather lost its memory advantage"
        )
    print(
        f"segmented-memory guard OK: engine {dense / max(seg, 1):.1f}x, "
        f"mesh {d / max(s, 1):.1f}x smaller than dense"
    )


if __name__ == "__main__":
    main()
