"""Figure 6: multi-attribute partitioning. HQI vs Range (partitioned on A)

on the synthetic two-attribute workload — queries over the non-partitioning
attribute B are where Range loses all pruning and HQI keeps it.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    HQIConfig, HQIIndex, RangeIndex, exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import synthetic_bigann_style

from .common import D, N, Q, emit, timed


def main():
    db, wl, sel = synthetic_bigann_style(n=N, d=D, n_query_vecs=max(10, Q // 20), seed=1)
    truth = exhaustive_search(db, wl)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64))
    rng_idx = RangeIndex.build(db, "A", n_buckets=16)

    np_h = tune_nprobe(lambda w, np_: hqi.search(w, nprobe=np_), wl, truth)
    np_r = tune_nprobe(lambda w, np_: rng_idx.search(w, nprobe=np_), wl, truth)

    for ti, t in enumerate(wl.templates):
        attr = getattr(t[0], "attr", "?")
        qidx = wl.queries_for_template(ti)
        sub = wl.subset(qidx)
        t_h = timed(lambda: hqi.search(sub, nprobe={0: np_h[ti]}))
        res_h = hqi.search(sub, nprobe={0: np_h[ti]})
        t_r = timed(lambda: rng_idx.search(sub, nprobe={0: np_r[ti]}))
        res_r = rng_idx.search(sub, nprobe={0: np_r[ti]})
        emit(
            f"fig6.{attr}{ti % 10}.hqi", t_h / sub.m * 1e6,
            f"sel={sel[ti]:.4f},scan={res_h.tuples_scanned}",
        )
        emit(
            f"fig6.{attr}{ti % 10}.range", t_r / sub.m * 1e6,
            f"slowdown={t_r/t_h:.2f}x,scan={res_r.tuples_scanned}",
        )


if __name__ == "__main__":
    main()
