"""Engine dispatch accounting + compressed-scan comparison.

Reports, for one HQI workload:
  * engine/dispatches_global   — kernel dispatches the workload-wide plan
                                 issues (≤ PlanConfig.max_bucket_shapes)
  * engine/dispatches_per_pair — what the same work costs when each
                                 (template × partition) product is planned
                                 separately (the pre-engine architecture)
  * engine/distinct_shapes     — distinct compiled problem shapes seen
  * engine/search              — wall time of the engine-backed search
  * engine/pq_*                — the pq-vs-f32 suite: QPS, bytes-scanned per
                                 query, and recall@10 vs the exact engine at
                                 refine_factor ∈ {1, 2, 4} (scan_mode="pq")

"derived" holds dispatch counts / reduction factors / recall.
"""
from __future__ import annotations

import numpy as np

from repro.core import HQIConfig, HQIIndex, recall_at_k
from repro.core.ivf import ScanStats
from repro.core.plan import build_plan
from repro.core.workload import kg_style
from repro.kernels import ops

from .common import FAST, N, D, Q, emit, timed


def _pq_vs_f32(hqi, wl, nprobe: int) -> None:
    """Two-stage compressed scan vs exact f32 scan, same index, same plan.

    The index was built with scan_mode="pq" so the arena carries codes;
    ``plan.scan_mode`` / ``plan.refine_factor`` are execution-time knobs, so
    one build serves the whole sweep.
    """
    plan = hqi.cfg.plan
    plan.scan_mode = "f32"
    exact = hqi.search(wl, nprobe=nprobe)
    t_f32 = timed(lambda: hqi.search(wl, nprobe=nprobe), warmup=1, iters=2)
    f32_bpq = exact.bytes_scanned / wl.m
    emit(
        "engine/pq_baseline_f32",
        t_f32 * 1e6,
        f"{wl.m / t_f32:.0f} qps; {f32_bpq:.0f} B/query",
    )
    for rf in (1, 2, 4):
        plan.scan_mode, plan.refine_factor = "pq", rf
        res = hqi.search(wl, nprobe=nprobe)
        t_pq = timed(lambda: hqi.search(wl, nprobe=nprobe), warmup=1, iters=2)
        bpq = res.bytes_scanned / wl.m
        emit(
            f"engine/pq_rf{rf}",
            t_pq * 1e6,
            f"{wl.m / t_pq:.0f} qps; {bpq:.0f} B/query "
            f"({f32_bpq / max(bpq, 1):.1f}x less); "
            f"recall@{wl.k}={recall_at_k(res, exact):.3f}",
        )
    plan.scan_mode = "f32"


def _skewed_memory(hqi, wl) -> None:
    """Candidate-buffer footprint, dense vs segmented merge layout, under
    SKEWED routing (one heavy template probing wide, the rest nprobe=1 — the
    shape the dense [m, n_slots, k] tensor pads every query to).

    Peak bytes are host-side shape accounting (DispatchStats), not timings:
    deterministic, so ci.yml can hard-fail a regression back to the dense
    m·n_slots·k bound. Results must stay bit-identical across layouts.
    """
    plan = hqi.cfg.plan
    plan.scan_mode = "pq"  # the LUT rows are only meaningful on the ADC path
    nprobe = {ti: (12 if ti == 0 else 1) for ti in range(len(wl.templates))}
    peaks, luts = {}, {}
    res = {}
    for layout in ("dense", "segmented"):
        plan.merge_layout = layout
        ops.reset_dispatch_stats()
        res[layout] = hqi.search(wl, nprobe=nprobe)
        st = ops.dispatch_stats()
        peaks[layout] = int(st.peak_candidate_bytes)
        luts[layout] = int(st.lut_expand_bytes)
    plan.merge_layout = "segmented"
    plan.scan_mode = "f32"
    exact = float(
        np.array_equal(res["dense"].scores, res["segmented"].scores)
        and np.array_equal(res["dense"].ids, res["segmented"].ids)
    )
    ratio = peaks["dense"] / max(peaks["segmented"], 1)
    emit("engine/skewed_peak_dense_bytes", float(peaks["dense"]),
         f"dense merge buffer, skewed routing ({wl.m} queries)")
    emit("engine/skewed_peak_segmented_bytes", float(peaks["segmented"]),
         f"flat CSR buffer, same workload ({ratio:.1f}x smaller)")
    emit("engine/skewed_parity_exact", 0.0, f"{exact:.3f}")
    emit("engine/lut_expand_dense_bytes", float(luts["dense"]),
         "[W,TQ,M,256] operands the dense pq path materializes")
    emit("engine/lut_expand_segmented_bytes", float(luts["segmented"]),
         "must be 0: segmented pq indexes the resident table in-kernel")


def main() -> None:
    kg = kg_style(n=min(N, 5000 if FAST else 50_000), d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64)
    )
    nprobe = 8

    # --- global plan: one build_plan over every routed product ---------------
    tasks, _, _ = hqi._engine_tasks(wl, nprobe=nprobe, batch_vec=True, stats=ScanStats())
    gplan = build_plan(
        hqi.arena, tasks, wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan
    )
    # --- pre-engine architecture: one plan per (template × partition) --------
    per_pair = 0
    for t in tasks:
        per_pair += build_plan(
            hqi.arena, [t], wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan
        ).n_dispatches

    # count one explicitly isolated search, then time separately
    before = ops.dispatch_stats().snapshot()
    hqi.search(wl, nprobe=nprobe)
    d_stats = ops.dispatch_stats().delta_since(before)
    dispatches = d_stats.knn_calls
    shapes = len(d_stats.shapes)
    t_search = timed(lambda: hqi.search(wl, nprobe=nprobe), warmup=1, iters=2)
    emit(
        "engine/dispatches_global",
        0.0,
        f"{dispatches} dispatches (budget {hqi.cfg.plan.max_bucket_shapes})",
    )
    emit("engine/dispatches_per_pair", 0.0, f"{per_pair} dispatches across {len(tasks)} pairs")
    reduction = per_pair / max(1, gplan.n_dispatches)
    emit("engine/dispatch_reduction", 0.0, f"{reduction:.1f}x fewer dispatches")
    emit("engine/distinct_shapes", 0.0, f"{shapes} compiled shapes")
    emit("engine/search", t_search * 1e6, f"{wl.m} queries, {gplan.n_units} work units")

    # --- compressed execution: ADC scan + exact re-rank vs f32 scan ----------
    # finer subquantizers at d >= 64 (dsub = 4): on the normalized KG vectors
    # M=16 buys ~0.1-0.15 recall@10 over M=8 while still cutting code bytes
    # 16x — the better point on the recall/bytes frontier at bench scale
    d = kg.db.d
    pq_m = 16 if (d >= 64 and d % 16 == 0) else (8 if d % 8 == 0 else 4)
    hqi_pq = HQIIndex.build(
        kg.db,
        wl,
        HQIConfig(
            min_partition_size=max(256, N // 64), max_leaves=64,
            scan_mode="pq", pq_m=pq_m,
        ),
    )
    _pq_vs_f32(hqi_pq, wl, nprobe)

    # --- segmented vs dense candidate-buffer footprint (skewed routing) ------
    _skewed_memory(hqi_pq, wl)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
