"""Engine dispatch accounting: global plan vs per-(template × partition) plans.

Reports, for one HQI workload:
  * engine/dispatches_global   — kernel dispatches the workload-wide plan
                                 issues (≤ PlanConfig.max_bucket_shapes)
  * engine/dispatches_per_pair — what the same work costs when each
                                 (template × partition) product is planned
                                 separately (the pre-engine architecture)
  * engine/distinct_shapes     — distinct compiled problem shapes seen
  * engine/search              — wall time of the engine-backed search

"derived" holds dispatch counts / reduction factors.
"""
from __future__ import annotations

import numpy as np

from repro.core import HQIConfig, HQIIndex
from repro.core.ivf import ScanStats
from repro.core.plan import build_plan
from repro.core.workload import kg_style
from repro.kernels import ops

from .common import FAST, N, D, Q, emit, timed


def main() -> None:
    kg = kg_style(n=min(N, 5000 if FAST else 50_000), d=D, queries_per_split=Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64)
    )
    nprobe = 8

    # --- global plan: one build_plan over every routed product ---------------
    tasks, _ = hqi._engine_tasks(wl, nprobe=nprobe, batch_vec=True, stats=ScanStats())
    gplan = build_plan(
        hqi.arena, tasks, wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan
    )
    # --- pre-engine architecture: one plan per (template × partition) --------
    per_pair = 0
    for t in tasks:
        per_pair += build_plan(
            hqi.arena, [t], wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan
        ).n_dispatches

    # count one explicitly isolated search, then time separately
    ops.reset_dispatch_stats()
    hqi.search(wl, nprobe=nprobe)
    dispatches = ops.dispatch_stats().knn_calls
    shapes = len(ops.dispatch_stats().shapes)
    t_search = timed(lambda: hqi.search(wl, nprobe=nprobe), warmup=1, iters=2)
    emit(
        "engine/dispatches_global",
        0.0,
        f"{dispatches} dispatches (budget {hqi.cfg.plan.max_bucket_shapes})",
    )
    emit("engine/dispatches_per_pair", 0.0, f"{per_pair} dispatches across {len(tasks)} pairs")
    reduction = per_pair / max(1, gplan.n_dispatches)
    emit("engine/dispatch_reduction", 0.0, f"{reduction:.1f}x fewer dispatches")
    emit("engine/distinct_shapes", 0.0, f"{shapes} compiled shapes")
    emit("engine/search", t_search * 1e6, f"{wl.m} queries, {gplan.n_units} work units")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
