"""Kernel-profiler perf baseline: the rows ``check_perf.py`` gates in CI.

Runs the f32 and PQ engine paths under ``obs.profile.KernelProfiler`` on a
FIXED workload (sizes deliberately independent of ``REPRO_BENCH_FAST`` /
the scale env knobs) so the attributed bytes, FLOPs, dispatch counts and
occupancies are machine-independent constants: any drift in them means the
planner's bucketing or the profiler's attribution model changed, and the
exact-match gate in ``check_perf.py`` catches it. Timing rows (``*_us``)
are machine-dependent and gated with a wide tolerance band instead.

Also writes ``PROFILE_perf.json`` (the profiler's full roofline report, a CI
artifact) and runs the flight-recorder incident smoke: a live ``HQIService``
with an armed ``service.flush`` failpoint must produce exactly one
schema-valid incident bundle under ``incidents/`` (also uploaded by CI).

Full-precision values lead each row's "derived" field; ``emit``'s
``us_per_call`` column is rounded to 0.1 and only carries the timings.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import HQIConfig, HQIIndex
from repro.core.workload import kg_style

from .common import emit

# fixed workload: never scaled by FAST/N/D/Q — the exact rows below must be
# bit-identical on every machine and backend for the baseline gate to work
PERF_N = 6000
PERF_D = 16
PERF_Q = 256
PERF_NPROBE = 8
PASSES = 3


def _emit_exact(name: str, value: float, unit: str) -> None:
    # full precision in derived (check_perf parses the leading token);
    # us_per_call's %.1f would destroy occupancy ratios
    emit(name, 0.0, f"{value:.12g} {unit}")


def _profiled_pass(hqi, wl, prof, mode: str):
    """Warmup + PASSES profiled searches; returns (wall_s/pass, scan totals,
    all-phase totals)."""
    hqi.search(wl, nprobe=PERF_NPROBE, batch_vec=True, scan_mode=mode)  # compile
    prof.reset()
    t0 = time.perf_counter()
    for _ in range(PASSES):
        hqi.search(wl, nprobe=PERF_NPROBE, batch_vec=True, scan_mode=mode)
    wall = (time.perf_counter() - t0) / PASSES
    return wall, prof.totals(phase="scan"), prof.totals()


def _emit_mode(tag: str, wall_s: float, scan: dict, total: dict) -> None:
    emit(f"perf/{tag}_us", wall_s * 1e6,
         f"{wall_s * 1e6:.1f} us/pass, {PERF_Q} queries profiled")
    _emit_exact(f"perf/{tag}_bytes", scan["bytes"] / PASSES, "scan bytes/pass")
    _emit_exact(f"perf/{tag}_flops", scan["flops"] / PASSES, "scan FLOPs/pass")
    _emit_exact(f"perf/{tag}_occupancy", scan["row_occupancy"],
                "scan row occupancy (1 - padding waste)")
    _emit_exact(f"perf/{tag}_dispatches", total["dispatches"] / PASSES,
                "attributed dispatches/pass (all phases)")


def _incident_smoke() -> int:
    """Live service + armed ``service.flush`` failpoint → exactly one
    schema-valid incident bundle in ``incidents/``. Returns bundle count."""
    import shutil

    from repro.fault import failpoints
    from repro.obs import trace
    from repro.obs.flight import FlightRecorder, validate_incident_bundle
    from repro.service import HQIService, ServiceConfig

    kg = kg_style(n=1500, d=PERF_D, queries_per_split=32, seed=1)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl, HQIConfig(min_partition_size=128, max_leaves=8)
    )
    svc = HQIService(
        hqi, ServiceConfig(k=wl.k, nprobe=PERF_NPROBE, max_batch=16)
    )
    root = os.path.abspath("incidents")
    shutil.rmtree(root, ignore_errors=True)
    trace.enable(capacity=8192)
    rec = FlightRecorder(svc, root, max_incidents=4)
    try:
        assert rec.observe() is None  # baseline sample
        for i in range(8):
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        failpoints.arm("service.flush", count=1)
        svc.flush()  # crash is contained; telemetry records the failure
        path = rec.observe()
        assert path is not None, "armed flush crash produced no incident"
        validate_incident_bundle(path)
        assert rec.observe() is None, "single crash dumped twice"
        return len(rec.incidents())
    finally:
        svc.stop(drain=False)
        trace.disable()
        failpoints.disarm_all()


def main() -> None:
    from repro.obs.profile import disable_profiler, enable_profiler

    kg = kg_style(n=PERF_N, d=PERF_D, queries_per_split=PERF_Q, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl,
        HQIConfig(min_partition_size=256, max_leaves=32,
                  scan_mode="pq", pq_m=8),
    )

    prof = enable_profiler()
    try:
        wall, scan, total = _profiled_pass(hqi, wl, prof, "f32")
        _emit_mode("f32_scan", wall, scan, total)
        report_f32 = prof.report()

        wall, scan, total = _profiled_pass(hqi, wl, prof, "pq")
        _emit_mode("pq_scan", wall, scan, total)
        rerank = prof.totals(phase="rerank")
        _emit_exact("perf/pq_rerank_flops", rerank.get("flops", 0.0) / PASSES,
                    "re-rank FLOPs/pass")

        report = prof.report()
        report["phases"].update(report_f32["phases"])  # both modes in the dump
        with open("PROFILE_perf.json", "w") as f:
            json.dump(report, f, indent=2)
        cov = report["coverage"]
        _emit_exact("perf/coverage", cov,
                    "profiler dispatch coverage (attributed/issued)")
        assert cov == 1.0, f"unattributed kernel dispatches (coverage {cov})"
    finally:
        disable_profiler()

    n_bundles = _incident_smoke()
    _emit_exact("perf/flight_incident", float(n_bundles),
                "incident bundles from one armed flush crash (must be 1)")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    main()
