"""Table 5: robustness to future queries. HQI indexed from t0 only; QPS

measured on each temporal split t0..t3 vs PreFilter. Filter stability means
the t0-trained layout keeps its advantage on unseen future queries.
"""
from __future__ import annotations

from repro.core import (
    HQIConfig, HQIIndex, PreFilterIndex, exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import kg_style

from .common import D, N, Q, emit, timed


def main():
    kg = kg_style(n=N, d=D, queries_per_split=Q)
    hqi = HQIIndex.build(kg.db, kg.splits[0], HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64))
    pre = PreFilterIndex.build(kg.db)

    truth0 = exhaustive_search(kg.db, kg.splits[0])
    np_hqi = tune_nprobe(lambda w, np_: hqi.search(w, nprobe=np_), kg.splits[0], truth0)
    np_pre = tune_nprobe(lambda w, np_: pre.search(w, nprobe=np_), kg.splits[0], truth0)

    qps0 = None
    for i, split in enumerate(kg.splits):
        truth = exhaustive_search(kg.db, split)
        t_h = timed(lambda: hqi.search(split, nprobe=np_hqi))
        rec_h = recall_at_k(hqi.search(split, nprobe=np_hqi), truth)
        t_p = timed(lambda: pre.search(split, nprobe=np_pre))
        rec_p = recall_at_k(pre.search(split, nprobe=np_pre), truth)
        qps_h, qps_p = split.m / t_h, split.m / t_p
        if qps0 is None:
            qps0 = qps_h
        emit(f"table5.t{i}.hqi", t_h / split.m * 1e6, f"qps_norm={qps_h/qps0:.2f},recall={rec_h:.2f}")
        emit(f"table5.t{i}.prefilter", t_p / split.m * 1e6, f"qps_norm={qps_p/qps0:.3f},recall={rec_p:.2f}")


if __name__ == "__main__":
    main()
