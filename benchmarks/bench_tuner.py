"""Index evolution: drift-triggered rebuild + blue/green swap payoff.

Scenario (the paper's temporal workload shift, Table 1 splits, sharpened):
an index is built and nprobe-frozen for an era of broad analytic traffic
(split-0 queries over T6-T10), then the mix drifts — split-3 traffic over
the *selective* head templates (T1-T5) takes over, exactly the queries a
frozen low-nprobe layout starves. (The raw Table-1 splits are nearly
stationary — total-variation ~0.05, below half-window sampling noise at
smoke scale — so the bench drifts the *category* mix, the regime the tuner
exists for.) The tuner detects the share shift, rebuilds the qd-tree off
to the side over a workload reconstructed from the drifted traffic,
re-tunes per-filter nprobe against a recall target, and hot-swaps the new
generation in. Reports:

  * tuner/pre_recall   — recall@k of the frozen layout on drifted traffic
                         (us_per_call = per-query serving latency)
  * tuner/build        — off-to-the-side rebuild (capture → qd-tree → PQ →
                         retune → persisted generation); serving continues
  * tuner/swap         — the blue/green swap itself (drain + delta rebuild +
                         WAL-tail replay + pointer flip) — the only part
                         that touches the serving path
  * tuner/post_recall  — recall@k after the swap, tuned per-filter nprobe
  * tuner/recall_gain  — post - pre (derived; CI gates > 0 via
                         ``benchmarks/check_tuner.py``)
  * tuner/dropped      — queries dropped or failed across the whole run
                         including the swap (derived; must be exactly 0)

Recall truth is exhaustive search over the same database, so the gain row
isolates what the swap bought: a layout partitioned for the live mix plus
nprobe re-tuned to the target, versus the frozen original.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import numpy as np

from repro.core import HQIConfig, HQIIndex, recall_at_k
from repro.core.baselines import exhaustive_search
from repro.core.types import SearchResult, Workload
from repro.core.workload import kg_style
from repro.service import ServiceConfig
from repro.store import init_store
from repro.tuner import Tuner, TunerConfig

from .common import FAST, N, D, Q, emit

PRE_NPROBE = 2  # deliberately starved: the frozen layout under-probes drifted traffic
TARGET_RECALL = 0.9


def _stream(svc, wl):
    """Stream a workload through the serving path; returns (result, seconds,
    dropped). Never raises on a failed query — the dropped count is a gated
    bench row, not an assert."""
    t0 = time.perf_counter()
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        for i in range(wl.m)
    ]
    svc.drain()
    took = time.perf_counter() - t0
    dropped = sum(0 if h.ok else 1 for h in handles)
    ok = [h for h in handles if h.ok]
    if not ok:
        return None, took, dropped
    res = SearchResult(
        ids=np.stack([h.ids for h in ok]), scores=np.stack([h.scores for h in ok])
    )
    return res, took, dropped


def main() -> None:
    n = min(N, 8_000 if FAST else 40_000)
    q = min(Q, 200 if FAST else 800)
    kg = kg_style(n=n, d=D, queries_per_split=q, seed=0)

    def era(split, mask):
        return Workload(
            vectors=split.vectors[mask],
            templates=list(split.templates),
            template_of=split.template_of[mask],
            k=split.k,
        )

    # phase A: broad templates only; phase B: the selective head takes over
    wl_a = era(kg.splits[0], kg.splits[0].template_of >= 5)
    wl_b = era(kg.splits[3], kg.splits[3].template_of <= 4)
    k = wl_b.k

    hqi = HQIIndex.build(
        kg.db, wl_a, HQIConfig(min_partition_size=max(256, n // 32), max_leaves=64)
    )
    root = tempfile.mkdtemp(prefix="bench_tuner_")
    dropped = 0
    try:
        svc = init_store(
            root,
            hqi,
            cfg=ServiceConfig(k=k, nprobe=PRE_NPROBE, max_batch=64, deadline_s=0.002),
            sync=False,
        )
        tuner = Tuner(
            svc,
            root,
            cfg=TunerConfig(
                share_shift=0.1,
                min_window=64,
                retune_nprobe=True,
                target_recall=TARGET_RECALL,
                max_nprobe=64,
                workload_queries=128,
                sample_per_template=32,
            ),
        )

        truth = exhaustive_search(kg.db, wl_b)
        _, _, d0 = _stream(svc, wl_a)  # split-0 era: establishes the reference mix
        res, took, d1 = _stream(svc, wl_b)  # the drift arrives (also the pre pass)
        dropped += d0 + d1
        pre = recall_at_k(res, truth) if res is not None else 0.0
        emit(
            "tuner/pre_recall",
            took / wl_b.m * 1e6,
            f"{pre:.3f} recall@{k}, frozen layout, nprobe={PRE_NPROBE}",
        )

        rec = tuner.tune_once()
        if rec is None:  # drift below threshold at this scale: swap anyway
            rec = tuner.tune_once(force=True)
        npb = rec.nprobe_by_filter or {}
        avg_np = float(np.mean(list(npb.values()))) if npb else float(PRE_NPROBE)
        emit(
            "tuner/build",
            rec.build_s * 1e6,
            f"{rec.reason}: rebuilt {rec.n_rows} rows off to the side -> {rec.generation}",
        )
        emit(
            "tuner/swap",
            rec.swap_s * 1e6,
            f"blue/green flip, wal tail replayed={rec.replayed}",
        )

        res, took, d2 = _stream(svc, wl_b)
        dropped += d2
        post = recall_at_k(res, truth) if res is not None else 0.0
        emit(
            "tuner/post_recall",
            took / wl_b.m * 1e6,
            f"{post:.3f} recall@{k}, evolved layout, avg nprobe {avg_np:.1f}"
            f" (target {TARGET_RECALL:.2f})",
        )
        emit("tuner/recall_gain", 0.0, f"{post - pre:+.3f} post-swap vs frozen")
        emit("tuner/dropped", float(dropped), f"{dropped} dropped queries (must be 0)")
        if svc.wal is not None:
            svc.wal.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()
