"""Figure 7: batching microbenchmarks.

7a — batch size sweep: HQI (with/without vector batching) vs PreFilter on a
     mid-selectivity template; shows the crossover the paper discusses.
7b — runtime vs recall (nprobe sweep) on attribute-free vectors: vector-
     similarity batching vs per-query IVF.
7c — attribute-constraint batching vs selectivity: batched bitmaps vs
     one-at-a-time filter evaluation (the ~orders-of-magnitude gap).
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    HQIConfig, HQIIndex, PreFilterIndex, exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.ivf import IVFIndex
from repro.core.planner import batch_search_ivf
from repro.core.types import Workload
from repro.core.workload import kg_style, synthetic_bigann_style

from .common import D, FAST, N, Q, emit, timed


def fig7a():
    kg = kg_style(n=N, d=D, queries_per_split=max(Q, 512))
    db, wl = kg.db, kg.splits[0]
    truth = exhaustive_search(db, wl)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=max(256, N // 64), max_leaves=64))
    pre = PreFilterIndex.build(db)
    ti = 3  # T4: mid selectivity (the paper's pick)
    qidx = wl.queries_for_template(ti)
    np_h = tune_nprobe(lambda w, np_: hqi.search(w, nprobe=np_), wl, truth)[ti]
    np_p = tune_nprobe(lambda w, np_: pre.search(w, nprobe=np_), wl, truth)[ti]
    base = None
    for bs in (1, 10, 100, 1000):
        if bs > len(qidx):
            break
        sub = wl.subset(qidx[:bs])
        t_bv = timed(lambda: hqi.search(sub, nprobe={0: np_h}))
        t_nv = timed(lambda: hqi.search(sub, nprobe={0: np_h}, batch_vec=False))
        t_pre = timed(lambda: pre.search(sub, nprobe={0: np_p}))
        if base is None:
            base = t_nv
        emit(f"fig7a.bs{bs}.hqi_vecbatch", t_bv * 1e6, f"norm={t_bv/base:.2f}")
        emit(f"fig7a.bs{bs}.hqi_novecbatch", t_nv * 1e6, f"norm={t_nv/base:.2f}")
        emit(f"fig7a.bs{bs}.prefilter", t_pre * 1e6, f"norm={t_pre/base:.2f}")


def fig7b():
    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(N, D)).astype(np.float32)
    ivf = IVFIndex.build(vecs, metric="l2")
    m = max(100, Q // 4)
    q = rng.normal(size=(m, D)).astype(np.float32)
    # ground truth
    ip = q @ vecs.T
    sc = 2 * ip - (vecs**2).sum(1)[None, :] - (q**2).sum(1)[:, None]
    truth_ids = np.argsort(-sc, axis=1)[:, :10]
    for nprobe in (1, 2, 4, 8, 16, 32):
        bs, bi = batch_search_ivf(ivf, q, nprobe=nprobe, k=10)
        rec = np.mean([
            len(set(bi[i].tolist()) & set(truth_ids[i].tolist())) / 10 for i in range(m)
        ])
        t_b = timed(lambda: batch_search_ivf(ivf, q, nprobe=nprobe, k=10))
        t_s = timed(lambda: [ivf.search_single(q[i], nprobe=nprobe, k=10) for i in range(m)])
        emit(f"fig7b.nprobe{nprobe}.vecbatch", t_b / m * 1e6, f"recall={rec:.2f}")
        emit(f"fig7b.nprobe{nprobe}.perquery", t_s / m * 1e6,
             f"recall={rec:.2f},slowdown={t_s/t_b:.1f}x")


def fig7c():
    db, wl, sel = synthetic_bigann_style(n=N, d=D, n_query_vecs=max(10, Q // 20), seed=2)
    pre = PreFilterIndex.build(db)
    for ti in (0, 3, 6, 9):  # selectivities 1, 2^-3, 2^-6, 2^-9
        qidx = wl.queries_for_template(ti)[: 50 if FAST else 200]
        sub = wl.subset(qidx)
        t_batched = timed(lambda: pre.search(sub, nprobe=8, batch_attr=True))
        t_one = timed(lambda: pre.search(sub, nprobe=8, batch_attr=False))
        t_vec = timed(lambda: pre.search(sub, nprobe=8, batch_attr=True, batch_vec=True))
        emit(f"fig7c.sel{sel[ti]:.4f}.attr_batched", t_batched / sub.m * 1e6, "")
        emit(f"fig7c.sel{sel[ti]:.4f}.one_at_a_time", t_one / sub.m * 1e6,
             f"slowdown={t_one/t_batched:.1f}x")
        emit(f"fig7c.sel{sel[ti]:.4f}.attr_plus_vec", t_vec / sub.m * 1e6,
             f"vs_one={t_one/t_vec:.1f}x")


def main():
    fig7a()
    fig7b()
    fig7c()


if __name__ == "__main__":
    main()
