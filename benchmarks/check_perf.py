"""CI perf-baseline gate for the kernel profiler's numbers.

    PYTHONPATH=src python -m benchmarks.check_perf [BENCH_perf.json]
    PYTHONPATH=src python -m benchmarks.check_perf --write-baseline
    PYTHONPATH=src python -m benchmarks.check_perf --degraded-selftest

Compares the ``perf`` suite's rows (``benchmarks/bench_perf.py``) against the
committed per-backend baseline ``benchmarks/baselines/BENCH_perf_baseline.json``
— the repo's durable perf record. Two row kinds:

  * **exact** — attributed bytes / FLOPs / dispatch counts / occupancies on
    the fixed perf workload. Machine-independent by construction; gated at
    rtol 1e-6. A mismatch means the planner's bucketing or the profiler's
    attribution model changed — if intentional, re-record with
    ``--write-baseline`` and commit the diff (the diff IS the review
    artifact).
  * **timing** — ``*_us`` wall-clock rows. Gated as a ratio against the
    recorded baseline with a wide band (``REPRO_PERF_TOLERANCE``, default
    3.0x: shared CI runners are noisy; the gate is for order-of-magnitude
    regressions, not percent drift). Bump the env in the workflow rather
    than deleting the gate.

Baselines are keyed per backend (``jnp`` vs ``pallas-interpret``, from the
bench env's ``use_pallas``); an unrecorded backend skips with a warning so a
new backend can land before its baseline does. ``--degraded-selftest``
proves the gate is live: it gates the current rows against a synthetically
degraded baseline and exits 0 only if that comparison FAILS.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List

BASELINE_SCHEMA = "hqi-perf-baseline-v1"
BASELINE_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "baselines", "BENCH_perf_baseline.json"
)
EXACT_RTOL = 1e-6
DEFAULT_TOLERANCE = 3.0


def _row_value(row: dict) -> float:
    """Full-precision value: leading token of "derived" (bench_perf writes
    ``{value:.12g} unit...``), falling back to the rounded us_per_call."""
    try:
        return float(row["derived"].split(None, 1)[0])
    except (ValueError, IndexError, KeyError):
        return float(row["us_per_call"])


def _row_kind(name: str) -> str:
    return "timing" if name.endswith("_us") else "exact"


def load_rows(bench_path: str) -> Dict[str, Dict[str, object]]:
    with open(bench_path) as f:
        bench = json.load(f)
    backend = "pallas-interpret" if bench["env"].get("use_pallas") == "1" else "jnp"
    rows = {
        r["name"]: {"value": _row_value(r), "kind": _row_kind(r["name"])}
        for r in bench["rows"]
    }
    return {"backend": backend, "rows": rows}


def gate(current: dict, baseline: dict, tolerance: float) -> List[str]:
    """Compare one backend's current rows against its baseline rows."""
    errors: List[str] = []
    for name, base in sorted(baseline.items()):
        cur = current.get(name)
        if cur is None:
            errors.append(f"row {name} in baseline but missing from bench output")
            continue
        bv, cv = float(base["value"]), float(cur["value"])
        if base.get("kind", _row_kind(name)) == "exact":
            denom = max(abs(bv), 1e-30)
            if abs(cv - bv) / denom > EXACT_RTOL:
                errors.append(
                    f"{name}: exact value drifted {bv:.12g} -> {cv:.12g} "
                    f"(attribution/bucketing change? re-record with "
                    f"--write-baseline if intentional)"
                )
        else:
            if bv > 0 and cv > bv * tolerance:
                errors.append(
                    f"{name}: {cv:.1f}us exceeds baseline {bv:.1f}us "
                    f"x{tolerance:.1f} tolerance ({cv / bv:.2f}x)"
                )
    for name in sorted(set(current) - set(baseline)):
        print(f"note: new row {name} not in baseline (re-record to start gating it)")
    return errors


def write_baseline(bench_path: str) -> str:
    cur = load_rows(bench_path)
    doc = {"schema": BASELINE_SCHEMA, "recorded": "", "bench": {}, "backends": {}}
    if os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH) as f:
            doc = json.load(f)
    with open(bench_path) as f:
        env = json.load(f)["env"]
    doc["schema"] = BASELINE_SCHEMA
    doc["recorded"] = time.strftime("%Y-%m-%d")
    doc["bench"] = {"python": env.get("python", "")}
    doc["backends"][cur["backend"]] = {"rows": cur["rows"]}
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    with open(BASELINE_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"recorded {len(cur['rows'])} rows for backend {cur['backend']!r} "
          f"-> {BASELINE_PATH}")
    return BASELINE_PATH


def degraded_selftest(bench_path: str, tolerance: float) -> int:
    """Exit 0 iff the gate FAILS against a synthetically degraded baseline —
    proves in CI that the comparison is live, not vacuously green."""
    cur = load_rows(bench_path)
    degraded: Dict[str, Dict[str, object]] = {}
    for name, row in cur["rows"].items():
        v = float(row["value"])
        if row["kind"] == "timing":
            # pretend the recorded machine was far faster: current wall time
            # must now exceed baseline * tolerance
            degraded[name] = {"value": v / (tolerance * 10.0), "kind": "timing"}
        else:
            degraded[name] = {"value": v, "kind": "exact"}
    # and one attribution drift: perturb a single exact row past rtol
    for name, row in degraded.items():
        if row["kind"] == "exact" and float(row["value"]) != 0.0:
            row["value"] = float(row["value"]) * (1.0 + 1e-3)
            break
    errors = gate(cur["rows"], degraded, tolerance)
    if not errors:
        print("FAIL: degraded baseline passed the gate — gate is dead",
              file=sys.stderr)
        return 1
    print(f"selftest OK: degraded baseline correctly rejected "
          f"({len(errors)} violations, e.g. {errors[0]!r})")
    return 0


def main() -> int:
    argv = [a for a in sys.argv[1:]]
    tolerance = float(os.environ.get("REPRO_PERF_TOLERANCE", str(DEFAULT_TOLERANCE)))
    paths = [a for a in argv if not a.startswith("--")]
    bench_path = paths[0] if paths else "BENCH_perf.json"

    if "--write-baseline" in argv:
        write_baseline(bench_path)
        return 0
    if "--degraded-selftest" in argv:
        return degraded_selftest(bench_path, tolerance)

    cur = load_rows(bench_path)
    if not os.path.exists(BASELINE_PATH):
        print(f"FAIL: no baseline at {BASELINE_PATH} "
              f"(run --write-baseline and commit it)", file=sys.stderr)
        return 1
    with open(BASELINE_PATH) as f:
        doc = json.load(f)
    if doc.get("schema") != BASELINE_SCHEMA:
        print(f"FAIL: baseline schema {doc.get('schema')!r} != {BASELINE_SCHEMA!r}",
              file=sys.stderr)
        return 1
    backend = doc["backends"].get(cur["backend"])
    if backend is None:
        print(f"warning: no baseline recorded for backend {cur['backend']!r} "
              f"({sorted(doc['backends'])} recorded) — skipping gate")
        return 0
    errors = gate(cur["rows"], backend["rows"], tolerance)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    if not errors:
        n_exact = sum(1 for r in backend["rows"].values() if r.get("kind") == "exact")
        print(f"perf baseline OK: {len(backend['rows'])} rows "
              f"({n_exact} exact, tolerance {tolerance:.1f}x, "
              f"backend {cur['backend']})")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
