"""CI guard for index evolution (rides the bench-smoke job).

    PYTHONPATH=src python -m benchmarks.check_tuner [BENCH_tuner.json]

Fails the build when
  * any query was dropped or failed across the drift → rebuild → blue/green
    swap run (``tuner/dropped`` must read exactly 0 — the zero-downtime
    contract), or
  * the swap did not pay for itself: post-swap recall@k on the drifted
    traffic must exceed the frozen layout's by more than
    ``REPRO_TUNER_MIN_GAIN`` (default 0.0 — strictly better). The pre pass
    is deliberately nprobe-starved on a layout partitioned for the old mix,
    so a working rebuild + per-filter retune clears this by a wide margin;
    a regression in drift reconstruction, the retune ladder, or the
    per-filter nprobe plumbing lands the gain at or below zero.

Both rows come from seeded, single-process runs — the recall figures are
deterministic for a given scale, so the gate does not flake with machine
load the way a QPS floor would.
"""
from __future__ import annotations

import json
import os
import sys


def check(bench_path: str, min_gain: float) -> list:
    errors = []
    with open(bench_path) as f:
        bench = json.load(f)
    rows = {r["name"]: r for r in bench.get("rows", [])}

    row = rows.get("tuner/dropped")
    if row is None:
        errors.append(f"{bench_path}: no tuner/dropped row")
    elif float(row["us_per_call"]) != 0.0:
        errors.append(f"zero-downtime violated: {row['derived']}")
    else:
        print("dropped queries across swap: 0  OK")

    def recall_of(name):
        r = rows.get(name)
        if r is None:
            errors.append(f"{bench_path}: no {name} row")
            return None
        try:
            return float(r["derived"].split(" ", 1)[0])
        except (ValueError, IndexError):
            errors.append(f"{name}: unparseable derived {r['derived']!r}")
            return None

    pre, post = recall_of("tuner/pre_recall"), recall_of("tuner/post_recall")
    if pre is not None and post is not None:
        gain = post - pre
        if gain <= min_gain:
            errors.append(
                f"swap did not improve recall: pre={pre:.3f} post={post:.3f}"
                f" gain={gain:+.3f} <= gate {min_gain:+.3f}"
            )
        else:
            print(f"recall gain {gain:+.3f} (pre {pre:.3f} -> post {post:.3f})  OK")
    return errors


def main() -> int:
    bench_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_tuner.json"
    min_gain = float(os.environ.get("REPRO_TUNER_MIN_GAIN", "0.0"))
    errors = check(bench_path, min_gain)
    for e in errors:
        print(f"FAIL: {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
