"""Predicate evaluation, implication soundness, disjointness soundness."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.predicates import (
    Between, CentroidIn, Cmp, Contains, In, NotNull, evaluate_filter, make_filter,
)
from repro.core.qdtree import predicates_disjoint

from conftest import small_db

DB = small_db(n=1500, seed=3)


def _eval(p):
    cent = np.arange(DB.n, dtype=np.int32) % 7
    return p.evaluate(DB, cent)


num_pred = st.one_of(
    st.tuples(st.sampled_from(["A", "B"]), st.sampled_from(["<", "<=", ">", ">=", "=="]),
              st.floats(0, 1, allow_nan=False, width=32)).map(lambda t: Cmp(*t)),
    st.tuples(st.sampled_from(["A", "B"]),
              st.floats(0, 1, allow_nan=False, width=32),
              st.floats(0, 1, allow_nan=False, width=32)).map(
        lambda t: Between(t[0], min(t[1], t[2]), max(t[1], t[2]))),
)
any_pred = st.one_of(
    num_pred,
    st.builds(In, st.just("cat"), st.frozensets(st.integers(0, 7), min_size=1, max_size=4)),
    st.builds(Contains, st.just("tags"), st.integers(0, 5)),
    st.builds(NotNull, st.sampled_from(["A", "B", "cat", "tags"])),
    st.builds(CentroidIn, st.frozensets(st.integers(0, 6), min_size=1, max_size=3)),
)


@settings(max_examples=150, deadline=None)
@given(any_pred, any_pred)
def test_implication_soundness(p, q):
    """p.implies(q) must mean eval(p) ⊆ eval(q) — routing correctness rests

    on this."""
    if p.implies(q):
        ep, eq = _eval(p), _eval(q)
        assert not (ep & ~eq).any(), f"{p} claims to imply {q} but does not"


@settings(max_examples=150, deadline=None)
@given(any_pred, any_pred)
def test_disjointness_soundness(p, q):
    if predicates_disjoint(p, q):
        assert not (_eval(p) & _eval(q)).any(), f"{p} and {q} claimed disjoint"


def test_filter_conjunction():
    f = make_filter(Between("A", 0.0, 0.5), NotNull("B"))
    m = evaluate_filter(f, DB)
    a = DB.columns["A"].values
    assert (m == ((a >= 0) & (a < 0.5) & ~DB.columns["B"].null_mask)).all()


def test_empty_filter_matches_all():
    assert evaluate_filter((), DB).all()


def test_setcat_contains():
    m = Contains("tags", 3).evaluate(DB)
    assert (m == DB.columns["tags"].values[:, 3]).all()


def test_nulls_fail_comparisons():
    m = Cmp("B", ">=", 0.0).evaluate(DB)
    assert not (m & DB.columns["B"].null_mask).any()
