"""Index-evolution tuner: drift-triggered rebuild + blue/green hot swap.

The load-bearing guarantees under test:

  * a mid-stream template shift trips the tuner, which rebuilds the layout
    off to the side and swaps it in with ZERO dropped queries — post-swap
    answers still exactly equal an unswapped reference in exhaustive mode,
    because global ids are row positions and the rebuild covers the full
    captured row space (dead rows included, nothing renumbers);
  * writes acknowledged between capture and swap survive: the WAL tail past
    the build's covered seq replays into the fresh delta with bit-exact id
    continuity (and crash recovery from the promoted generation reproduces
    the same state);
  * a faulted build or swap (``tuner.build`` / ``tuner.swap`` failpoints)
    leaves the old index serving untouched and ``CURRENT`` unflipped;
  * ``rollback()`` restores the displaced layout without losing writes
    acknowledged after the forward swap.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex
from repro.core.workload import reconstruct_workload
from repro.fault import failpoints
from repro.obs.drift import DriftReport
from repro.service import HQIService, ServiceConfig
from repro.store import init_store, list_generations, open_service
from repro.store.snapshot import current_generation, pinned_generations
from repro.tuner import Tuner, TunerConfig

from conftest import assert_same_results, small_db, small_workload

EXACT = 10_000  # nprobe past every list count: search becomes exact


def _build_index(db, wl):
    return HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))


def _service(db, wl, **cfg_kw):
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return HQIService(_build_index(db, wl), ServiceConfig(**kw))


def _stream(svc, wl, rows=None):
    rows = range(wl.m) if rows is None else rows
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]]) for i in rows
    ]
    svc.drain()
    assert all(h.ok for h in handles)  # zero dropped / failed queries
    return np.stack([h.ids for h in handles]), np.stack([h.scores for h in handles])


def _report(**over):
    base = dict(
        n_window=500,
        window_span_s=10.0,
        template_shares={},
        reference_shares={},
        share_shift=0.0,
        part_heat={},
        delta_rows=0,
        delta_growth_per_s=0.0,
    )
    base.update(over)
    return DriftReport(**base)


# ---------------------------------------------------------------------------
# end-to-end: shift → trigger → rebuild → swap, zero drops, exact parity
# ---------------------------------------------------------------------------


def test_shift_triggers_swap_with_exact_parity_and_zero_drops():
    db = small_db(n=1500, seed=5)
    wl = small_workload(db, n_queries=48)
    svc = _service(db, wl)
    ref = _service(db, wl)  # never swapped — the parity reference
    tuner = Tuner(
        svc, cfg=TunerConfig(min_window=32, share_shift=0.5, retune_nprobe=False)
    )
    assert tuner.tune_once() is None  # stationary start: no trigger

    rows_a = np.where(wl.template_of <= 2)[0]
    rows_b = np.where(wl.template_of >= 3)[0]
    _stream(svc, wl, np.repeat(rows_a, 2))  # phase A traffic
    _stream(svc, wl, np.repeat(rows_b, 2))  # phase B: near-disjoint mix
    rec = tuner.tune_once()
    assert rec is not None and rec.reason == "share-shift"
    assert rec.n_rows == db.n and rec.swap_s >= 0.0

    # the swap is visible in health + telemetry, and the drift window was
    # reset so the tuner doesn't immediately re-trigger on its own rebuild
    assert svc.health().index_swaps == 1
    assert svc.telemetry.summary()["index_swaps"] == 1.0
    assert svc.drift_report().n_window == 0
    assert tuner.tune_once() is None

    # exhaustive-mode answers on the new layout == the unswapped reference
    s_ids, s_scores = _stream(svc, wl)
    r_ids, r_scores = _stream(ref, wl)
    assert_same_results(s_scores, s_ids, r_scores, r_ids)


def test_swap_preserves_inflight_queued_queries():
    """Queries queued (not yet flushed) across the swap are answered on the
    new index — none dropped, answers still exact."""
    db = small_db(n=900, seed=2)
    wl = small_workload(db, n_queries=24)
    svc = _service(db, wl, max_batch=1000, deadline_s=1000.0)  # nothing auto-flushes
    ref = _service(db, wl)
    tuner = Tuner(svc, cfg=TunerConfig(retune_nprobe=False))
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]]) for i in range(wl.m)
    ]
    assert not any(h.done for h in handles)  # still queued
    rec = tuner.tune_once(force=True)
    assert rec is not None
    svc.drain()
    assert all(h.ok for h in handles)
    s_ids = np.stack([h.ids for h in handles])
    s_scores = np.stack([h.scores for h in handles])
    r_ids, r_scores = _stream(ref, wl)
    assert_same_results(s_scores, s_ids, r_scores, r_ids)


# ---------------------------------------------------------------------------
# WAL-seq continuity: acked writes between capture and swap replay bit-exact
# ---------------------------------------------------------------------------


def test_scripted_swap_wal_continuity_bit_identical(tmp_path):
    db = small_db(n=900, seed=3)
    wl = small_workload(db, n_queries=24)
    rng = np.random.default_rng(9)
    svc = init_store(
        str(tmp_path), _build_index(db, wl),
        cfg=ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0),
    )
    ref = _service(db, wl)  # same writes, never swapped
    tuner = Tuner(svc, str(tmp_path), cfg=TunerConfig(retune_nprobe=False))

    v1 = rng.normal(size=(5, db.d)).astype(np.float32)
    ids1 = svc.insert(v1)
    np.testing.assert_array_equal(ids1, ref.insert(v1))
    built = tuner._build("forced")  # capture includes ids1
    assert built.covered_seq == svc._applied_seq
    assert built.index.db.n == db.n + 5  # dead rows included, ids preserved

    # acked writes AFTER capture, BEFORE swap — the tail the swap must replay
    v2 = rng.normal(size=(4, db.d)).astype(np.float32)
    ids2 = svc.insert(v2)
    np.testing.assert_array_equal(ids2, ref.insert(v2))
    dels = [int(ids1[0]), 7]
    assert svc.delete(dels) == ref.delete(dels) == 2

    rec = tuner._swap(built)
    assert rec.replayed == 2  # one insert record + one delete record
    assert svc._wal_folded_seq == built.covered_seq  # seq continuity
    np.testing.assert_array_equal(np.sort(svc.live_ids()), np.sort(ref.live_ids()))

    # id continuity for NEW writes across the swap boundary
    v3 = rng.normal(size=(2, db.d)).astype(np.float32)
    np.testing.assert_array_equal(svc.insert(v3), ref.insert(v3))

    # answers bit-identical to the unswapped reference (exhaustive mode)
    s_ids, s_scores = _stream(svc, wl)
    r_ids, r_scores = _stream(ref, wl)
    assert_same_results(s_scores, s_ids, r_scores, r_ids)

    # crash recovery from the promoted generation reproduces the same state
    svc2 = open_service(
        str(tmp_path),
        cfg=ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0),
    )
    np.testing.assert_array_equal(np.sort(svc2.live_ids()), np.sort(ref.live_ids()))
    s2_ids, s2_scores = _stream(svc2, wl)
    assert_same_results(s2_scores, s2_ids, r_scores, r_ids)


def test_swap_under_concurrent_inserts_loses_no_acked_write(tmp_path):
    db = small_db(n=700, seed=4)
    wl = small_workload(db, n_queries=12)
    svc = init_store(
        str(tmp_path), _build_index(db, wl),
        cfg=ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0),
    )
    tuner = Tuner(svc, str(tmp_path), cfg=TunerConfig(retune_nprobe=False))
    rng = np.random.default_rng(11)
    acked, stop = [], threading.Event()

    def writer():
        while not stop.is_set():
            ids = svc.insert(rng.normal(size=(1, db.d)).astype(np.float32))
            acked.extend(int(i) for i in ids)

    t = threading.Thread(target=writer)
    t.start()
    try:
        while len(acked) < 5:
            time.sleep(0.001)
        rec = tuner.tune_once(force=True)
    finally:
        stop.set()
        t.join()
    assert rec is not None
    live = set(int(i) for i in svc.live_ids())
    assert set(acked) <= live  # every acknowledged insert survived the swap
    assert len(acked) == len(set(acked))  # and no id was handed out twice
    _stream(svc, wl)  # still serving, zero drops
    # recovery agrees
    svc2 = open_service(str(tmp_path))
    assert set(acked) <= set(int(i) for i in svc2.live_ids())


# ---------------------------------------------------------------------------
# fault containment: a faulted build/swap leaves the old index serving
# ---------------------------------------------------------------------------


def test_build_failpoint_leaves_old_index_serving():
    db = small_db(n=700, seed=6)
    wl = small_workload(db, n_queries=12)
    svc = _service(db, wl)
    old_index = svc.index
    tuner = Tuner(svc, cfg=TunerConfig(retune_nprobe=False))
    with failpoints.armed("tuner.build", "runtimeerror"):
        with pytest.raises(RuntimeError):
            tuner.tune_once(force=True)
    assert svc.index is old_index  # nothing mutated
    assert svc.health().index_swaps == 0
    assert tuner.consecutive_failures == 1
    assert svc.health().tuner_failures == 1
    assert "RuntimeError" in svc.health().tuner_error
    _stream(svc, wl)  # still serving
    # the fault was transient: the next cycle succeeds and health heals
    assert tuner.tune_once(force=True) is not None
    assert tuner.consecutive_failures == 0 and tuner.last_error is None


def test_swap_failpoint_leaves_current_unflipped_then_retry_succeeds(tmp_path):
    db = small_db(n=700, seed=7)
    wl = small_workload(db, n_queries=12)
    svc = init_store(
        str(tmp_path), _build_index(db, wl),
        cfg=ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0),
    )
    old_index = svc.index
    tuner = Tuner(svc, str(tmp_path), cfg=TunerConfig(retune_nprobe=False))
    ids = svc.insert(np.random.default_rng(0).normal(size=(3, db.d)).astype(np.float32))
    with failpoints.armed("tuner.swap", "oserror", count=1):
        with pytest.raises(OSError):
            tuner.tune_once(force=True)
    # old index serving, blue/green candidate written but NOT promoted — a
    # restart here loads the layout that matches what is actually serving
    assert svc.index is old_index
    assert current_generation(str(tmp_path)) == "gen-000001"
    assert len(list_generations(str(tmp_path))) == 2  # candidate parked on disk
    assert set(int(i) for i in ids) <= set(int(i) for i in svc.live_ids())
    _stream(svc, wl)
    rec = tuner.tune_once(force=True)  # failpoint exhausted: retry lands
    assert rec is not None
    assert current_generation(str(tmp_path)) == rec.generation
    assert pinned_generations(str(tmp_path)) == {"gen-000001"}
    assert svc.health().index_swaps == 1


def test_rollback_preserves_post_swap_writes(tmp_path):
    db = small_db(n=700, seed=8)
    wl = small_workload(db, n_queries=12)
    svc = init_store(
        str(tmp_path), _build_index(db, wl),
        cfg=ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0),
    )
    tuner = Tuner(svc, str(tmp_path), cfg=TunerConfig(retune_nprobe=False))
    with pytest.raises(RuntimeError):
        tuner.rollback()  # nothing swapped yet
    rec = tuner.tune_once(force=True)
    assert current_generation(str(tmp_path)) == rec.generation
    ids = svc.insert(np.random.default_rng(1).normal(size=(4, db.d)).astype(np.float32))
    before = set(int(i) for i in svc.live_ids())
    tuner.rollback()
    # writes acked after the forward swap replay onto the displaced layout
    assert set(int(i) for i in svc.live_ids()) == before
    assert set(int(i) for i in ids) <= before
    assert current_generation(str(tmp_path)) == "gen-000001"
    assert pinned_generations(str(tmp_path)) == set()
    assert svc.wal.pin_seq is None and svc._nprobe_by_filter is None
    _stream(svc, wl)
    assert svc.health().index_swaps == 2  # rollback is itself a swap


# ---------------------------------------------------------------------------
# triggers, nprobe retune install, workload reconstruction
# ---------------------------------------------------------------------------


def test_should_rebuild_thresholds_and_cooldown():
    tuner = Tuner.__new__(Tuner)  # should_rebuild only reads cfg + cooldown
    tuner.cfg = TunerConfig(
        share_shift=0.3, recall_floor=0.7, delta_growth_per_s=100.0,
        min_window=64, min_interval_s=1000.0,
    )
    tuner._last_swap_t = None
    assert tuner.should_rebuild(_report(n_window=10, share_shift=0.9)) is None
    assert tuner.should_rebuild(_report(share_shift=0.31)) == "share-shift"
    assert tuner.should_rebuild(_report(recall_at_k=0.5)) == "recall-sag"
    assert tuner.should_rebuild(_report(delta_growth_per_s=150.0)) == "delta-growth"
    assert tuner.should_rebuild(_report(recall_at_k=0.9)) is None
    tuner._last_swap_t = time.monotonic()  # inside the cooldown
    assert tuner.should_rebuild(_report(share_shift=0.9)) is None


def test_retune_installs_filter_keyed_nprobe():
    db = small_db(n=700, seed=10)
    wl = small_workload(db, n_queries=30)
    svc = _service(db, wl, nprobe=2)
    ref = _service(db, wl)  # exhaustive reference
    _stream(svc, wl)  # two passes: the reconstruction reads the RECENT half
    _stream(svc, wl)  # of the window, which must carry every template
    tuner = Tuner(
        svc,
        cfg=TunerConfig(
            retune_nprobe=True, target_recall=1.0, max_nprobe=EXACT,
            workload_queries=64, sample_per_template=8,
        ),
    )
    rec = tuner.tune_once(force=True)
    assert rec.nprobe_by_filter is not None
    # overrides are keyed by the actual filter tuples the traffic carried
    assert set(rec.nprobe_by_filter) == set(wl.templates)
    assert svc._nprobe_by_filter == rec.nprobe_by_filter
    # at target_recall=1.0 with an exhaustive cap, the tuned service answers
    # exactly — the per-flush translation in _answer is what applies them
    s_ids, s_scores = _stream(svc, wl)
    r_ids, r_scores = _stream(ref, wl)
    assert_same_results(s_scores, s_ids, r_scores, r_ids)
    svc.set_nprobe_by_filter(None)
    assert svc._nprobe_by_filter is None


def test_reconstruct_workload_shares_vectors_determinism():
    fa, fb = (("A", 1),), (("B", 2),)
    traffic = [(0.0, fa)] * 6 + [(0.0, fb)] * 2
    vec = np.full(4, 7.0, np.float32)
    samples = [(vec, fa, np.array([1]))]
    fallback = np.zeros((10, 4), np.float32)
    wl = reconstruct_workload(traffic, samples, fallback_vectors=fallback, n_queries=8)
    assert wl is not None and set(wl.templates) == {fa, fb}
    counts = {wl.templates[t]: int((wl.template_of == t).sum()) for t in range(2)}
    assert counts[fa] == 6 and counts[fb] == 2  # observed shares preserved
    # fa queries use the reservoir's REAL query vector; fb falls back
    np.testing.assert_array_equal(
        wl.vectors[wl.template_of == wl.templates.index(fa)], np.tile(vec, (6, 1))
    )
    wl2 = reconstruct_workload(traffic, samples, fallback_vectors=fallback, n_queries=8)
    np.testing.assert_array_equal(wl.vectors, wl2.vectors)  # deterministic
    assert wl.templates == wl2.templates
    # every observed template keeps >= 1 query however rare
    rare = [(0.0, fa)] * 99 + [(0.0, fb)]
    wl3 = reconstruct_workload(rare, (), fallback_vectors=fallback, n_queries=10)
    assert (wl3.template_of == wl3.templates.index(fb)).sum() == 1
    assert reconstruct_workload([], (), fallback_vectors=fallback) is None
