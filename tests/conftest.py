import numpy as np
import pytest

from repro.core.predicates import Between, Cmp, Contains, In, NotNull, make_filter
from repro.core.types import Column, VectorDatabase, Workload


def assert_same_results(a_s, a_i, b_s, b_i):
    """Scores allclose (with -inf normalized) and per-row candidate-set
    equality modulo exact-tie ordering — the engine-parity assertion shared
    by the engine/service/pq suites."""
    np.testing.assert_allclose(
        np.where(np.isfinite(a_s), a_s, -1e30),
        np.where(np.isfinite(b_s), b_s, -1e30),
        rtol=1e-4,
        atol=1e-4,
    )
    for r in range(a_i.shape[0]):
        assert set(a_i[r][a_i[r] >= 0].tolist()) == set(b_i[r][b_i[r] >= 0].tolist()), r


def small_db(n=2000, d=16, seed=0, metric="l2"):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    a = rng.random(n).astype(np.float32)
    b = rng.random(n).astype(np.float32)
    cat = rng.integers(0, 8, n).astype(np.int32)
    null = rng.random(n) < 0.3
    member = rng.random((n, 6)) < 0.25
    member[np.arange(n), rng.integers(0, 6, n)] = True
    return VectorDatabase(
        vectors=vecs,
        columns={
            "A": Column.numeric("A", a),
            "B": Column.numeric("B", b, null_mask=null),
            "cat": Column.categorical("cat", cat),
            "tags": Column.setcat("tags", member),
        },
        metric=metric,
    )


def small_workload(db, n_queries=60, seed=1, k=5):
    rng = np.random.default_rng(seed)
    templates = [
        make_filter(Between("A", 0.0, 0.1)),
        make_filter(Between("A", 0.0, 0.5), NotNull("B")),
        make_filter(Contains("tags", 2)),
        make_filter(In("cat", frozenset({0, 1})), Between("B", 0.2, 0.9)),
        make_filter(NotNull("B")),
        make_filter(),  # pure vector search
    ]
    t_of = rng.integers(0, len(templates), n_queries).astype(np.int32)
    qv = rng.normal(size=(n_queries, db.d)).astype(np.float32)
    return Workload(vectors=qv, templates=templates, template_of=t_of, k=k)


@pytest.fixture(scope="session")
def db():
    return small_db()


@pytest.fixture(scope="session")
def workload(db):
    return small_workload(db)
