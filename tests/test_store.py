"""Persistence & recovery: snapshot round-trip parity, WAL crash recovery,
compaction, and delta-store compression.

The load-bearing guarantees:

  * a saved-then-loaded index answers **bit-identically** (ids AND scores)
    to the in-memory original, across metrics, scan modes, and mesh on/off;
  * after a crash, ``open_service`` recovers every ACKNOWLEDGED insert and
    delete (committed to the WAL before the ack) with the same external ids,
    and cleanly drops the unacknowledged torn tail;
  * compaction folds + re-snapshots without changing any answer, and prunes
    generations/WAL segments no recovery path needs;
  * once the live delta outgrows ``ServiceConfig.delta_pq_threshold`` (and
    the index has a codebook), flush scans run compressed (ADC + exact
    re-rank) — under the threshold they stay exact f32.
"""
import os

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex, PackedArena, train_pq
from repro.core.types import Workload
from repro.kernels import ops as kops
from repro.service import HQIService, ServiceConfig
from repro.store import (
    Compactor,
    WriteAheadLog,
    init_store,
    list_generations,
    load_snapshot,
    open_service,
    pin_generation,
    pinned_generations,
    prune_generations,
    save_snapshot,
    unpin_generation,
)
from repro.store.wal import _HEADER, _MAGIC

from conftest import small_db, small_workload

EXACT = 10_000  # nprobe past every list count: search becomes exact


def _build(metric="ip", scan_mode=None, n=1500, seed=0, n_queries=40):
    db = small_db(n=n, d=16, seed=seed, metric=metric)
    wl = small_workload(db, n_queries=n_queries, seed=seed + 1)
    cfg = HQIConfig(min_partition_size=128, max_leaves=8)
    if scan_mode == "pq":
        cfg = HQIConfig(
            min_partition_size=128, max_leaves=8, scan_mode="pq", pq_m=4,
            refine_factor=4,
        )
    return db, wl, HQIIndex.build(db, wl, cfg)


def _one_dev_mesh():
    import jax
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]), ("model",))


# ---------------------------------------------------------------------------
# Snapshot round-trip parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["ip", "l2"])
@pytest.mark.parametrize("scan_mode", [None, "pq"])
@pytest.mark.parametrize("mesh", [False, True])
def test_roundtrip_parity(tmp_path, metric, scan_mode, mesh):
    """build → save → load → bit-identical ids+scores, every configuration."""
    _, wl, hqi = _build(metric=metric, scan_mode=scan_mode)
    if mesh:
        hqi.cfg.mesh = _one_dev_mesh()
    r0 = hqi.search(wl, nprobe=4)
    save_snapshot(tmp_path, hqi)
    loaded = load_snapshot(str(tmp_path)).index
    if mesh:
        loaded.cfg.mesh = _one_dev_mesh()
    r1 = loaded.search(wl, nprobe=4)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)
    # the adaptive/per-query path must agree too (routing + bitmap cache)
    o0 = hqi.search_online(wl, nprobe=4)
    o1 = loaded.search_online(wl, nprobe=4)
    np.testing.assert_array_equal(o0.ids, o1.ids)
    np.testing.assert_array_equal(o0.scores, o1.scores)


def test_loaded_snapshot_is_warm(tmp_path):
    """Load restores the arena (rows + codes) and the Router bitmap cache —
    no O(N) recompute before the first engine search."""
    _, wl, hqi = _build(scan_mode="pq")
    hqi.search(wl, nprobe=4)  # materialize arena + populate bitmap cache
    assert hqi.router._bitmap_cache
    save_snapshot(tmp_path, hqi)
    loaded = load_snapshot(str(tmp_path)).index
    assert loaded._arena is not None
    assert loaded._arena.codes is not None and loaded._arena.pq is not None
    assert set(loaded.router._bitmap_cache) == set(hqi.router._bitmap_cache)
    for filt, bm in hqi.router._bitmap_cache.items():
        np.testing.assert_array_equal(bm, loaded.router._bitmap_cache[filt])


def test_roundtrip_after_extend(tmp_path):
    """A snapshot taken after live folds round-trips the grown index."""
    db, wl, hqi = _build()
    hqi.search(wl, nprobe=4)
    from repro.core.types import VectorDatabase

    new = db.take(np.arange(7))
    new = VectorDatabase(
        vectors=new.vectors + 0.01, columns=new.columns, metric=db.metric,
        ids=db.n + np.arange(7, dtype=np.int64),
    )
    hqi.extend(new)
    r0 = hqi.search(wl, nprobe=EXACT)
    save_snapshot(tmp_path, hqi)
    loaded = load_snapshot(str(tmp_path)).index
    r1 = loaded.search(wl, nprobe=EXACT)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)


def test_roundtrip_property():
    """Hypothesis sweep: save→load parity holds on random configurations."""
    pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
    import tempfile

    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 50),
        metric=st.sampled_from(["ip", "l2"]),
        pq=st.booleans(),
        k=st.integers(1, 8),
    )
    def check(seed, metric, pq, k):
        db = small_db(n=900, d=16, seed=seed, metric=metric)
        wl = small_workload(db, n_queries=20, seed=seed + 1, k=k)
        cfg = HQIConfig(
            min_partition_size=128, max_leaves=8,
            scan_mode="pq" if pq else None, pq_m=4,
        )
        hqi = HQIIndex.build(db, wl, cfg)
        r0 = hqi.search(wl, nprobe=3)
        with tempfile.TemporaryDirectory() as tmp:
            save_snapshot(tmp, hqi)
            loaded = load_snapshot(tmp).index
        r1 = loaded.search(wl, nprobe=3)
        np.testing.assert_array_equal(r0.ids, r1.ids)
        np.testing.assert_array_equal(r0.scores, r1.scores)

    check()


def test_generation_fallback_and_prune(tmp_path):
    """A torn newest generation is skipped; pruning keeps CURRENT loadable."""
    _, wl, hqi = _build(n=900, n_queries=16)
    r0 = hqi.search(wl, nprobe=3)
    save_snapshot(tmp_path, hqi)
    save_snapshot(tmp_path, hqi)
    # simulate a crash that tore generation 2: blob missing entirely
    gen2 = tmp_path / "gen-000002"
    os.remove(gen2 / "arrays" / "index.db.vectors.npy")
    snap = load_snapshot(str(tmp_path))
    assert snap.generation == 1
    r1 = snap.index.search(wl, nprobe=3)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    # a truncated blob (partial write) is also detected
    save_snapshot(tmp_path, hqi)  # gen 3, complete
    blob = tmp_path / "gen-000003" / "arrays" / "index.db.vectors.npy"
    with open(blob, "r+b") as f:
        f.truncate(64)
    assert load_snapshot(str(tmp_path)).generation == 1
    # prune keeps the newest `keep` (and never the CURRENT target)
    save_snapshot(tmp_path, hqi)  # gen 4
    prune_generations(str(tmp_path), keep=1)
    assert list_generations(str(tmp_path)) == ["gen-000004"]
    assert load_snapshot(str(tmp_path)).generation == 4


def test_prune_keep_zero_and_pins(tmp_path):
    """Regression: ``keep=0`` silently deleted NOTHING despite the "all but
    the newest keep" contract. It now prunes everything except CURRENT and
    pinned generations; negative keep raises."""
    import pytest

    _, wl, hqi = _build(n=600, n_queries=8)
    for _ in range(4):
        save_snapshot(tmp_path, hqi)  # gen 1..4; CURRENT -> gen-000004
    root = str(tmp_path)
    with pytest.raises(ValueError):
        prune_generations(root, keep=-1)
    # pinned generations survive any keep (the tuner's rollback target)
    pin_generation(root, "gen-000002")
    assert pinned_generations(root) == {"gen-000002"}
    doomed = prune_generations(root, keep=0)
    assert sorted(doomed) == ["gen-000001", "gen-000003"]
    assert list_generations(root) == ["gen-000002", "gen-000004"]
    assert load_snapshot(root).generation == 4  # CURRENT untouched
    # explicit pinned= argument works too; unpinning re-exposes to pruning
    unpin_generation(root, "gen-000002")
    assert prune_generations(root, keep=0, pinned=("gen-000002",)) == []
    assert prune_generations(root, keep=0) == ["gen-000002"]
    assert list_generations(root) == ["gen-000004"]


# ---------------------------------------------------------------------------
# WAL + crash recovery
# ---------------------------------------------------------------------------


def _svc_pair(tmp_path, wl, hqi, **cfg_kw):
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return init_store(str(tmp_path), hqi, cfg=ServiceConfig(**kw))


def _answers(svc, wl):
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        for i in range(wl.m)
    ]
    svc.drain()
    return np.stack([h.ids for h in handles]), np.stack([h.scores for h in handles])


def test_recovery_restores_acknowledged_writes(tmp_path):
    """Acknowledged inserts/deletes survive a crash with identical answers."""
    db, wl, hqi = _build(metric="l2")
    svc = _svc_pair(tmp_path, wl, hqi)
    rng = np.random.default_rng(7)
    ids_a = svc.insert(db.vectors[:5] + 0.01)
    svc.delete([int(ids_a[1]), 3, 3])  # delta + indexed + repeat (no-op)
    ids_b = svc.insert(rng.normal(size=(4, db.d)).astype(np.float32))
    a_ids, a_scores = _answers(svc, wl)

    # "crash": drop the in-memory service, reopen from disk
    svc.wal.close()
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    assert svc2.n_live == svc.n_live
    np.testing.assert_array_equal(np.sort(svc2.live_ids()), np.sort(svc.live_ids()))
    b_ids, b_scores = _answers(svc2, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_scores, b_scores)
    # id assignment continues exactly where the crashed process would have
    nxt = svc2.insert(db.vectors[:1])
    assert int(nxt[0]) == int(ids_b[-1]) + 1


def test_crash_mid_wal_append_drops_only_the_tail(tmp_path):
    """A record torn mid-append (crash during write) is dropped; every
    earlier (acknowledged) record survives."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    acked = svc.insert(db.vectors[:3] + 0.05)
    svc.delete([int(acked[2])])
    svc.wal.close()

    seg = os.path.join(str(tmp_path), "wal", svc.wal.segments()[-1])
    with open(seg, "ab") as f:
        # a torn insert: intact header claiming 500 payload bytes, only 20
        # made it to disk before the "crash"
        f.write(_HEADER.pack(_MAGIC, 99, 1, 500, 0) + b"x" * 20)

    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    live = set(svc2.live_ids().tolist())
    assert int(acked[0]) in live and int(acked[1]) in live
    assert int(acked[2]) not in live  # the acknowledged delete survived
    # the torn record contributed nothing and the log is appendable again
    nxt = svc2.insert(db.vectors[:1])
    assert int(nxt[0]) == int(acked[-1]) + 1
    svc3 = open_service(str(tmp_path), cfg=svc.cfg)
    assert int(nxt[0]) in set(svc3.live_ids().tolist())


def test_corrupt_payload_detected_by_crc(tmp_path):
    """Bit rot inside a sealed segment's committed payload raises loudly —
    acknowledged records sit behind the damage, silent drop is data loss."""
    from repro.store.wal import WalCorruptionError

    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.insert(db.vectors[:2])
    svc.insert(db.vectors[2:4])
    svc.wal.close()  # seals the segment (close == rotate)
    seg = os.path.join(str(tmp_path), "wal", svc.wal.segments()[-1])
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.seek(size - 24)  # inside record 2's payload, before the seal frame
        f.write(b"\xff\xff\xff")
    with pytest.raises(WalCorruptionError, match="sealed segment"):
        open_service(str(tmp_path))


def test_refresh_rotates_and_compaction_prunes(tmp_path):
    """refresh() seals the WAL segment; compaction snapshots at the fold
    point and prunes generations + covered segments."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.insert(db.vectors[:4] + 0.01)
    assert len(svc.wal.segments()) == 1
    svc.refresh()
    svc.insert(db.vectors[4:6] + 0.01)
    assert len(svc.wal.segments()) == 2  # rotation at the fold boundary

    comp = Compactor(svc, str(tmp_path), keep_generations=1, min_delta_rows=1)
    assert comp.compact_once() == "gen-000002"
    assert list_generations(str(tmp_path)) == ["gen-000002"]
    # gen-2 covers every record: every sealed segment is prunable
    assert svc.wal.segments() == []
    # ... and the log stays appendable, continuing the sequence
    svc.insert(db.vectors[6:7] + 0.01)
    assert len(svc.wal.segments()) == 1
    # post-compaction recovery needs no replayed pre-fold inserts
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    a_ids, a_s = _answers(svc, wl)
    b_ids, b_s = _answers(svc2, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_s, b_s)


def test_background_compactor_thread(tmp_path):
    """start()/stop() drives fold→snapshot cycles without answer drift."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    comp = Compactor(svc, str(tmp_path), interval_s=0.01, min_delta_rows=1)
    comp.start()
    import time

    rng = np.random.default_rng(11)
    for _ in range(4):
        svc.insert(rng.normal(size=(3, db.d)).astype(np.float32))
        time.sleep(0.03)
    comp.stop()
    assert comp.generations_written >= 1
    a_ids, a_s = _answers(svc, wl)
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    b_ids, b_s = _answers(svc2, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_s, b_s)


def test_seq_continues_after_full_wal_prune(tmp_path):
    """Compaction may prune EVERY segment; recovered services must keep
    committing ABOVE the snapshot's seq or the next recovery would skip
    acknowledged writes as already-covered."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.insert(db.vectors[:4] + 0.01)
    comp = Compactor(svc, str(tmp_path), keep_generations=1)
    comp.compact_once(force=True)
    comp.compact_once(force=True)  # no new writes: same wal_seq, prunes all
    assert svc.wal.segments() == []
    svc.wal.close()

    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    acked = svc2.insert(db.vectors[4:6] + 0.01)  # seqs must resume > covered
    svc3 = open_service(str(tmp_path), cfg=svc.cfg)
    live = set(svc3.live_ids().tolist())
    assert int(acked[0]) in live and int(acked[1]) in live


def test_sealed_segment_corruption_is_not_truncated(tmp_path):
    """Mid-log bit rot in a SEALED segment stops replay conservatively but
    must not destroy the bytes (only the open segment's torn tail is
    repaired)."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.insert(db.vectors[:2])
    svc.refresh()  # seals segment 1
    svc.insert(db.vectors[2:4])  # opens segment 2
    svc.wal.close()
    segs = svc.wal.segments()
    assert len(segs) == 2
    sealed = os.path.join(str(tmp_path), "wal", segs[0])
    size = os.path.getsize(sealed)
    with open(sealed, "r+b") as f:
        f.seek(size - 3)
        f.write(b"\xff\xff\xff")
    wal = WriteAheadLog(os.path.join(str(tmp_path), "wal"))
    assert os.path.getsize(sealed) == size  # bytes kept for forensics
    wal.close()
    # ... and recovery refuses to serve with acknowledged records
    # unreachable behind the rot, instead of silently dropping them
    from repro.store.wal import WalCorruptionError

    with pytest.raises(WalCorruptionError, match="sealed segment"):
        open_service(str(tmp_path), cfg=svc.cfg)


def test_delete_only_interval_still_seals_and_prunes(tmp_path):
    """Tombstones of indexed rows never touch the delta, but their WAL
    records must still be sealed + pruned by compaction (they are covered
    by the snapshot's live mask)."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.delete(np.arange(0, 30, 3))
    comp = Compactor(svc, str(tmp_path), keep_generations=1)
    assert comp.compact_once(force=True) is not None
    assert svc.wal.segments() == []  # delete-only segment sealed + covered
    svc.wal.close()
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    a_ids, a_s = _answers(svc, wl)
    b_ids, b_s = _answers(svc2, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_s, b_s)
    assert svc2.n_live == svc.n_live


def test_fallback_when_blob_torn_inside_header_margin(tmp_path):
    """A blob truncated by less than the npy header passes the cheap size
    check but fails at load — the loader must fall back, not crash."""
    _, wl, hqi = _build(n=900, n_queries=16)
    r0 = hqi.search(wl, nprobe=3)
    save_snapshot(tmp_path, hqi)
    save_snapshot(tmp_path, hqi)
    blob = tmp_path / "gen-000002" / "arrays" / "index.db.vectors.npy"
    size = os.path.getsize(blob)
    with open(blob, "r+b") as f:
        f.truncate(size - 40)  # within the ~128 B header margin
    snap = load_snapshot(str(tmp_path))
    assert snap.generation == 1
    r1 = snap.index.search(wl, nprobe=3)
    np.testing.assert_array_equal(r0.ids, r1.ids)


def test_rejected_insert_is_never_logged(tmp_path):
    """Validation failures happen BEFORE the WAL commit: a rejected insert
    leaves neither a log record nor visible rows."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    seq_before = svc.wal.last_seq
    n_before = svc.n_live
    with pytest.raises(AssertionError, match="unknown columns"):
        svc.insert(db.vectors[:1], columns={"no_such_column": np.zeros(1)})
    assert svc.wal.last_seq == seq_before
    assert svc.n_live == n_before
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)  # replay stays clean
    assert svc2.n_live == n_before


def test_snapshot_handles_pathological_column_names(tmp_path):
    """Column names flow into blob filenames; separators must not escape."""
    from repro.core.types import Column, VectorDatabase

    rng = np.random.default_rng(0)
    db = VectorDatabase(
        vectors=rng.normal(size=(600, 16)).astype(np.float32),
        columns={"a/b c": Column.numeric("a/b c", rng.random(600))},
        metric="ip",
    )
    from repro.core.predicates import NotNull, make_filter

    wl = Workload(
        vectors=rng.normal(size=(8, 16)).astype(np.float32),
        templates=[make_filter(NotNull("a/b c"))],
        template_of=np.zeros(8, dtype=np.int32),
        k=5,
    )
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=4))
    r0 = hqi.search(wl, nprobe=EXACT)
    save_snapshot(tmp_path, hqi)
    loaded = load_snapshot(str(tmp_path)).index
    r1 = loaded.search(wl, nprobe=EXACT)
    np.testing.assert_array_equal(r0.ids, r1.ids)
    np.testing.assert_array_equal(r0.scores, r1.scores)


def test_init_store_over_reused_root_covers_stale_wal(tmp_path):
    """Re-bootstrapping over a previously used root must not leave the old
    incarnation's WAL records replayable into the new index."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    stale = svc.insert(db.vectors[:2] + 0.5)  # incarnation 1's records
    svc.wal.close()

    _, _, hqi2 = _build(seed=3)  # operator rebuilds from scratch
    svc2 = _svc_pair(tmp_path, wl, hqi2)
    fresh = svc2.insert(db.vectors[2:4] + 0.5)
    svc2.wal.close()

    svc3 = open_service(str(tmp_path), cfg=svc2.cfg)  # must not resurrect
    live = set(svc3.live_ids().tolist())
    assert int(fresh[0]) in live and int(fresh[1]) in live
    assert svc3.n_live == svc2.n_live
    a_ids, _ = _answers(svc2, wl)
    b_ids, _ = _answers(svc3, wl)
    np.testing.assert_array_equal(a_ids, b_ids)


def test_corruption_in_covered_segment_does_not_block_recovery(tmp_path):
    """Bit rot in a retained-but-snapshot-covered segment is skipped: the
    newest snapshot + WAL tail can fully serve the restart."""
    db, wl, hqi = _build()
    svc = _svc_pair(tmp_path, wl, hqi)
    svc.insert(db.vectors[:3] + 0.01)
    comp = Compactor(svc, str(tmp_path), keep_generations=2)
    comp.compact_once()  # gen-2 covers seg-1; seg-1 retained for gen-1
    covered = svc.wal.segments()
    assert len(covered) == 1
    acked = svc.insert(db.vectors[3:5] + 0.01)  # opens segment 2
    svc.wal.close()
    seg1 = os.path.join(str(tmp_path), "wal", covered[0])
    with open(seg1, "r+b") as f:
        f.seek(30)
        f.write(b"\xff\xff\xff")  # interior rot in the covered segment
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    live = set(svc2.live_ids().tolist())
    assert int(acked[0]) in live and int(acked[1]) in live
    assert svc2.n_live == svc.n_live


def test_wal_reopen_resumes_seq(tmp_path):
    """Reopening a WAL continues the sequence; replay(after_seq) filters."""
    wal = WriteAheadLog(str(tmp_path / "wal"))
    s1 = wal.log_insert(np.zeros((2, 4), np.float32), np.array([10, 11]))
    s2 = wal.log_delete([10])
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.last_seq == s2 == 2
    s3 = wal2.log_delete([11])
    recs = list(wal2.replay(after_seq=s1))
    assert [r.seq for r in recs] == [s2, s3]
    wal2.close()


# ---------------------------------------------------------------------------
# Delta-store compression (ROADMAP satellite)
# ---------------------------------------------------------------------------


def _pq_service(tmp_path, threshold):
    db, wl, hqi = _build(metric="l2", scan_mode="pq")
    svc = HQIService(
        hqi,
        ServiceConfig(
            k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0,
            delta_pq_threshold=threshold,
        ),
    )
    return db, wl, svc


def test_delta_pq_scan_over_threshold(tmp_path):
    """Past the threshold the delta scans compressed (pq-tagged dispatch);
    with full refine the answers stay exactly equal to the f32 scan."""
    db, wl, svc = _pq_service(tmp_path, threshold=8)
    rng = np.random.default_rng(5)
    n_new = 40
    svc.index.cfg.plan.refine_factor = (n_new // wl.k) + 1  # full refine: exact
    svc.insert(rng.normal(size=(n_new, db.d)).astype(np.float32))

    kops.reset_dispatch_stats()
    a_ids, a_s = _answers(svc, wl)
    shapes = kops.dispatch_stats().snapshot().shapes
    assert any(s[0] == "pq" for s in shapes), shapes  # compressed delta scan

    # identical workload through the exact path (threshold disabled)
    svc.cfg.delta_pq_threshold = None
    b_ids, b_s = _answers(svc, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_s, b_s)


def test_delta_pq_under_threshold_stays_exact(tmp_path):
    """At or under the threshold no ADC dispatch happens on the delta."""
    db, wl, svc = _pq_service(tmp_path, threshold=4096)
    svc.insert(db.vectors[:6] + 0.01)
    kops.reset_dispatch_stats()
    _answers(svc, wl)
    shapes = kops.dispatch_stats().snapshot().shapes
    assert not any(s[0] == "pq" for s in shapes), shapes


def test_delta_pq_respects_tombstones_and_filters(tmp_path):
    """Compressed delta scans still honor deletes and template bitmaps."""
    db, wl, svc = _pq_service(tmp_path, threshold=4)
    rng = np.random.default_rng(9)
    svc.index.cfg.plan.refine_factor = 64
    ids = svc.insert(rng.normal(size=(20, db.d)).astype(np.float32))
    svc.delete(ids[:10])
    a_ids, _ = _answers(svc, wl)
    dead = set(int(i) for i in ids[:10])
    assert not (set(a_ids[a_ids >= 0].tolist()) & dead)


# ---------------------------------------------------------------------------
# Codebook-shape validation (satellite fix)
# ---------------------------------------------------------------------------


def test_attach_pq_rejects_mismatched_codebook():
    rng = np.random.default_rng(0)
    from repro.core import IVFIndex

    vecs = rng.normal(size=(256, 16)).astype(np.float32)
    ivf = IVFIndex.build(vecs, metric="l2", n_centroids=4)
    arena = PackedArena.from_ivf(ivf)
    bad = train_pq(rng.normal(size=(256, 24)).astype(np.float32), 4, metric="l2")
    with pytest.raises(ValueError, match=r"d=24.*d=16"):
        arena.attach_pq(bad)
    assert arena.pq is None and arena.codes is None  # attach left no residue


def test_encode_pq_rejects_mismatched_vectors():
    from repro.core import encode_pq

    rng = np.random.default_rng(0)
    cb = train_pq(rng.normal(size=(512, 16)).astype(np.float32), 4, metric="l2")
    with pytest.raises(ValueError, match=r"m=4.*dsub=4.*d=20"):
        encode_pq(cb, rng.normal(size=(8, 20)).astype(np.float32))


# ---------------------------------------------------------------------------
# WAL group commit
# ---------------------------------------------------------------------------


def _count_fsync(monkeypatch, delay_s=0.002):
    """Replace os.fsync with a counting (optionally slowed) stand-in; the
    delay widens the group-commit window so followers actually pile up."""
    import time

    import repro.store.wal as wal_mod

    calls = []
    real = os.fsync

    def counting(fd):
        calls.append(fd)
        if delay_s:
            time.sleep(delay_s)
        return real(fd)

    monkeypatch.setattr(wal_mod.os, "fsync", counting)
    return calls


def test_group_commit_batches_fsyncs(tmp_path, monkeypatch):
    """Concurrent writers share durability barriers: T threads x B commits
    with a slowed fsync must issue FEWER fsyncs than commits (leader syncs
    the whole staged tail; followers just wait for the high-water mark),
    while every insert still acks unique, gap-free ids."""
    import threading

    db, wl, hqi = _build(n=600)
    svc = _svc_pair(tmp_path, wl, hqi)
    calls = _count_fsync(monkeypatch)
    base = len(calls)
    T, B = 8, 6
    acked = [[] for _ in range(T)]

    def writer(t):
        rng = np.random.default_rng(100 + t)
        for _ in range(B):
            ids = svc.insert(rng.normal(size=(1, db.d)).astype(np.float32))
            acked[t].append(int(ids[0]))

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(T)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    n_commits = T * B
    n_fsyncs = len(calls) - base
    assert n_fsyncs < n_commits, (n_fsyncs, n_commits)  # batching happened
    assert n_fsyncs >= 1  # but durability was never skipped
    flat = sorted(x for lane in acked for x in lane)
    assert len(set(flat)) == n_commits  # unique ids, no double-assignment
    assert flat == list(range(flat[0], flat[0] + n_commits))  # gap-free
    # each thread's acks arrive in its own submission order
    assert all(lane == sorted(lane) for lane in acked)

    # crash + reopen: every acknowledged row replays bit-identically
    svc.wal.close()
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    assert svc2.n_live == svc.n_live
    np.testing.assert_array_equal(np.sort(svc2.live_ids()), np.sort(svc.live_ids()))


def test_group_commit_mixed_inserts_deletes(tmp_path, monkeypatch):
    """Interleaved concurrent inserts and deletes keep the WAL replay order
    consistent with the in-memory state: recovery lands on the same live set
    and the same answers as the uncrashed process."""
    import threading

    db, wl, hqi = _build(n=600, metric="l2")
    svc = _svc_pair(tmp_path, wl, hqi)
    _count_fsync(monkeypatch, delay_s=0.001)
    seed_ids = svc.insert(db.vectors[:12] + 0.01)

    def inserter(t):
        rng = np.random.default_rng(t)
        for _ in range(5):
            svc.insert(rng.normal(size=(2, db.d)).astype(np.float32))

    def deleter(t):
        for j in range(3):
            svc.delete([int(seed_ids[(t * 3 + j) % len(seed_ids)])])

    threads = [threading.Thread(target=inserter, args=(t,)) for t in range(4)]
    threads += [threading.Thread(target=deleter, args=(t,)) for t in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    a_ids, a_scores = _answers(svc, wl)
    svc.wal.close()
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    assert svc2.n_live == svc.n_live
    np.testing.assert_array_equal(np.sort(svc2.live_ids()), np.sort(svc.live_ids()))
    b_ids, b_scores = _answers(svc2, wl)
    np.testing.assert_array_equal(a_ids, b_ids)
    np.testing.assert_array_equal(a_scores, b_scores)


def test_group_commit_fsync_failure_is_not_acknowledged(tmp_path, monkeypatch):
    """A failing durability barrier must propagate to every commit waiting on
    it (no silent ack), and the log must keep working once fsync recovers —
    later commits land above the failed ones with correct ids."""
    import repro.store.wal as wal_mod

    db, wl, hqi = _build(n=600)
    svc = _svc_pair(tmp_path, wl, hqi)
    ok_ids = svc.insert(db.vectors[:2] + 0.01)

    real = os.fsync
    fail = {"on": True}

    def flaky(fd):
        if fail["on"]:
            raise OSError("injected fsync failure")
        return real(fd)

    monkeypatch.setattr(wal_mod.os, "fsync", flaky)
    with pytest.raises(OSError, match="injected"):
        svc.insert(db.vectors[2:4] + 0.01)

    # failing past the retry budget poisons the log: writes fail fast until
    # the operator heals it (repro.fault quarantine — reads keep serving)
    assert svc.wal.poisoned is not None
    from repro.service import ServiceReadOnly

    with pytest.raises(ServiceReadOnly):
        svc.insert(db.vectors[4:6] + 0.01)

    fail["on"] = False
    svc.wal.clear_poison()
    later = svc.insert(db.vectors[4:6] + 0.01)
    # the failed batch still consumed its id range (its frame is in the log;
    # replay applies it), so the next ack continues above it
    assert int(later[0]) == int(ok_ids[-1]) + 3
    svc.wal.close()
    svc2 = open_service(str(tmp_path), cfg=svc.cfg)
    assert svc2.n_live == svc.n_live


def test_wal_stage_sync_api_direct(tmp_path, monkeypatch):
    """stage() orders frames (seq = file order) without waiting; sync_upto()
    is idempotent and monotone; replay sees every staged record exactly once,
    in order, across concurrent writers."""
    import threading

    wal = WriteAheadLog(str(tmp_path / "wal"))
    calls = _count_fsync(monkeypatch, delay_s=0.001)
    T, B = 6, 10

    def writer(t):
        for j in range(B):
            seq = wal.stage_delete(np.array([t * B + j], dtype=np.int64))
            wal.sync_upto(seq)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(T)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    assert len(calls) < T * B  # group commit collapsed barriers
    recs = list(wal.replay(0))
    assert [r.seq for r in recs] == list(range(1, T * B + 1))
    seen = sorted(int(r.arrays["ids"][0]) for r in recs)
    assert seen == list(range(T * B))
    # syncing an already-durable seq is a no-op (no new fsync)
    n = len(calls)
    wal.sync_upto(1)
    assert len(calls) == n
    wal.close()
