"""Segmented candidate pipeline: the flat CSR merge must be BIT-IDENTICAL
to the dense slot-rectangular layout it replaces.

The acceptance bar is exact equality of ids AND scores (``np.array_equal``,
not the tie-tolerant conftest helper): the segmented scatter preserves each
query's slot-major candidate order and the segmented merge's stable sort
reproduces ``lax.top_k``'s smallest-index tie rule, so nothing — not even
exact-tie ordering — may diverge.

Covered: {ip, l2} × {f32, pq} engine parity with forced score ties and
bitmap pushdown; skewed per-template routing through HQIIndex (1-vs-all
nprobe dicts → ragged segment widths); empty segments (templates matching
nothing); k larger than every segment; the adaptive executor's extras
folding (batch_vec="auto"); the resident-LUT invariant (segmented pq never
materializes a [W, TQ, M, 256] operand: DispatchStats.lut_expand_bytes == 0);
kernel-level oracle checks for ``segmented_merge_topk`` and the streamed
Pallas ADC grid; and a hypothesis property over random segment shapes.
Mesh parity for the segmented layout lives in test_engine_sharded.py
(test_sharded_merge_layout_parity) — jax device pools need a subprocess.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import HQIConfig, HQIIndex
from repro.core.ivf import IVFIndex, ScanStats
from repro.core.plan import PlanConfig
from repro.core.planner import batch_search_ivf
from repro.core.pq import train_pq
from repro.core.types import Workload
from repro.kernels import ops, ref

from conftest import small_db, small_workload


def _tied_db(metric, seed=0):
    """small_db with duplicated vector blocks so exact score ties occur."""
    db = small_db(n=900, seed=seed, metric=metric)
    db.vectors[100:120] = db.vectors[0]  # 21 identical rows -> guaranteed ties
    db.vectors[400:408] = db.vectors[3]
    return db


def _cfg(layout, mode):
    return PlanConfig(
        tq_unit=8,
        min_list_pad=8,
        use_pallas=False,
        scan_mode=mode,
        refine_factor=2,
        merge_layout=layout,
    )


def assert_exact(a, b, ctx=""):
    (a_s, a_i), (b_s, b_i) = a, b
    assert np.array_equal(a_s, b_s), f"scores diverge: {ctx}"
    assert np.array_equal(a_i, b_i), f"ids diverge: {ctx}"


@pytest.mark.parametrize("metric", ["ip", "l2"])
@pytest.mark.parametrize("mode", ["f32", "pq"])
def test_segmented_vs_dense_engine_parity(metric, mode):
    """batch_search_ivf: segmented == dense bit-for-bit, with ties and
    bitmap pushdown, across metrics and both scan modes."""
    rng = np.random.default_rng(17)
    db = _tied_db(metric)
    ivf = IVFIndex.build(db.vectors, metric=metric, n_centroids=16, seed=0)
    pq = train_pq(db.vectors, 4, metric=metric, iters=4, seed=0) if mode == "pq" else None
    q = rng.normal(size=(23, db.d)).astype(np.float32)
    q[5] = db.vectors[0]  # lands on the duplicated block: top-k is all ties
    for bitmap in (None, rng.random(db.n) < 0.4):
        dense = batch_search_ivf(
            ivf, q, nprobe=6, k=5, bitmap=bitmap, cfg=_cfg("dense", mode), pq=pq
        )
        seg = batch_search_ivf(
            ivf, q, nprobe=6, k=5, bitmap=bitmap, cfg=_cfg("segmented", mode), pq=pq
        )
        assert_exact(seg, dense, f"{metric}/{mode} bitmap={bitmap is not None}")


def _search_layout(hqi, wl, layout, **kw):
    prev = hqi.cfg.plan.merge_layout
    hqi.cfg.plan.merge_layout = layout
    try:
        return hqi.search(wl, **kw)
    finally:
        hqi.cfg.plan.merge_layout = prev


@pytest.mark.parametrize("mode", ["f32", "pq"])
def test_segmented_hqi_skewed_routing_parity(mode):
    """Skewed per-template nprobe (one heavy template, the rest nprobe=1)
    makes segment widths ragged — exactly the shape the dense layout pads
    for. Results must still be bit-identical, through the full HQI path
    (multi-partition arena, template bitmaps, final fold)."""
    db = small_db(n=1500, seed=4)
    wl = small_workload(db, n_queries=48, seed=2)
    hqi = HQIIndex.build(
        db,
        wl,
        HQIConfig(
            min_partition_size=128, max_leaves=32,
            scan_mode=mode, refine_factor=2,
        ),
    )
    nprobe = {t: (12 if t == 0 else 1) for t in range(len(wl.templates))}
    for batch_vec in (True, "auto"):
        dense = _search_layout(hqi, wl, "dense", nprobe=nprobe, batch_vec=batch_vec)
        seg = _search_layout(hqi, wl, "segmented", nprobe=nprobe, batch_vec=batch_vec)
        assert np.array_equal(dense.scores, seg.scores), (mode, batch_vec)
        assert np.array_equal(dense.ids, seg.ids), (mode, batch_vec)
    # the skewed plan really is ragged: raggedness is what this test is about
    st = ScanStats()
    tasks, _, _ = hqi._engine_tasks(wl, nprobe=nprobe, batch_vec=True, stats=st)
    from repro.core.plan import build_plan

    plan = build_plan(hqi.arena, tasks, wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan)
    counts = plan.seg_counts
    assert counts.max() > counts.min(), "nprobe dict failed to skew segments"


def test_segmented_empty_segments():
    """Queries whose template matches nothing contribute zero-width segments
    and must come back as exactly (-inf, -1) rows — same as dense."""
    from repro.core.predicates import Between, make_filter

    db = small_db(n=600, seed=9)
    wl = small_workload(db, n_queries=24, seed=3)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    templates = [make_filter(Between("A", 5.0, 6.0)), make_filter()]  # A in [0,1): empty
    wl2 = Workload(
        vectors=wl.vectors[:10],
        templates=templates,
        template_of=(np.arange(10) % 2).astype(np.int32),
        k=4,
    )
    dense = _search_layout(hqi, wl2, "dense", nprobe=6)
    seg = _search_layout(hqi, wl2, "segmented", nprobe=6)
    assert np.array_equal(dense.scores, seg.scores)
    assert np.array_equal(dense.ids, seg.ids)
    empty = np.arange(10) % 2 == 0
    assert (seg.ids[empty] == -1).all()
    assert np.isneginf(seg.scores[empty]).all()


@pytest.mark.parametrize("mode", ["f32", "pq"])
def test_segmented_k_exceeds_segment_width(mode):
    """k larger than any posting list: every segment is narrower than k, so
    the merge must pad — identically in both layouts."""
    db = small_db(n=300, seed=5)
    ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=32, seed=0)
    pq = train_pq(db.vectors, 8, metric=db.metric, seed=0) if mode == "pq" else None
    rng = np.random.default_rng(5)
    q = rng.normal(size=(9, db.d)).astype(np.float32)
    k = 64  # lists average ~10 rows
    dense = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=_cfg("dense", mode), pq=pq)
    seg = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=_cfg("segmented", mode), pq=pq)
    assert_exact(seg, dense, f"k>width {mode}")
    assert (seg[1] == -1).any()  # padding must actually occur


def test_segmented_pq_never_expands_lut():
    """The resident-LUT invariant: segmented pq dispatch indexes the [U, M,
    256] table in-kernel and must NEVER materialize the dense [W, TQ, M, 256]
    expansion — lut_expand_bytes stays 0 (and is nonzero for dense)."""
    db = small_db(n=900, seed=1)
    wl = small_workload(db, n_queries=32, seed=1)
    hqi = HQIIndex.build(
        db, wl,
        HQIConfig(min_partition_size=128, max_leaves=32, scan_mode="pq", refine_factor=2),
    )
    ops.reset_dispatch_stats()
    res_seg = _search_layout(hqi, wl, "segmented", nprobe=6)
    st = ops.dispatch_stats()
    assert st.lut_expand_bytes == 0, st.lut_expand_bytes
    assert st.peak_candidate_bytes > 0
    # the per-search observability surfaces through SearchResult
    assert res_seg.peak_candidate_bytes > 0
    assert res_seg.lut_bytes > 0  # resident table bytes are still accounted

    ops.reset_dispatch_stats()
    res_dense = _search_layout(hqi, wl, "dense", nprobe=6)
    st = ops.dispatch_stats()
    assert st.lut_expand_bytes > 0  # dense pays the expanded operand
    assert res_dense.lut_bytes > res_seg.lut_bytes


def test_build_plan_emits_seg_counts():
    """build_plan's seg_counts are the per-query REAL slot counts: they sum
    to the total routed (query, list) pairs and max out at n_slots."""
    from repro.core.plan import build_plan

    db = small_db(n=800, seed=2)
    wl = small_workload(db, n_queries=30, seed=2)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    st = ScanStats()
    nprobe = {t: (10 if t == 0 else 2) for t in range(len(wl.templates))}
    tasks, _, _ = hqi._engine_tasks(wl, nprobe=nprobe, batch_vec=True, stats=st)
    plan = build_plan(hqi.arena, tasks, wl.vectors, m=wl.m, k=wl.k, cfg=hqi.cfg.plan)
    counts = plan.seg_counts
    assert counts.shape == (wl.m,)
    assert counts.max() == plan.n_slots
    # slots are allocated per probed list (a bitmap-killed or empty list still
    # consumes its slot as -inf padding), so seg_counts bounds the emitted
    # work-unit rows from above and every unit's slot lands inside its segment
    total = sum(len(u.qrows) for units in plan.buckets.values() for u in units)
    assert counts.sum() >= total > 0
    for units in plan.buckets.values():
        for u in units:
            assert (u.slots < counts[u.qrows]).all()


# --------------------------------------------------------------------------
# kernel-level oracles


def _dense_merge_emulation(flat_s, flat_i, counts, k):
    """Scatter flat rows into the dense [m, n_slots, kk] layout and reduce
    with lax.top_k — the exact computation the dense merge performs."""
    m = len(counts)
    kk = flat_s.shape[1]
    n_slots = int(max(counts.max(), 1)) if m else 1
    ds = np.full((m, n_slots, kk), -np.inf, np.float32)
    di = np.full((m, n_slots, kk), -1, np.int64)
    r = 0
    for q in range(m):
        for sl in range(counts[q]):
            ds[q, sl], di[q, sl] = flat_s[r], flat_i[r]
            r += 1
    ds, di = ds.reshape(m, -1), di.reshape(m, -1)
    keff = min(k, ds.shape[1])
    top, pos = jax.lax.top_k(jnp.asarray(ds), keff)
    oi = jnp.take_along_axis(jnp.asarray(di), pos.astype(jnp.int64), axis=1)
    top, oi = ref.normalize_merge_sentinels(top, oi)
    if keff < k:
        top = jnp.pad(top, ((0, 0), (0, k - keff)), constant_values=-np.inf)
        oi = jnp.pad(oi, ((0, 0), (0, k - keff)), constant_values=-1)
    return np.asarray(top), np.asarray(oi)


def _random_segments(rng, m, kk):
    """Random ragged candidate rows with sentinel flavors and heavy ties."""
    counts = rng.integers(0, 5, size=m)
    C = int(counts.sum())
    flat_s = rng.choice(
        [-np.inf, float(-3.4e38), 0.0, 1.0, 2.0], size=(C, kk)
    ).astype(np.float32)
    flat_i = rng.integers(-1, 50, size=(C, kk)).astype(np.int64)
    flat_i = np.where(np.isneginf(flat_s), -1, flat_i)
    seg_of = np.repeat(np.arange(m), counts).astype(np.int32)
    return counts, flat_s, flat_i, seg_of


def test_segmented_merge_matches_dense_merge():
    """segmented_merge_topk == the dense scatter + lax.top_k emulation,
    bit-for-bit, over random ragged shapes with ties and both sentinel
    flavors (incl. empty segments and k > width)."""
    rng = np.random.default_rng(1)
    for trial in range(60):
        m = int(rng.integers(1, 6))
        k = int(rng.integers(1, 5))
        kk = int(rng.integers(1, 4))
        counts, flat_s, flat_i, seg_of = _random_segments(rng, m, kk)
        want_s, want_i = _dense_merge_emulation(flat_s, flat_i, counts, k)
        got_s, got_i = ops.segmented_merge_topk(
            jnp.asarray(flat_s), jnp.asarray(flat_i), jnp.asarray(seg_of), m, k
        )
        assert np.array_equal(np.asarray(got_i), want_i), trial
        assert np.array_equal(np.asarray(got_s), want_s), trial


def test_segmented_merge_pad_rows_dropped():
    """Rows tagged seg >= n_segments (flat-buffer pow2 padding) never leak
    into any segment's result."""
    flat_s = np.array([[5.0], [9.0]], np.float32)
    flat_i = np.array([[7], [8]], np.int64)
    seg_of = np.array([0, 1], np.int32)  # row 1 belongs to pad segment
    s, i = ops.segmented_merge_topk(
        jnp.asarray(flat_s), jnp.asarray(flat_i), jnp.asarray(seg_of), 1, 2
    )
    assert np.asarray(i).tolist() == [[7, -1]]
    assert np.asarray(s)[0, 0] == 5.0 and np.isneginf(np.asarray(s)[0, 1])


def test_pq_streamed_kernel_matches_ref():
    """The scalar-prefetch streamed ADC grid == the expanded-LUT reference:
    per-row DMA from the resident table must not change a single score."""
    from repro.core.pq import PQIndex, adc_tables
    from repro.kernels import pq_scan
    from repro.kernels import ref as kref

    rng = np.random.default_rng(7)
    m, d, w, tq, nv, k = 4, 32, 3, 5, 90, 6
    vecs = rng.normal(size=(400, d)).astype(np.float32)
    idx = PQIndex.build(vecs, m=m)
    U = 11
    table = np.stack(
        [adc_tables(idx.cb, rng.normal(size=(1, d)).astype(np.float32))[0] for _ in range(U)]
    )
    lut_idx = rng.integers(0, U, size=(w, tq)).astype(np.int32)
    codes = np.stack([idx.codes[rng.integers(0, len(vecs), nv)] for _ in range(w)])
    valid = rng.random((w, nv)) > 0.3
    luts_expanded = table[lut_idx]  # [W, TQ, M, 256]
    s_ref, i_ref = kref.workunit_pq_topk_ref(
        jnp.asarray(luts_expanded), jnp.asarray(codes), jnp.asarray(valid), k
    )
    s_st, i_st = pq_scan.workunit_pq_scan_streamed(
        jnp.asarray(table), jnp.asarray(lut_idx), jnp.asarray(codes),
        jnp.asarray(valid), k=k, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(s_st), np.asarray(s_ref), rtol=1e-4, atol=1e-4)
    for w_ in range(w):
        for r in range(tq):
            a, b = np.asarray(i_ref)[w_, r], np.asarray(i_st)[w_, r]
            assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist()), (w_, r)


def test_segmented_merge_property():
    """Hypothesis: over arbitrary segment shapes / scores / duplicate ids,
    segmented merge == dense emulation bit-for-bit."""
    hyp = pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(
        seed=st.integers(0, 2**31 - 1),
        m=st.integers(1, 7),
        k=st.integers(1, 6),
        kk=st.integers(1, 4),
    )
    def check(seed, m, k, kk):
        rng = np.random.default_rng(seed)
        counts, flat_s, flat_i, seg_of = _random_segments(rng, m, kk)
        want_s, want_i = _dense_merge_emulation(flat_s, flat_i, counts, k)
        got_s, got_i = ops.segmented_merge_topk(
            jnp.asarray(flat_s), jnp.asarray(flat_i), jnp.asarray(seg_of), m, k
        )
        assert np.array_equal(np.asarray(got_i), want_i)
        assert np.array_equal(np.asarray(got_s), want_s)

    check()
