"""Boundary unit tests for predicate disjointness (Between vs Cmp).

Unlike test_qdtree.py these are plain unit tests (no hypothesis) so they run
even without the [test] extra. ``predicates_disjoint`` must be conservative:
True only when p ∧ q is provably unsatisfiable — a false True silently drops
results through semantic-description routing.
"""
import pytest

from repro.core.predicates import Between, Cmp
from repro.core.qdtree import predicates_disjoint


B = Between("A", 0.0, 1.0)  # matches [0, 1)


@pytest.mark.parametrize(
    "cmp_, expect",
    [
        # op ">": range is < hi, so disjoint iff hi <= value
        (Cmp("A", ">", 1.0), True),  # (1, inf) vs [0, 1): boundary, disjoint
        (Cmp("A", ">", 0.999), False),  # x = 0.9995 satisfies both
        (Cmp("A", ">", -1.0), False),
        # op ">=": [1, inf) vs [0, 1) share no point (hi exclusive)
        (Cmp("A", ">=", 1.0), True),
        (Cmp("A", ">=", 0.999), False),  # x = 0.9995 satisfies both
        # op "<": (-inf, 0) vs [0, 1): boundary, disjoint
        (Cmp("A", "<", 0.0), True),
        (Cmp("A", "<", 0.001), False),  # x = 0.0005 satisfies both
        # op "<=": x = 0.0 satisfies both — NOT disjoint at the boundary
        (Cmp("A", "<=", 0.0), False),
        (Cmp("A", "<=", -0.001), True),
        # op "==": inside vs outside the half-open interval
        (Cmp("A", "==", 0.5), False),
        (Cmp("A", "==", 0.0), False),  # lo is inclusive
        (Cmp("A", "==", 1.0), True),  # hi is exclusive
        (Cmp("A", "==", -0.5), True),
    ],
)
def test_between_vs_cmp_boundaries(cmp_, expect):
    assert predicates_disjoint(B, cmp_) is expect
    # symmetric dispatch (Cmp, Between) must agree
    assert predicates_disjoint(cmp_, B) is expect


def test_different_attrs_never_disjoint():
    assert not predicates_disjoint(B, Cmp("B", ">", 5.0))


def test_between_vs_between_boundaries():
    assert predicates_disjoint(B, Between("A", 1.0, 2.0))  # touching: disjoint
    assert not predicates_disjoint(B, Between("A", 0.999, 2.0))
    assert predicates_disjoint(Between("A", -1.0, 0.0), B)
