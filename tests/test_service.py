"""Online serving subsystem: offline parity, freshness, invalidation, triggers.

The load-bearing guarantee: every answer from ``HQIService`` micro-batched
flushes — including answers produced after interleaved inserts/deletes and
across a ``refresh()`` fold — exactly equals an offline ``HQIIndex.search``
over the equivalent DB snapshot. Exactness is checked in exhaustive mode
(nprobe larger than any partition's list count), where sound routing makes
both sides the true filtered top-k regardless of index layout.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex, exhaustive_search
from repro.core.types import VectorDatabase, Workload
from repro.kernels import ops
from repro.service import (
    DeltaStore,
    HQIService,
    MicroBatchScheduler,
    PendingQuery,
    QueueFull,
    ServiceConfig,
)

from conftest import assert_same_results as _assert_same_results
from conftest import small_db, small_workload

EXACT = 10_000  # nprobe past every list count: search becomes exact


def _service(db, wl, **cfg_kw):
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return HQIService(hqi, ServiceConfig(**kw))


def _stream(svc, wl):
    """Submit the whole workload, drain, and return stacked (ids, scores)."""
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]]) for i in range(wl.m)
    ]
    answered = svc.drain()
    assert answered == wl.m
    assert all(h.done for h in handles)
    return np.stack([h.ids for h in handles]), np.stack([h.scores for h in handles])


def _offline(svc, wl):
    """Ground truth: offline HQIIndex.search over the live-DB snapshot."""
    snap = svc.snapshot_db()
    live = svc.live_ids()
    offline = HQIIndex.build(snap, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    res = offline.search(wl, nprobe=EXACT)
    ids = np.where(res.ids >= 0, live[np.maximum(res.ids, 0)], -1)
    return ids, res.scores


@pytest.fixture(scope="module")
def db():
    return small_db(n=1500, seed=5)


@pytest.fixture(scope="module")
def workload(db):
    return small_workload(db, n_queries=48)


def test_service_parity_across_writes_and_refresh(db, workload):
    """Service flushes == offline search on the equivalent snapshot, through
    an interleaved insert/delete + refresh() cycle and a second delta cycle —
    without a full index rebuild per query (partition count stays fixed)."""
    svc = _service(db, workload)
    n_parts = len(svc.index.partitions)

    got = _stream(svc, workload)
    exp = _offline(svc, workload)
    _assert_same_results(got[1], got[0], exp[1], exp[0])

    # interleave writes: inserts near existing vectors (so they rank), deletes
    # of base and delta rows
    rng = np.random.default_rng(7)
    newv = db.vectors[rng.integers(0, db.n, 120)] + 0.01 * rng.normal(
        size=(120, db.d)
    ).astype(np.float32)
    cols = {
        "A": rng.random(120).astype(np.float32),
        "B": rng.random(120).astype(np.float32),
        "cat": rng.integers(0, 8, 120).astype(np.int32),
        "tags": rng.random((120, 6)) < 0.5,
    }
    ids = svc.insert(newv, cols)
    assert ids[0] == db.n  # global ids continue the index's row numbering
    svc.delete(rng.integers(0, db.n, 60))
    svc.delete(ids[:10])

    got = _stream(svc, workload)  # delta live, not folded yet
    exp = _offline(svc, workload)
    _assert_same_results(got[1], got[0], exp[1], exp[0])

    assert svc.refresh() == 120
    assert len(svc.index.partitions) == n_parts  # extended, not rebuilt
    got = _stream(svc, workload)  # post-fold
    exp = _offline(svc, workload)
    _assert_same_results(got[1], got[0], exp[1], exp[0])

    # a second insert/delete cycle against the refreshed index, with partial
    # columns (missing ones become NULL and must fail NotNull filters)
    svc.insert(newv[:30], columns={"A": cols["A"][:30]})
    svc.delete([db.n + 120, db.n + 121])
    got = _stream(svc, workload)
    exp = _offline(svc, workload)
    _assert_same_results(got[1], got[0], exp[1], exp[0])


def test_refresh_invalidates_router_cache_and_arena(db, workload):
    """refresh() must clear the Router bitmap cache and grow the arena."""
    svc = _service(db, workload)
    hqi = svc.index
    hqi.search(workload, nprobe=4)  # populate bitmap cache + arena
    assert hqi.router._bitmap_cache and hqi._arena is not None
    n0 = hqi.arena.n

    svc.insert(np.zeros((5, db.d), dtype=np.float32))
    assert svc.refresh() == 5
    assert hqi.router._bitmap_cache == {}  # stale [old_n] bitmaps dropped
    assert hqi.db.n == n0 + 5
    assert hqi.arena.n == n0 + 5  # incremental arena update covers new rows
    assert set(hqi.arena.gid.tolist()) == set(range(n0 + 5))
    # per-partition rows still align with ivf local order
    for p in hqi.partitions:
        assert len(p.rows) == p.ivf.n

    # invalidate_caches drops both derived structures entirely
    hqi.router.template_bitmap(workload.templates[0])  # repopulate cache
    hqi.invalidate_caches()
    assert hqi.router._bitmap_cache == {} and hqi._arena is None


def test_deletes_do_not_invalidate_bitmap_cache(db, workload):
    """Tombstones flow through live_mask, so cached bitmaps stay valid."""
    svc = _service(db, workload)
    svc.drain()
    svc.index.search(workload, nprobe=4)
    cached = dict(svc.index.router._bitmap_cache)
    svc.delete([0, 1, 2])
    assert svc.index.router._bitmap_cache == cached
    got = _stream(svc, workload)
    assert not ({0, 1, 2} & set(got[0].reshape(-1).tolist()))


def test_scheduler_triggers_and_slot_padding():
    sched = MicroBatchScheduler(max_batch=4, deadline_s=0.5, pad_pow2=True)
    vec = np.zeros(8, dtype=np.float32)
    t0 = 100.0
    for i in range(3):
        sched.push(PendingQuery(handle=None, vector=vec, filt=(), t_submit=t0))
    assert not sched.ready(now=t0 + 0.1)  # under size, under deadline
    assert sched.ready(now=t0 + 0.6)  # deadline fired
    sched.push(PendingQuery(handle=None, vector=vec, filt=(), t_submit=t0))
    assert sched.ready(now=t0 + 0.1)  # size fired
    batch = sched.take()
    assert len(batch) == 4 and len(sched) == 0
    wl, n_real = sched.build_workload(batch[:3], k=5)
    assert n_real == 3 and wl.m == 4  # padded to the next power-of-two slot
    assert wl.template_of[3] == wl.template_of[0]


def test_queue_bound_admission(db, workload):
    svc = _service(db, workload, queue_bound=4)
    for i in range(4):
        svc.submit(workload.vectors[i], workload.templates[0])
    with pytest.raises(QueueFull):
        svc.submit(workload.vectors[4], workload.templates[0])
    assert svc.telemetry.summary()["rejected"] == 1
    assert svc.drain() == 4  # draining frees the queue
    svc.submit(workload.vectors[4], workload.templates[0])


def test_delta_store_scan_edges(db):
    delta = DeltaStore(db, first_id=db.n)
    wl = Workload(
        vectors=np.zeros((3, db.d), dtype=np.float32),
        templates=[()],
        template_of=np.zeros(3, dtype=np.int32),
        k=4,
    )
    assert delta.scan(wl) is None  # empty buffer
    ids = delta.insert(np.ones((2, db.d), dtype=np.float32))
    assert list(ids) == [db.n, db.n + 1]
    for i in ids:
        assert delta.delete(int(i))
    assert not delta.delete(int(ids[0]))  # already dead
    assert not delta.delete(0)  # not a buffer row
    assert delta.scan(wl) is None  # all tombstoned
    ids2 = delta.insert(np.full((1, db.d), 2.0, dtype=np.float32))
    s, i = delta.scan(wl)  # k=4 > 1 live row: padded with (-inf, -1)
    assert (i[:, 0] == ids2[0]).all() and (i[:, 1:] == -1).all()
    assert np.isneginf(s[:, 1:]).all()


def test_telemetry_records_flushes(db, workload):
    svc = _service(db, workload, max_batch=16, nprobe=8)
    _stream(svc, workload)
    s = svc.telemetry.summary()
    assert s["queries"] == workload.m
    assert s["flushes"] == int(np.ceil(workload.m / 16))
    assert s["p50_latency_s"] > 0 and s["p99_latency_s"] >= s["p50_latency_s"]
    assert s["merge_dispatches_per_flush"] >= 1


def test_submit_completes_while_flush_in_flight(db, workload):
    """Lock-free flush regression: the kernel pipeline runs OUTSIDE the state
    lock, so submit()/insert()/delete() during a slow flush queue into the
    next micro-batch instead of blocking for the flush duration."""
    svc = _service(db, workload, max_batch=4)
    started, release = threading.Event(), threading.Event()
    orig_search = svc.index.search

    def slow_search(*args, **kwargs):
        started.set()
        assert release.wait(timeout=30), "test harness never released the flush"
        return orig_search(*args, **kwargs)

    svc.index.search = slow_search
    for i in range(3):
        svc.submit(workload.vectors[i], workload.templates[workload.template_of[i]])
    flusher = threading.Thread(target=svc.flush)
    flusher.start()
    assert started.wait(timeout=30), "flush never reached the engine"

    # the flush is mid-pipeline; with the old lock-holding _flush these
    # writes would block until release fires (and this wait would time out)
    wrote = threading.Event()
    state = {}

    def writer():
        state["h"] = svc.submit(
            workload.vectors[3], workload.templates[workload.template_of[3]]
        )
        state["ins"] = svc.insert(np.zeros((2, db.d), dtype=np.float32))
        state["del"] = svc.delete([0])
        wrote.set()

    w = threading.Thread(target=writer)
    w.start()
    assert wrote.wait(timeout=10), "writers blocked behind the in-flight flush"
    w.join()
    release.set()
    flusher.join()

    svc.drain()  # the mid-flight submit answers on the next micro-batch
    assert state["h"].done
    assert state["del"] == 1 and len(state["ins"]) == 2


def test_threaded_service_and_dispatch_stats_thread_safety(db, workload):
    """Background scheduler thread + concurrent submitters; the process-wide
    DispatchStats counter must not lose increments under the race the lock
    now prevents."""
    ops.reset_dispatch_stats()
    base = ops.dispatch_stats().snapshot()

    # raw counter hammering from many threads: exact count must survive
    def hammer():
        for _ in range(500):
            ops.dispatch_stats().record_knn((1, 1, 1, 1))

    threads = [threading.Thread(target=hammer) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ops.dispatch_stats().delta_since(base).knn_calls == 4000
    ops.reset_dispatch_stats()

    svc = _service(db, workload, max_batch=8, deadline_s=0.002, nprobe=8)
    svc.start()
    try:
        handles = []
        for i in range(24):
            while True:
                try:
                    handles.append(
                        svc.submit(
                            workload.vectors[i % workload.m],
                            workload.templates[workload.template_of[i % workload.m]],
                        )
                    )
                    break
                except QueueFull:
                    time.sleep(0.001)
        for h in handles:
            assert h.wait(timeout=120), "service thread never answered"
    finally:
        svc.stop()
    assert svc.telemetry.summary()["queries"] == 24
