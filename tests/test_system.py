"""End-to-end system tests: the related-KG-queries scenario on a KG-shaped

dataset (the paper's running example), the serving loop, and the HLO cost
machinery the roofline depends on."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    HQIConfig, HQIIndex, PreFilterIndex, exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import kg_style, lp_style


@pytest.fixture(scope="module")
def kg():
    return kg_style(n=6000, d=24, queries_per_split=150, seed=7)


def test_related_queries_end_to_end(kg):
    """Build HQI from t0, answer t0 at target recall, beat PreFilter on

    tuples scanned — the paper's headline scenario in miniature."""
    wl = kg.splits[0]
    truth = exhaustive_search(kg.db, wl)
    hqi = HQIIndex.build(kg.db, wl, HQIConfig(min_partition_size=512, max_leaves=32))
    nprobe = tune_nprobe(lambda w, np_: hqi.search(w, nprobe=np_[0]), wl, truth)
    res = hqi.search(wl, nprobe=nprobe)
    assert recall_at_k(res, truth) >= 0.8

    pre = PreFilterIndex.build(kg.db)
    pre_np = tune_nprobe(lambda w, np_: pre.search(w, nprobe=np_[0]), wl, truth)
    res_pre = pre.search(wl, nprobe=pre_np)
    assert recall_at_k(res_pre, truth) >= 0.8
    assert res.tuples_scanned < res_pre.tuples_scanned, (
        res.tuples_scanned, res_pre.tuples_scanned,
    )


def test_workload_selectivity_structure(kg):
    """The generated templates span Table-1-like selectivities (4 decades)."""
    sels = np.array(sorted(kg.selectivities.values()))
    assert sels[0] < 0.005
    assert sels[-1] > 0.3


def test_lp_workload_batching_only():
    db, wl = lp_style(n=3000, d=16, n_queries=100, seed=1)
    truth = exhaustive_search(db, wl)
    pre = PreFilterIndex.build(db)
    res = pre.search(wl, nprobe=1000, batch_vec=True)
    assert recall_at_k(res, truth) == 1.0


def test_serving_loop_matches_unbatched():
    """SlotServer greedy decode == sequential prefill+decode per request."""
    from repro.configs import get_reduced
    from repro.models import api
    from repro.serve.server import Request, SlotServer
    import dataclasses

    cfg = dataclasses.replace(get_reduced("minicpm-2b"), dtype=jnp.float32)
    params = api.init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab, 8).astype(np.int32) for _ in range(5)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)]
    srv = SlotServer(params, cfg, n_slots=3, max_len=32, eos_id=-1)
    srv.run(reqs)
    for p, r in zip(prompts, reqs):
        toks = jnp.asarray(p[None, :], jnp.int32)
        logits, cache = api.serve_prefill(params, cfg, {"tokens": toks}, max_len=32)
        want = [int(jnp.argmax(logits[0]))]
        for _ in range(5):
            logits, cache = api.serve_decode(params, cfg, jnp.asarray([want[-1]], jnp.int32), cache)
            want.append(int(jnp.argmax(logits[0])))
        assert r.out_tokens == want, (r.out_tokens, want)


def test_hlo_cost_trip_counts():
    """The roofline's FLOP accounting must multiply scan bodies by trip count

    (XLA's cost_analysis does not)."""
    from repro.launch import hlo_cost

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    txt = jax.jit(scanned).lower(x, ws).compile().as_text()
    c = hlo_cost.analyze(txt)
    assert c.flops == pytest.approx(12 * 2 * 256**3, rel=1e-6)


def test_roofline_param_counts():
    from repro.configs import get_config
    from repro.launch.roofline import total_params
    from repro.models import api

    # analytic total_params must match the real init within 2%
    for arch in ("minicpm-2b", "deepseek-moe-16b"):
        cfg = get_config(arch)
        sds = api.params_specs(cfg)
        real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(sds))
        approx = total_params(cfg)
        assert abs(real - approx) / real < 0.02, (arch, real, approx)
