"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_knn import fused_knn

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nq,nv,d,k", [(5, 300, 32, 4), (130, 1000, 64, 10), (1, 7, 8, 3), (257, 129, 16, 5)])
@pytest.mark.parametrize("metric", ["ip", "l2"])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_fused_knn_matches_ref(nq, nv, d, k, metric, dtype):
    q = jnp.asarray(RNG.normal(size=(nq, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(nv, d)), dtype)
    valid = jnp.asarray(RNG.random(nv) > 0.3)
    s1, i1 = fused_knn(q, v, valid, k=k, metric=metric, interpret=True)
    s2, i2 = ref.masked_topk_ref(q, v, valid, k, metric)
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=tol, atol=tol)
    # ids: same candidate sets modulo exact-tie ordering
    for r in range(nq):
        a = set(np.asarray(i1)[r][np.asarray(i1)[r] >= 0].tolist())
        b = set(np.asarray(i2)[r][np.asarray(i2)[r] >= 0].tolist())
        if len(a) == len(b) and np.unique(np.asarray(s2)[r]).size == k:
            assert a == b


def test_fused_knn_all_invalid():
    q = jnp.ones((4, 8), jnp.float32)
    v = jnp.ones((64, 8), jnp.float32)
    s, i = fused_knn(q, v, jnp.zeros(64, bool), k=3, interpret=True)
    assert (np.asarray(i) == -1).all()


@pytest.mark.parametrize(
    "b,s,hq,hkv,dh,causal,window",
    [
        (2, 64, 4, 2, 32, True, 0),
        (1, 100, 4, 4, 16, True, 32),
        (2, 33, 8, 2, 64, False, 0),
        (1, 256, 2, 1, 32, True, 64),
    ],
)
def test_flash_attention_matches_ref(b, s, hq, hkv, dh, causal, window):
    q = jnp.asarray(RNG.normal(size=(b, s, hq, dh)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, hkv, dh)), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=causal, window=window, bq=32, bk=32)
    refo = ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2),
        causal=causal, window=window or None,
    )
    np.testing.assert_allclose(np.asarray(out), np.moveaxis(np.asarray(refo), 1, 2), rtol=2e-3, atol=2e-3)


def test_chunked_jax_attention_matches_ref():
    from repro.models.attention import flash_attention as chunked

    q = jnp.asarray(RNG.normal(size=(2, 75, 8, 16)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 75, 4, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 75, 4, 16)), jnp.float32)
    out = chunked(q, k, v, causal=True, q_chunk=16, kv_chunk=32)
    refo = ref.flash_attention_ref(
        jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2), causal=True
    )
    np.testing.assert_allclose(np.asarray(out), np.moveaxis(np.asarray(refo), 1, 2), rtol=2e-3, atol=2e-3)


def test_ops_dispatch_pallas_equals_jnp():
    q = jnp.asarray(RNG.normal(size=(10, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(120, 16)), jnp.float32)
    valid = jnp.asarray(RNG.random(120) > 0.5)
    s1, _ = ops.masked_topk(q, v, valid, 4, metric="l2", use_pallas=True, interpret=True)
    s2, _ = ops.masked_topk(q, v, valid, 4, metric="l2", use_pallas=False)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_batched_masked_topk():
    q = jnp.asarray(RNG.normal(size=(3, 8, 16)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(3, 50, 16)), jnp.float32)
    valid = jnp.asarray(RNG.random((3, 50)) > 0.4)
    s, i = ops.batched_masked_topk(q, v, valid, 4, metric="ip", use_pallas=False)
    for w in range(3):
        s2, i2 = ref.masked_topk_ref(q[w], v[w], valid[w], 4, "ip")
        np.testing.assert_allclose(np.asarray(s[w]), np.asarray(s2), rtol=1e-5)


@pytest.mark.parametrize("nq,nv,d,k,metric", [(5, 300, 32, 4, "ip"), (100, 700, 16, 7, "l2"), (130, 64, 8, 3, "ip")])
def test_fused_knn_db_stationary_matches_ref(nq, nv, d, k, metric):
    from repro.kernels.fused_knn import fused_knn_db_stationary

    q = jnp.asarray(RNG.normal(size=(nq, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(nv, d)), jnp.float32)
    valid = jnp.asarray(RNG.random(nv) > 0.3)
    s1, i1 = fused_knn_db_stationary(q, v, valid, k=k, metric=metric, tq=32, tv=64, interpret=True)
    s2, i2 = ref.masked_topk_ref(q, v, valid, k, metric)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)


def test_pq_scan_kernel_matches_oracle():
    from repro.core.pq import PQIndex, adc_scan_ref, adc_tables
    from repro.kernels.pq_scan import pq_scan

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(500, 32)).astype(np.float32)
    idx = PQIndex.build(vecs, m=4)
    q = rng.normal(size=(3, 32)).astype(np.float32)
    luts = jnp.asarray(adc_tables(idx.cb, q))
    valid = jnp.asarray(rng.random(500) > 0.3)
    for r in range(3):
        s1, i1 = pq_scan(luts[r], jnp.asarray(idx.codes), valid, k=5, tv=128, interpret=True)
        s2, i2 = adc_scan_ref(luts[r : r + 1], jnp.asarray(idx.codes), valid, 5)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2)[0], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("w,tq,nv,m,k", [(3, 5, 100, 4, 6), (1, 8, 700, 8, 10), (4, 2, 30, 4, 3)])
def test_workunit_pq_topk_matches_ref(w, tq, nv, m, k):
    """Batched work-unit ADC kernel: pallas (one-hot MXU contraction) == jnp
    reference == the single-query oracle, with uint8 code tiles."""
    from repro.core.pq import PQIndex, adc_scan_ref, adc_tables

    rng = np.random.default_rng(w * 100 + m)
    d = m * 8
    vecs = rng.normal(size=(max(nv, 300), d)).astype(np.float32)
    idx = PQIndex.build(vecs, m=m)
    luts = np.stack(
        [adc_tables(idx.cb, rng.normal(size=(tq, d)).astype(np.float32)) for _ in range(w)]
    )
    codes = np.stack([idx.codes[rng.integers(0, len(vecs), nv)] for _ in range(w)])
    assert codes.dtype == np.uint8  # ships uint8 across the dispatch boundary
    valid = rng.random((w, nv)) > 0.3
    s_ref, i_ref = ref.workunit_pq_topk_ref(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(valid), k
    )
    s_pl, i_pl = ops.workunit_pq_topk(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(valid), k,
        use_pallas=True, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(s_pl), np.asarray(s_ref), rtol=1e-4, atol=1e-4)
    for w_ in range(w):
        # unit w_ equals the one-query oracle run on its own tables
        s1, _ = adc_scan_ref(
            jnp.asarray(luts[w_]), jnp.asarray(codes[w_]), jnp.asarray(valid[w_]), k
        )
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s_ref)[w_], rtol=1e-4, atol=1e-4)
        for r in range(tq):
            a = np.asarray(i_ref)[w_, r]
            b = np.asarray(i_pl)[w_, r]
            assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())
