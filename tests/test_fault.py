"""Fault injection & self-healing: failpoints, retries, quarantine, chaos.

The load-bearing guarantees:

  * the failpoint registry is deterministic (seeded prob draws, bounded
    counts) and a disarmed site is a no-op — production paths keep their
    instrumentation for free;
  * an fsync fault mid-group-commit means the affected writes are NOT
    acknowledged, the WAL is poisoned (writes fail fast ``ServiceReadOnly``,
    reads keep serving), and a crash + ``open_service`` replays exactly the
    acknowledged prefix;
  * a snapshot-write fault mid-compaction leaves the old generation CURRENT
    and loadable, WAL segments unpruned, and the compactor backing off
    exponentially until a cycle succeeds;
  * a flush crash fails that batch typed (``QueryError``) and the service
    keeps answering with exact parity;
  * per-query deadlines are enforced at admission, take, and fulfill;
  * overload sheds to PQ-approximate scans and recovers with hysteresis;
  * the seeded chaos run (>= 200 queries, >= 5 distinct sites fired) upholds
    the standing invariants: no lost acked write, no hung query, exact
    parity on non-degraded answers.
"""
import os
import threading

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex, train_pq
from repro.fault import FailpointError, failpoints, with_retries
from repro.fault.chaos import ChaosConfig, run_chaos
from repro.service import (
    DeadlineExceeded,
    HQIService,
    QueryError,
    ResultPending,
    ServiceConfig,
    ServiceReadOnly,
)
from repro.store import (
    Compactor,
    WalPoisonedError,
    init_store,
    list_generations,
    load_snapshot,
    open_service,
)

from conftest import assert_same_results, small_db, small_workload

EXACT = 10_000  # nprobe past every list count: search becomes exact


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts and ends with no armed failpoints (process-global)."""
    failpoints.disarm_all()
    yield
    failpoints.disarm_all()


def _service(db, wl, **cfg_kw):
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return HQIService(hqi, ServiceConfig(**kw))


def _store_service(root, db, wl, **cfg_kw):
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return init_store(str(root), hqi, cfg=ServiceConfig(**kw))


def _stream(svc, wl):
    handles = [
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]]) for i in range(wl.m)
    ]
    svc.drain()
    assert all(h.done for h in handles)
    return handles


def _offline(svc, wl):
    """Ground truth: offline HQIIndex.search over the live-DB snapshot."""
    snap = svc.snapshot_db()
    live = svc.live_ids()
    offline = HQIIndex.build(snap, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    res = offline.search(wl, nprobe=EXACT)
    ids = np.where(res.ids >= 0, live[np.maximum(res.ids, 0)], -1)
    return ids, res.scores


@pytest.fixture(scope="module")
def db():
    return small_db(n=1500, seed=11)


@pytest.fixture(scope="module")
def workload(db):
    return small_workload(db, n_queries=40)


def _payload(rng, n, d):
    return (
        rng.normal(size=(n, d)).astype(np.float32),
        {
            "A": rng.random(n).astype(np.float32),
            "B": rng.random(n).astype(np.float32),
            "cat": rng.integers(0, 8, n).astype(np.int32),
            "tags": (rng.random((n, 6)) < 0.3),
        },
    )


# ---------------------------------------------------------------------------
# Failpoint registry
# ---------------------------------------------------------------------------


def test_failpoint_disarmed_is_noop():
    assert failpoints.failpoint("wal.fsync") is None
    assert failpoints.fired("wal.fsync") == 0
    assert failpoints.evaluated("wal.fsync") == 0
    assert not failpoints._ACTIVE


def test_failpoint_arm_count_and_heal():
    failpoints.arm("wal.fsync", "oserror", count=2)
    for _ in range(2):
        with pytest.raises(OSError):
            failpoints.failpoint("wal.fsync")
    # count exhausted: the site healed
    failpoints.failpoint("wal.fsync")
    assert failpoints.fired("wal.fsync") == 2
    assert failpoints.evaluated("wal.fsync") == 3
    failpoints.disarm("wal.fsync")
    assert not failpoints._ACTIVE


def test_failpoint_skip_and_prob_determinism():
    failpoints.arm("service.flush", FailpointError, skip=3)
    for _ in range(3):
        failpoints.failpoint("service.flush")
    with pytest.raises(FailpointError):
        failpoints.failpoint("service.flush")

    def draws(seed):
        failpoints.arm("scheduler.tick", "runtimeerror", prob=0.5, seed=seed)
        out = []
        for _ in range(20):
            try:
                failpoints.failpoint("scheduler.tick")
                out.append(0)
            except RuntimeError:
                out.append(1)
        failpoints.disarm("scheduler.tick")
        return out

    a, b = draws(7), draws(7)
    assert a == b  # seeded prob draws are reproducible
    assert 0 < sum(a) < 20


def test_failpoint_strict_and_error_forms():
    with pytest.raises(KeyError):
        failpoints.arm("no.such.site", "oserror")
    failpoints.arm("no.such.site", "oserror", strict=False)
    with pytest.raises(OSError):
        failpoints.failpoint("no.such.site")
    failpoints.disarm("no.such.site")
    # ready instance raised as-is; factory gets the site name
    sentinel = ValueError("sentinel")
    with failpoints.armed("wal.stage", sentinel):
        with pytest.raises(ValueError) as ei:
            failpoints.failpoint("wal.stage")
        assert ei.value is sentinel
    with failpoints.armed("wal.stage", lambda site: KeyError(site)):
        with pytest.raises(KeyError, match="wal.stage"):
            failpoints.failpoint("wal.stage")
    assert not failpoints._ACTIVE


def test_failpoint_env_grammar():
    failpoints._arm_from_env("wal.fsync=oserror:p0.25:n3:s2:seed9, custom.site=")
    armed = failpoints.list_armed()
    assert armed["wal.fsync"] == {"prob": 0.25, "remaining": 3, "skip": 2}
    assert armed["custom.site"]["prob"] == 1.0
    with pytest.raises(FailpointError):
        failpoints.failpoint("custom.site")
    with pytest.raises(ValueError):
        failpoints._arm_from_env("wal.fsync=oserror:x3")


def test_with_retries_transient_and_fatal():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise OSError("transient")
        return "ok"

    waited = []
    assert (
        with_retries(flaky, attempts=3, sleep=waited.append) == "ok"
    )
    assert len(calls) == 3 and len(waited) == 2
    assert waited[1] > 0  # backoff grows (jittered, but never zero after base)

    with pytest.raises(OSError):  # budget exhausted: last error propagates
        with_retries(lambda: (_ for _ in ()).throw(OSError("always")),
                     attempts=2, sleep=lambda _s: None)
    with pytest.raises(ValueError):  # non-retryable: immediate, single call
        with_retries(lambda: (_ for _ in ()).throw(ValueError("fatal")),
                     attempts=5, sleep=lambda _s: None)


# ---------------------------------------------------------------------------
# WAL: transient fsync retry, poisoning quarantine, stage abort
# ---------------------------------------------------------------------------


def test_wal_fsync_transient_fault_retried(tmp_path, db, workload):
    """A transient fsync fault is absorbed by the retry budget: the insert
    acks normally and the WAL stays healthy."""
    svc = _store_service(tmp_path, db, workload)
    rng = np.random.default_rng(0)
    vecs, cols = _payload(rng, 3, db.d)
    with failpoints.armed("wal.fsync", "oserror", count=1):
        ids = svc.insert(vecs, cols)
    assert failpoints.fired("wal.fsync") == 1
    assert svc.wal.poisoned is None
    assert svc.wal.synced_seq == svc._applied_seq
    assert set(ids.tolist()) <= set(svc.live_ids().tolist())


def test_wal_fsync_poison_quarantine_heal_and_replay(tmp_path, db, workload):
    """fsync failing past its retry budget mid-group-commit: the writers in
    that commit are NOT acked, the service turns read-only (reads still
    serve), clear_poison() heals it, and a crash + open_service replays
    exactly the acknowledged writes."""
    svc = _store_service(tmp_path, db, workload)
    rng = np.random.default_rng(1)
    vecs, cols = _payload(rng, 4, db.d)
    acked = svc.insert(vecs, cols)

    # two concurrent writers share the poisoned group commit: neither acks
    errs = {}

    def writer(name, seed):
        v, c = _payload(np.random.default_rng(seed), 2, db.d)
        try:
            svc.insert(v, c)
        except BaseException as e:
            errs[name] = e

    with failpoints.armed("wal.fsync", "oserror", count=svc.wal.fsync_retries * 2):
        ts = [threading.Thread(target=writer, args=(n, 50 + n)) for n in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    assert set(errs) == {0, 1}
    assert all(isinstance(e, (OSError, WalPoisonedError)) for e in errs.values())
    assert svc.wal.poisoned is not None

    # quarantined: writes fail fast, reads keep serving
    with pytest.raises(ServiceReadOnly):
        svc.insert(vecs, cols)
    with pytest.raises(ServiceReadOnly):
        svc.delete([0])
    h = svc.health()
    assert h.status == "read-only" and h.read_only and h.write_error
    handles = _stream(svc, workload)
    assert all(hd.ok for hd in handles)

    # operator heal: the disk is "fixed", writes resume
    svc.wal.clear_poison()
    assert svc.health().status == "ok"
    vecs2, cols2 = _payload(rng, 2, db.d)
    acked2 = svc.insert(vecs2, cols2)

    # crash + recover: every acked write survives with the same ids
    svc.wal.close()
    rec = open_service(str(tmp_path), cfg=svc.cfg)
    live = set(rec.live_ids().tolist())
    assert set(acked.tolist()) <= live
    assert set(acked2.tolist()) <= live
    got = _stream(rec, workload)
    exp = _offline(rec, workload)
    assert_same_results(
        np.stack([h.scores for h in got]), np.stack([h.ids for h in got]), exp[1], exp[0]
    )
    rec.wal.close()


def test_wal_stage_fault_releases_id_reservation(tmp_path, db, workload):
    """A stage failure never reaches the log, so its id reservation is
    released: the next insert gets the same ids and recovery agrees."""
    svc = _store_service(tmp_path, db, workload)
    rng = np.random.default_rng(2)
    vecs, cols = _payload(rng, 3, db.d)
    next_id = svc.index.db.n + svc.delta.n
    with failpoints.armed("wal.stage", "oserror"):
        with pytest.raises(OSError):
            svc.insert(vecs, cols)
    ids = svc.insert(vecs, cols)
    assert ids[0] == next_id  # no id gap from the aborted reservation
    svc.wal.close()
    rec = open_service(str(tmp_path), cfg=svc.cfg)
    assert set(ids.tolist()) <= set(rec.live_ids().tolist())
    rec.wal.close()


def test_delta_apply_poison_heals_on_restart(tmp_path, db, workload):
    """An apply failure AFTER the WAL logged the record quarantines the write
    path permanently in-process (the log and memory diverged), but restart +
    replay heals: the logged record's rows are live after recovery."""
    svc = _store_service(tmp_path, db, workload)
    rng = np.random.default_rng(3)
    vecs, cols = _payload(rng, 2, db.d)
    with failpoints.armed("delta.apply", "runtimeerror"):
        with pytest.raises(RuntimeError):
            svc.insert(vecs, cols)
    assert svc._write_poisoned is not None
    with pytest.raises(ServiceReadOnly):
        svc.insert(vecs, cols)
    assert svc.health().status == "read-only"
    handles = _stream(svc, workload)  # reads unaffected
    assert all(h.ok for h in handles)

    logged_seq = svc.wal.last_seq
    svc.wal.close()
    rec = open_service(str(tmp_path), cfg=svc.cfg)
    assert rec._applied_seq == logged_seq  # the diverged record replayed
    assert rec.health().status == "ok"
    ids2 = rec.insert(vecs, cols)
    assert set(ids2.tolist()) <= set(rec.live_ids().tolist())
    rec.wal.close()


# ---------------------------------------------------------------------------
# Compaction: snapshot-write faults, exponential backoff
# ---------------------------------------------------------------------------


def test_snapshot_write_fault_keeps_old_generation_current(tmp_path, db, workload):
    svc = _store_service(tmp_path, db, workload)
    comp = Compactor(svc, str(tmp_path), interval_s=0.5, keep_generations=2)
    rng = np.random.default_rng(4)
    vecs, cols = _payload(rng, 5, db.d)
    ids = svc.insert(vecs, cols)

    gens_before = list_generations(str(tmp_path))
    with open(os.path.join(str(tmp_path), "CURRENT")) as f:
        current_before = f.read()
    segs_before = svc.wal.segments()

    # past the retry budget: every attempt on the first blob fails
    with failpoints.armed("snapshot.write", "oserror", count=100):
        with pytest.raises(OSError):
            comp.compact_once(force=True)
    assert comp.consecutive_failures == 1
    assert comp.last_error is not None
    assert comp._backoff_s() == pytest.approx(comp.interval_s * 2.0)
    # CURRENT still points at the old generation; nothing was pruned
    with open(os.path.join(str(tmp_path), "CURRENT")) as f:
        assert f.read() == current_before
    assert list_generations(str(tmp_path)) == gens_before
    assert svc.wal.segments() == segs_before
    assert svc.health().compactor_failures == 1
    # the old generation still loads and serves
    assert load_snapshot(str(tmp_path)).index is not None

    # repeated failures inflate the backoff exponentially (capped)
    with failpoints.armed("snapshot.write", "oserror", count=100):
        for _ in range(2):
            with pytest.raises(OSError):
                comp.compact_once(force=True)
    assert comp.consecutive_failures == 3
    assert comp._backoff_s() == pytest.approx(comp.interval_s * 8.0)
    comp.max_backoff_s = 1.0
    assert comp._backoff_s() == 1.0  # cap

    # success resets the health and writes a fresh generation
    name = comp.compact_once(force=True)
    assert name is not None
    assert comp.consecutive_failures == 0 and comp.last_error is None
    assert comp._backoff_s() == comp.interval_s
    assert svc.health().compactor_failures == 0
    rec_live = set(load_snapshot(str(tmp_path)).index.db.ids.tolist())
    assert set(ids.tolist()) <= rec_live
    svc.wal.close()


def test_snapshot_write_transient_fault_retried(tmp_path, db, workload):
    """One blob-write fault inside the retry budget: the cycle still lands."""
    svc = _store_service(tmp_path, db, workload)
    comp = Compactor(svc, str(tmp_path))
    rng = np.random.default_rng(5)
    vecs, cols = _payload(rng, 3, db.d)
    svc.insert(vecs, cols)
    with failpoints.armed("snapshot.write", "oserror", count=1):
        assert comp.compact_once(force=True) is not None
    assert comp.consecutive_failures == 0
    svc.wal.close()


# ---------------------------------------------------------------------------
# Serving: flush crash containment, deadlines, result(), overload
# ---------------------------------------------------------------------------


def test_flush_crash_contained_and_service_keeps_answering(db, workload):
    svc = _service(db, workload)
    with failpoints.armed("service.flush", "runtimeerror", count=1):
        handles = [svc.submit(workload.vectors[i]) for i in range(4)]
        svc.drain()
    assert all(h.done and not h.ok for h in handles)
    for h in handles:
        assert isinstance(h.error, QueryError)
        assert isinstance(h.error.cause, RuntimeError)
        with pytest.raises(QueryError):
            h.result()
    assert svc.telemetry.summary()["flush_failures"] == 1

    # the very next stream answers, with exact parity
    got = _stream(svc, workload)
    assert all(h.ok for h in got)
    exp = _offline(svc, workload)
    assert_same_results(
        np.stack([h.scores for h in got]), np.stack([h.ids for h in got]), exp[1], exp[0]
    )


def test_background_loop_survives_flush_and_tick_faults(db, workload):
    """start()'s loop must outlive injected tick/flush crashes: queries
    submitted after the faults heal are still answered."""
    svc = _service(db, workload, deadline_s=0.001)
    failpoints.arm("scheduler.tick", "runtimeerror", count=2)
    failpoints.arm("service.flush", "runtimeerror", count=1)
    svc.start(poll_s=0.002)
    try:
        handles = [svc.submit(workload.vectors[i]) for i in range(6)]
        for h in handles:
            assert h.wait(timeout=30.0)
        failpoints.disarm_all()
        h_ok = svc.submit(workload.vectors[0])
        assert h_ok.wait(timeout=30.0) and h_ok.ok
    finally:
        svc.stop(drain=False)
    assert svc.telemetry.summary()["loop_errors"] >= 1


def test_deadline_admission_and_expiry(db, workload):
    svc = _service(db, workload)
    with pytest.raises(DeadlineExceeded):  # lapsed at admission: never queued
        svc.submit(workload.vectors[0], deadline_s=0.0)
    assert len(svc.scheduler) == 0

    h = svc.submit(workload.vectors[0], deadline_s=1e-6)
    h_ok = svc.submit(workload.vectors[1], deadline_s=60.0)
    svc.drain()
    assert h.done and isinstance(h.error, DeadlineExceeded)
    with pytest.raises(DeadlineExceeded):
        h.result()
    assert h_ok.ok and h_ok.error is None
    assert svc.telemetry.summary()["deadline_expired"] >= 2

    # config default applies when submit() omits deadline_s
    svc2 = _service(db, workload, query_deadline_s=1e-6)
    h2 = svc2.submit(workload.vectors[0])
    svc2.drain()
    assert isinstance(h2.error, DeadlineExceeded)


def test_result_semantics(db, workload):
    svc = _service(db, workload)
    h = svc.submit(workload.vectors[0])
    with pytest.raises(ResultPending):  # non-blocking accessor
        h.result()
    with pytest.raises(DeadlineExceeded):  # bounded wait on an unflushed queue
        h.result(timeout=0.01)
    svc.drain()
    ids, scores = h.result()
    assert ids.shape == (workload.k,) and scores.shape == (workload.k,)
    ids2, scores2 = h.result(timeout=5.0)  # idempotent accessor
    np.testing.assert_array_equal(ids, ids2)
    np.testing.assert_array_equal(scores, scores2)


def test_overload_degrade_and_recover(db, workload):
    """Queue pressure sheds flushes to PQ-approximate scans; hysteresis
    recovers once the queue drains below the recovery fraction."""
    svc = _service(
        db,
        workload,
        max_batch=8,
        overload_queue_depth=16,
        degraded_refine_factor=4,
    )
    svc.index.attach_pq(train_pq(db.vectors, m=4, metric=db.metric))
    handles = [
        svc.submit(workload.vectors[i % workload.m]) for i in range(64)
    ]
    first = svc.flush()  # post-take depth 56 >> 16: enters degraded
    assert first == 8
    assert svc._degraded and svc.health().status == "degraded"
    assert all(h.degraded for h in handles[:8] if h.ok)
    svc.drain()  # queue empties; hysteresis exit at depth <= 8
    assert not svc._degraded and svc.health().status == "ok"
    assert all(h.done for h in handles)
    t = svc.telemetry.summary()
    assert t["degraded_flushes"] >= 1
    assert t["degraded_transitions"] >= 2  # enter + exit

    # post-recovery answers are exact again (threshold off so the 40-query
    # parity stream itself doesn't re-trigger the shed)
    svc.cfg.overload_queue_depth = None
    got = _stream(svc, workload)
    assert not any(h.degraded for h in got)
    exp = _offline(svc, workload)
    assert_same_results(
        np.stack([h.scores for h in got]), np.stack([h.ids for h in got]), exp[1], exp[0]
    )


def test_overload_needs_codebook(db, workload):
    """An index without a codebook never sheds, whatever the pressure."""
    svc = _service(db, workload, max_batch=8, overload_queue_depth=2)
    for i in range(32):
        svc.submit(workload.vectors[i % workload.m])
    svc.drain()
    assert not svc._degraded
    assert svc.telemetry.summary()["degraded_flushes"] == 0


def test_health_reports_armed_failpoints(db, workload):
    svc = _service(db, workload)
    h = svc.health()
    assert h.status == "ok" and h.armed_failpoints == ()
    assert h.wal_synced_seq is None  # in-memory service
    with failpoints.armed("service.flush", "runtimeerror"):
        assert "service.flush" in svc.health().armed_failpoints
    d = svc.health().as_dict()
    assert d["status"] == "ok" and isinstance(d["armed_failpoints"], list)


# ---------------------------------------------------------------------------
# Chaos: the full seeded invariants run
# ---------------------------------------------------------------------------


def test_chaos_invariants(tmp_path):
    """>= 200 queries against a live store under randomized (seeded) faults —
    transient WAL/snapshot/flush/tick errors, an fsync poisoning round, a
    SIGKILLed writer subprocess — upholding the three standing invariants:
    every acked write survives recovery, every query terminates, and every
    non-degraded successful answer exactly matches the offline reference."""
    cfg = ChaosConfig(seed=0, rounds=4, queries_per_round=50)
    rep = run_chaos(str(tmp_path), cfg)
    assert rep.ok, rep.as_dict()
    assert rep.queries_submitted >= 200
    assert rep.answered_ok > 0 and rep.writes_acked > 0
    assert rep.recovery_checks >= 1
    assert rep.hung == 0
    assert rep.parity_mismatches == 0
    assert rep.recovery_violations == 0
    # fault coverage: >= 5 distinct sites actually fired, including the two
    # highest-stakes ones (durability fsync and the answer pipeline)
    assert len(rep.sites_fired) >= 5, rep.sites_fired
    assert "wal.fsync" in rep.sites_fired
    assert "service.flush" in rep.sites_fired
