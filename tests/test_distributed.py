"""Multi-device tests (8 host devices via subprocess — the dry-run owns 512;

tests use a small pool so the rest of the suite sees 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_distributed_hqi_search_matches_single_device():
    """HQI search through the sharded engine on a (data, model) mesh is
    bit-identical to the single-device engine — bitmap pushdown, per-template
    nprobe, and the adaptive path included (the deep sweep lives in
    tests/test_engine_sharded.py)."""
    run_with_devices("""
        import sys, numpy as np, jax
        sys.path.insert(0, %r)
        from conftest import small_db, small_workload
        from repro.core import HQIConfig, HQIIndex
        from repro.launch.mesh import make_test_mesh

        db = small_db()
        wl = small_workload(db)
        hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=32))
        ref = hqi.search(wl, nprobe=6, batch_vec=True)
        hqi.cfg.mesh = make_test_mesh((2, 4), ("data", "model"))
        res = hqi.search(wl, nprobe=6, batch_vec=True)
        assert np.array_equal(ref.scores, res.scores)
        assert np.array_equal(ref.ids, res.ids)
        st = res.shard_stats
        assert st is not None and st.n_shards == 4
        # cross-rank traffic is the per-query candidate gather: O(k·|model|)
        assert st.gathered_per_query == 4 * wl.k, st.gathered_per_query
        assert st.per_rank_bytes.sum() > 0
        print("distributed HQI search OK")
    """ % os.path.join(REPO, "tests"))


def test_pjit_train_step_on_mesh():
    """Sharded train step on a 2×4 mesh == single-device step (same loss)."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import ShardingRules, tree_param_specs, use_rules
        from repro.models import api
        from repro.train.optimizer import OptConfig, init_opt
        from repro.train.train_step import TrainConfig, make_train_step

        cfg = get_reduced("qwen3-32b")
        mesh = make_test_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(mesh=mesh, fsdp=True)
        tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=5), microbatches=2)
        params = api.init_model(cfg, jax.random.key(0))
        opt = init_opt(params, tcfg.opt)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)), jnp.int32),
        }
        # single-device reference
        p1, o1, m1 = jax.jit(make_train_step(cfg, tcfg))(params, opt, batch)

        specs = tree_param_specs(params, rules)
        shard = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
        params_s = jax.tree.map(shard, params, specs, is_leaf=lambda x: hasattr(x, "shape"))
        ospecs = tree_param_specs(opt, rules)
        opt_s = jax.tree.map(shard, opt, ospecs, is_leaf=lambda x: hasattr(x, "shape"))
        batch_s = {k: jax.device_put(v, NamedSharding(mesh, P("data"))) for k, v in batch.items()}
        with mesh, use_rules(rules):
            p2, o2, m2 = jax.jit(make_train_step(cfg, tcfg))(params_s, opt_s, batch_s)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2, (float(m1["loss"]), float(m2["loss"]))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-2, d
        print("pjit train step OK", float(m1["loss"]), float(m2["loss"]))
    """)


def test_compressed_dp_training():
    """int8+error-feedback DP training tracks uncompressed closely."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.mesh import make_test_mesh
        from repro.models import api
        from repro.train.optimizer import OptConfig, init_opt
        from repro.train.fault_tolerance import dp_train_step_compressed
        from repro.train.train_step import TrainConfig, make_train_step
        from repro.distributed.compression import zero_residual

        cfg = get_reduced("minicpm-2b")
        mesh = make_test_mesh((4,), ("data",))
        ocfg = OptConfig(peak_lr=2e-3, warmup_steps=1, total_steps=30)
        params = api.init_model(cfg, jax.random.key(0))
        opt = init_opt(params, ocfg)
        res = zero_residual(params)
        step_c = dp_train_step_compressed(cfg, ocfg, mesh)
        pc, oc = params, opt
        tcfg = TrainConfig(opt=ocfg)
        step_u = jax.jit(make_train_step(cfg, tcfg))
        pu, ou = params, opt
        rng = np.random.default_rng(0)
        lc = lu = None
        for s in range(12):
            batch = {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
            }
            with mesh:
                pc, oc, res, mc = step_c(pc, oc, res, batch)
            pu, ou, mu = step_u(pu, ou, batch)
            lc, lu = float(mc["loss"]), float(mu["loss"])
        assert lc < 6.0 and abs(lc - lu) < 0.35, (lc, lu)
        print("compressed DP OK", lc, lu)
    """)


def test_elastic_remesh_degraded():
    """Preferred (16, 1) mesh on 8 devices degrades to (8, 1) and still runs."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.train.fault_tolerance import elastic_mesh
        m = elastic_mesh((16, 1), ("data", "model"))
        assert m.shape["data"] == 8, m.shape
        print("elastic mesh OK", dict(m.shape))
    """)


def test_moe_ep_matches_dense():
    """shard_map expert-parallel MoE == dense formulation (dropless regime)."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_test_mesh
        from repro.distributed.sharding import ShardingRules, use_rules
        from repro.models.moe import MoEConfig, init_moe, moe_layer_dense, moe_layer_ep

        mesh = make_test_mesh((4, 2), ("data", "model"))
        cfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, capacity_factor=64.0)
        p = init_moe(jax.random.key(0), 16, cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(8, 4, 16)).astype(np.float32))
        y_ref, aux_ref = moe_layer_dense(p, x, cfg)
        rules = ShardingRules(mesh=mesh)
        with mesh, use_rules(rules):
            y_ep, aux_ep = jax.jit(lambda p, x: moe_layer_ep(p, x, cfg, rules))(p, x)
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_ref), rtol=2e-3, atol=2e-3)
        assert abs(float(aux_ep["lb_loss"]) - float(aux_ref["lb_loss"])) < 1e-3
        print("EP MoE OK")
    """)


def test_dryrun_machinery_small_mesh():
    """The dry-run pipeline (rules → shardings → lower → compile → hlo_cost)

    end-to-end on an 8-device mesh with a reduced config."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.launch.mesh import make_test_mesh
        from repro.launch import hlo_cost
        from repro.distributed.sharding import ShardingRules, tree_param_specs, use_rules
        from repro.models import api
        from repro.train.optimizer import OptConfig, init_opt
        from repro.train.train_step import TrainConfig, make_train_step

        cfg = get_reduced("deepseek-moe-16b")
        mesh = make_test_mesh((2, 4), ("data", "model"))
        rules = ShardingRules(mesh=mesh, fsdp=True)
        tcfg = TrainConfig(opt=OptConfig(), microbatches=2)
        params0 = api.params_specs(cfg)
        pspecs = tree_param_specs(params0, rules)
        pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                              is_leaf=lambda x: isinstance(x, P))
        params = jax.tree.map(lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s),
                              params0, pshard)
        opt0 = jax.eval_shape(lambda p: init_opt(p, tcfg.opt), params0)
        ospecs = tree_param_specs(opt0, rules)
        opt = jax.tree.map(lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                           sharding=NamedSharding(mesh, s)), opt0, ospecs,
                           is_leaf=lambda x: hasattr(x, "shape"))
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32, sharding=NamedSharding(mesh, P("data"))),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32, sharding=NamedSharding(mesh, P("data"))),
        }
        with mesh, use_rules(rules):
            compiled = jax.jit(make_train_step(cfg, tcfg)).lower(params, opt, batch).compile()
        cost = hlo_cost.analyze(compiled.as_text())
        assert cost.flops > 0 and cost.bytes > 0
        ma = compiled.memory_analysis()
        assert int(ma.argument_size_in_bytes) > 0
        print("dryrun machinery OK", f"{cost.flops:.2e}")
    """)
