"""Vectorized recall metrics == the per-query set semantics they replaced."""
import numpy as np

from repro.core import recall_at_k
from repro.core.metrics import per_template_recall
from repro.core.types import SearchResult, Workload


def _recall_sets(result, truth):
    """The original per-query set-intersection definition (oracle)."""
    hits = total = 0
    for i in range(truth.ids.shape[0]):
        t = set(int(x) for x in truth.ids[i] if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in result.ids[i] if x >= 0)
        hits += len(t & r)
        total += len(t)
    return hits / max(total, 1)


def _random_results(rng, m, k, n_ids=200, pad_frac=0.2):
    ids = rng.integers(0, n_ids, size=(m, k))
    # distinct ids per row (top-k over distinct tuples), some -1 padding rows
    for r in range(m):
        ids[r] = rng.choice(n_ids, size=k, replace=False)
        npad = rng.integers(0, max(1, int(k * pad_frac) + 1))
        if npad:
            ids[r, k - npad :] = -1
    return SearchResult(ids=ids.astype(np.int64), scores=np.zeros((m, k), np.float32))


def test_recall_matches_set_semantics():
    rng = np.random.default_rng(0)
    for m, k in [(40, 10), (7, 3), (100, 5)]:
        res = _random_results(rng, m, k)
        tru = _random_results(rng, m, k)
        assert recall_at_k(res, tru) == _recall_sets(res, tru)


def test_recall_all_empty_truth():
    res = SearchResult(ids=np.zeros((4, 3), np.int64), scores=np.zeros((4, 3), np.float32))
    tru = SearchResult(ids=np.full((4, 3), -1, np.int64), scores=np.zeros((4, 3), np.float32))
    assert recall_at_k(res, tru) == 0.0


def test_recall_result_k_differs_from_truth_k():
    """Broadcasting handles k_result != k_truth (over-fetch / refine shapes)."""
    rng = np.random.default_rng(1)
    res = _random_results(rng, 20, 12)
    tru = _random_results(rng, 20, 5)
    wide = SearchResult(ids=res.ids[:, :5], scores=res.scores[:, :5])
    assert recall_at_k(res, tru) >= recall_at_k(wide, tru)


def test_per_template_recall_matches_per_slice():
    rng = np.random.default_rng(2)
    m, k = 60, 5
    res = _random_results(rng, m, k)
    tru = _random_results(rng, m, k)
    wl = Workload(
        vectors=np.zeros((m, 4), np.float32),
        templates=[(), ((),), ((), ())],  # 3 distinct dummy templates
        template_of=(np.arange(m) % 3).astype(np.int32),
        k=k,
    )
    got = per_template_recall(res, tru, wl)
    for ti in range(3):
        qidx = wl.queries_for_template(ti)
        sub_r = SearchResult(ids=res.ids[qidx], scores=res.scores[qidx])
        sub_t = SearchResult(ids=tru.ids[qidx], scores=tru.scores[qidx])
        assert got[ti] == _recall_sets(sub_r, sub_t)
