"""Vectorized recall metrics == the per-query set semantics they replaced."""
import numpy as np

from repro.core import recall_at_k
from repro.core.metrics import per_template_recall, tune_nprobe
from repro.core.types import SearchResult, Workload


def _recall_sets(result, truth):
    """The original per-query set-intersection definition (oracle)."""
    hits = total = 0
    for i in range(truth.ids.shape[0]):
        t = set(int(x) for x in truth.ids[i] if x >= 0)
        if not t:
            continue
        r = set(int(x) for x in result.ids[i] if x >= 0)
        hits += len(t & r)
        total += len(t)
    return hits / max(total, 1)


def _random_results(rng, m, k, n_ids=200, pad_frac=0.2):
    ids = rng.integers(0, n_ids, size=(m, k))
    # distinct ids per row (top-k over distinct tuples), some -1 padding rows
    for r in range(m):
        ids[r] = rng.choice(n_ids, size=k, replace=False)
        npad = rng.integers(0, max(1, int(k * pad_frac) + 1))
        if npad:
            ids[r, k - npad :] = -1
    return SearchResult(ids=ids.astype(np.int64), scores=np.zeros((m, k), np.float32))


def test_recall_matches_set_semantics():
    rng = np.random.default_rng(0)
    for m, k in [(40, 10), (7, 3), (100, 5)]:
        res = _random_results(rng, m, k)
        tru = _random_results(rng, m, k)
        assert recall_at_k(res, tru) == _recall_sets(res, tru)


def test_recall_all_empty_truth():
    res = SearchResult(ids=np.zeros((4, 3), np.int64), scores=np.zeros((4, 3), np.float32))
    tru = SearchResult(ids=np.full((4, 3), -1, np.int64), scores=np.zeros((4, 3), np.float32))
    assert recall_at_k(res, tru) == 0.0


def test_recall_result_k_differs_from_truth_k():
    """Broadcasting handles k_result != k_truth (over-fetch / refine shapes)."""
    rng = np.random.default_rng(1)
    res = _random_results(rng, 20, 12)
    tru = _random_results(rng, 20, 5)
    wide = SearchResult(ids=res.ids[:, :5], scores=res.scores[:, :5])
    assert recall_at_k(res, tru) >= recall_at_k(wide, tru)


def _unreachable_search_fn(probed):
    """A search that never reaches the recall target; records every nprobe
    it was asked to evaluate."""

    def fn(sub, nprobe_map):
        (npv,) = nprobe_map.values()
        probed.append(int(npv))
        m = sub.m
        return SearchResult(
            ids=np.full((m, sub.k), -2 - npv, np.int64),  # never matches truth
            scores=np.zeros((m, sub.k), np.float32),
        )

    return fn


def test_tune_nprobe_never_returns_unprobed_value():
    """Regression: the doubling search probed 1,2,4,... then returned
    ``min(np_t, max_nprobe)`` — a non-power-of-two cap (100) came back
    UNTESTED after only 64 was evaluated. Every returned nprobe must have
    been evaluated."""
    m, k = 8, 3
    wl = Workload(
        vectors=np.zeros((m, 4), np.float32),
        templates=[()],
        template_of=np.zeros(m, np.int32),
        k=k,
    )
    truth = SearchResult(
        ids=np.arange(m * k, dtype=np.int64).reshape(m, k),
        scores=np.zeros((m, k), np.float32),
    )
    probed = []
    got = tune_nprobe(
        _unreachable_search_fn(probed), wl, truth, target_recall=0.9, max_nprobe=100
    )
    assert got[0] == 100  # the cap is returned when recall is unreachable...
    assert 100 in probed  # ...and it was actually evaluated, not clamped in
    assert all(v in probed for v in got.values())
    # power-of-two caps keep the original ladder behavior
    probed2 = []
    got2 = tune_nprobe(
        _unreachable_search_fn(probed2), wl, truth, target_recall=0.9, max_nprobe=64
    )
    assert got2[0] == 64 and probed2 == [1, 2, 4, 8, 16, 32, 64]


def test_tune_nprobe_stops_at_target():
    """The ladder still stops at the first nprobe reaching the target."""
    m, k = 4, 2
    wl = Workload(
        vectors=np.zeros((m, 4), np.float32),
        templates=[()],
        template_of=np.zeros(m, np.int32),
        k=k,
    )
    truth = SearchResult(
        ids=np.arange(m * k, dtype=np.int64).reshape(m, k),
        scores=np.zeros((m, k), np.float32),
    )

    def fn(sub, nprobe_map):
        (npv,) = nprobe_map.values()
        ids = truth.ids if npv >= 4 else np.full((m, k), -1, np.int64)
        return SearchResult(ids=ids, scores=np.zeros((m, k), np.float32))

    got = tune_nprobe(fn, wl, truth, target_recall=0.8, max_nprobe=100)
    assert got[0] == 4


def test_per_template_recall_matches_per_slice():
    rng = np.random.default_rng(2)
    m, k = 60, 5
    res = _random_results(rng, m, k)
    tru = _random_results(rng, m, k)
    wl = Workload(
        vectors=np.zeros((m, 4), np.float32),
        templates=[(), ((),), ((), ())],  # 3 distinct dummy templates
        template_of=(np.arange(m) % 3).astype(np.int32),
        k=k,
    )
    got = per_template_recall(res, tru, wl)
    for ti in range(3):
        qidx = wl.queries_for_template(ti)
        sub_r = SearchResult(ids=res.ids[qidx], scores=res.scores[qidx])
        sub_t = SearchResult(ids=tru.ids[qidx], scores=tru.scores[qidx])
        assert got[ti] == _recall_sets(sub_r, sub_t)
