"""Mesh-parity suite: the sharded engine must be BIT-IDENTICAL to the
single-device engine.

Every test spawns a subprocess with 8 virtual host devices
(``--xla_force_host_platform_device_count``, the test_distributed.py
harness) and sweeps mesh sizes {1, 2, 4, 8} *inside* one subprocess —
``make_test_mesh((R,), ("model",))`` takes a prefix of the device pool, so
one jax init serves every mesh size. Assertions are exact array equality of
ids AND scores (``use_pallas=False`` pins both paths to the jnp kernels so
the comparison is bitwise-meaningful regardless of the CI backend matrix).

Covered: metrics {ip, l2} × scan_mode {"f32", "pq"}, bitmap pushdown, the
adaptive per-query path, per-template nprobe dicts; edges: shard-skewed
splits, an empty shard, k larger than any shard's rows, all-false bitmaps.
Communication structure: ShardStats.gathered_per_query must be exactly
O(k·|model|) and independent of DB size. The hypothesis property test
(random workloads × random shard bounds) additionally asserts per-rank work
units always PARTITION the single-device plan's units.
"""
import os
import textwrap

import pytest

from test_distributed import REPO, run_with_devices

TESTS = os.path.join(REPO, "tests")


def run(body: str, n: int = 8) -> str:
    """run_with_devices with the shared prelude (dedent body first: the
    prelude sits at column 0, so the harness's own dedent would no-op)."""
    return run_with_devices(PRELUDE + textwrap.dedent(body), n=n)

# Shared subprocess prelude: data + index builders and the exact-parity
# assertion. Mesh sizes take prefixes of the 8-device pool.
PRELUDE = f"""
import sys
sys.path.insert(0, {TESTS!r})
import numpy as np, jax
from jax.sharding import Mesh
from conftest import small_db, small_workload
from repro.core import HQIConfig, HQIIndex, PackedArena
from repro.core.ivf import IVFIndex
from repro.core.plan import PlanConfig
from repro.core.planner import batch_search_ivf
from repro.core.pq import train_pq

MESH_SIZES = (1, 2, 4, 8)

def mesh_of(r):
    return Mesh(np.asarray(jax.devices()[:r]), ("model",))

def assert_exact(a_s, a_i, b_s, b_i, ctx=""):
    assert np.array_equal(a_s, b_s), f"scores diverge {{ctx}}"
    assert np.array_equal(a_i, b_i), f"ids diverge {{ctx}}"
"""


def test_sharded_ivf_parity_f32():
    """batch_search_ivf(mesh=...) == batch_search_ivf: both metrics, with and
    without bitmap pushdown, every mesh size."""
    run("""
        rng = np.random.default_rng(11)
        for metric in ("ip", "l2"):
            db = small_db(n=900, seed=11, metric=metric)
            ivf = IVFIndex.build(db.vectors, metric=metric, n_centroids=16, seed=0)
            q = rng.normal(size=(23, db.d)).astype(np.float32)
            cfg = PlanConfig(tq_unit=8, min_list_pad=8, use_pallas=False)
            for bitmap in (None, rng.random(db.n) < 0.4):
                ss, si = batch_search_ivf(ivf, q, nprobe=6, k=5, bitmap=bitmap, cfg=cfg)
                for R in MESH_SIZES:
                    bs, bi = batch_search_ivf(
                        ivf, q, nprobe=6, k=5, bitmap=bitmap, cfg=cfg, mesh=mesh_of(R)
                    )
                    assert_exact(ss, si, bs, bi, f"{metric} R={R} bitmap={bitmap is not None}")
        print("sharded ivf f32 parity OK")
    """)


def test_sharded_ivf_parity_pq():
    """Compressed execution (ADC scan -> exact re-rank) sharded == single."""
    run("""
        rng = np.random.default_rng(7)
        for metric in ("ip", "l2"):
            db = small_db(n=900, seed=7, metric=metric)
            ivf = IVFIndex.build(db.vectors, metric=metric, n_centroids=16, seed=0)
            pq = train_pq(db.vectors, 4, metric=metric, iters=4, seed=0)
            q = rng.normal(size=(23, db.d)).astype(np.float32)
            cfg = PlanConfig(
                tq_unit=8, min_list_pad=8, scan_mode="pq", refine_factor=2,
                use_pallas=False,
            )
            bitmap = rng.random(db.n) < 0.5
            for bm in (None, bitmap):
                ss, si = batch_search_ivf(ivf, q, nprobe=6, k=5, bitmap=bm, cfg=cfg, pq=pq)
                for R in MESH_SIZES:
                    bs, bi = batch_search_ivf(
                        ivf, q, nprobe=6, k=5, bitmap=bm, cfg=cfg, pq=pq, mesh=mesh_of(R)
                    )
                    assert_exact(ss, si, bs, bi, f"pq {metric} R={R}")
        print("sharded ivf pq parity OK")
    """)


def test_sharded_hqi_parity():
    """Full HQI workloads through cfg.mesh: multi-partition arena, template
    bitmaps, nprobe dicts, and the adaptive executor mixing sharded buckets
    with host-side per-query scans — all bit-identical to mesh=None."""
    run("""
        db = small_db()
        wl = small_workload(db)
        nprobe_dict = {ti: 3 + (ti % 4) for ti in range(len(wl.templates))}
        for scan_kw in ({}, dict(scan_mode="pq", pq_m=4)):
            hqi = HQIIndex.build(db, wl, HQIConfig(
                min_partition_size=128, max_leaves=32,
                plan=PlanConfig(adaptive_crossover=8, use_pallas=False), **scan_kw))
            refs = {
                (bv, npk): hqi.search(wl, nprobe=(nprobe_dict if npk else 6), batch_vec=bv)
                for bv in (True, "auto") for npk in (False, True)
            }
            for R in MESH_SIZES:
                hqi.cfg.mesh = mesh_of(R)
                for (bv, npk), ref in refs.items():
                    res = hqi.search(wl, nprobe=(nprobe_dict if npk else 6), batch_vec=bv)
                    assert_exact(ref.scores, ref.ids, res.scores, res.ids,
                                 f"{scan_kw} R={R} bv={bv} npdict={npk}")
                    st = res.shard_stats
                    assert st is not None and st.n_shards == R
                    assert st.per_rank_units.sum() > 0  # engine work ran sharded
            hqi.cfg.mesh = None
        print("sharded hqi parity OK")
    """)


def test_sharded_edge_cases():
    """Skewed splits, an empty shard, k > any shard's rows, all-false
    bitmaps, and m=0 workloads all behave exactly like a single device."""
    run("""
        from repro.core.distributed import execute_sharded
        from repro.core.plan import EngineTask
        from repro.core.predicates import Between, make_filter
        from repro.core.types import Workload

        rng = np.random.default_rng(3)
        db = small_db(n=700, seed=3)
        ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=12, seed=0)
        arena = PackedArena.from_ivf(ivf)
        q = rng.normal(size=(17, db.d)).astype(np.float32)
        cfg = PlanConfig(tq_unit=8, min_list_pad=8, use_pallas=False)
        k = 200  # > any shard's probed rows (3 lists x ~58 rows per query)
        ss, si = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=cfg)
        assert (si == -1).any()  # padding exists even on one device
        task = EngineTask(part=0, qrows=np.arange(17, dtype=np.int64),
                          nprobe=3, packed_bitmap=None)
        G = arena.n_lists
        mesh = mesh_of(4)
        # skewed: rank 0 owns almost everything; rank 2 owns NOTHING (empty
        # shard: all its would-be rows live on other ranks)
        for bounds in ([0, G - 2, G - 1, G - 1, G], [0, 0, 1, G - 1, G]):
            sharded = arena.shard(4, bounds=np.asarray(bounds))
            assert (sharded.rows_per_shard == 0).any()
            bs, bi, st = execute_sharded(
                sharded, [task], q, mesh=mesh, m=17, k=k, cfg=cfg)
            assert_exact(ss, si, bs, bi, f"bounds={bounds}")
            empty = sharded.rows_per_shard == 0
            assert (st.per_rank_units[empty] == 0).all()
            assert (st.per_rank_bytes[empty] == 0).all()

        # more ranks than posting lists can absorb evenly: non-pow2 mesh
        sharded = arena.shard(7)
        bs, bi, st = execute_sharded(
            sharded, [task], q, mesh=mesh_of(7), m=17, k=k, cfg=cfg)
        assert_exact(ss, si, bs, bi, "R=7")

        # all-false bitmap through the HQI layer: (-inf, -1) everywhere
        wl0 = small_workload(db, n_queries=7)
        hqi = HQIIndex.build(db, wl0, HQIConfig(
            min_partition_size=128, max_leaves=16, plan=PlanConfig(use_pallas=False)))
        hqi.cfg.mesh = mesh
        dead = Workload(
            vectors=wl0.vectors[:7],
            templates=[make_filter(Between("A", 5.0, 6.0))],  # A in [0,1): empty
            template_of=np.zeros(7, dtype=np.int32), k=4)
        res = hqi.search(dead, nprobe=6)
        assert (res.ids == -1).all() and np.isneginf(res.scores).all()

        # m=0 workload
        none = Workload(vectors=np.zeros((0, db.d), np.float32),
                        templates=[make_filter()],
                        template_of=np.zeros(0, dtype=np.int32), k=4)
        res = hqi.search(none, nprobe=6)
        assert res.ids.shape == (0, 4)
        print("sharded edge cases OK")
    """)


def test_sharded_comm_is_topk_gather_only():
    """The candidate tensors crossing ranks are O(k·|model|) per query —
    constant in DB size — and per-rank scan bytes split the single-device
    scan ~1/|model| on balanced shards."""
    run("""
        from repro.core.distributed import execute_sharded
        from repro.core.plan import EngineTask

        rng = np.random.default_rng(5)
        cfg = PlanConfig(tq_unit=8, min_list_pad=8, use_pallas=False)
        k, m = 5, 16
        gathered = {}
        for n in (600, 2400):  # 4x the rows must not change gather width
            vecs = rng.normal(size=(n, 24)).astype(np.float32)
            ivf = IVFIndex.build(vecs, metric="ip", n_centroids=24, seed=0)
            arena = PackedArena.from_ivf(ivf)
            q = rng.normal(size=(m, 24)).astype(np.float32)
            task = EngineTask(part=0, qrows=np.arange(m, dtype=np.int64),
                              nprobe=8, packed_bitmap=None)
            for R in (2, 8):
                _, _, st = execute_sharded(
                    arena.shard(R), [task], q, mesh=mesh_of(R), m=m, k=k, cfg=cfg)
                gathered[(n, R)] = st.gathered_per_query
                assert st.gathered_per_query == R * k, st.gathered_per_query
                if n == 2400:
                    # balanced shards: every rank scans well under the whole
                    _, _, st1 = execute_sharded(
                        arena.shard(1), [task], q, mesh=mesh_of(1), m=m, k=k, cfg=cfg)
                    total = st1.per_rank_bytes[0]
                    assert st.per_rank_bytes.sum() == total  # same scan, split
                    assert st.per_rank_bytes.max() <= 2.5 * total / R
        for R in (2, 8):
            assert gathered[(600, R)] == gathered[(2400, R)]  # O(k·R), not O(n)
        print("comm structure OK")
    """)


def test_sharded_dispatch_budget():
    """Sharded dispatches stay O(#buckets): one collective scan dispatch per
    shared pad (<= max_bucket_shapes) + one gather merge, regardless of mesh
    size — and the shared shape ladder equals the single-device ladder."""
    run("""
        from repro.core import build_plan, build_plan_sharded
        from repro.core.plan import EngineTask
        from repro.kernels import ops

        rng = np.random.default_rng(1)
        db = small_db(n=2000, seed=1)
        ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=64, seed=0)
        arena = PackedArena.from_ivf(ivf)
        q = rng.normal(size=(50, db.d)).astype(np.float32)
        task = EngineTask(part=0, qrows=np.arange(50, dtype=np.int64),
                          nprobe=16, packed_bitmap=None)
        for budget in (1, 2, 4):
            cfg = PlanConfig(max_bucket_shapes=budget, tq_unit=8, min_list_pad=8,
                             use_pallas=False)
            single = build_plan(arena, [task], q, m=50, k=5, cfg=cfg)
            splan = build_plan_sharded(arena.shard(8), [task], q, m=50, k=5, cfg=cfg)
            assert splan.pads == sorted(single.buckets)  # same compiled ladder
            assert splan.n_dispatches <= budget
            assert splan.per_rank_units.sum() == single.n_units
            from repro.core.distributed import execute_sharded
            ops.reset_dispatch_stats()
            s, i = batch_search_ivf(ivf, q, nprobe=16, k=5, cfg=cfg, mesh=mesh_of(8))
            st = ops.dispatch_stats()
            assert 0 < st.knn_calls <= budget, st.knn_calls
            # segmented layout: one ragged pre-merge + the gather merge —
            # still O(1) merges per execution, never O(buckets)
            assert st.merge_calls == 2, st.merge_calls
            ss, si = batch_search_ivf(ivf, q, nprobe=16, k=5, cfg=cfg)
            assert_exact(ss, si, s, i, f"budget={budget}")
        print("sharded dispatch budget OK")
    """)


def test_sharded_service_flushes():
    """HQIService runs flushes sharded when the index carries a mesh — same
    answers as the single-device service, live inserts/deletes included
    (delta rows stay exact f32 host-side, folded in the final merge)."""
    run("""
        from repro.service import HQIService, ServiceConfig

        db = small_db(n=1200, seed=9)
        wl = small_workload(db, n_queries=24)

        def stream(svc):
            handles = [svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
                       for i in range(wl.m)]
            svc.drain()
            return (np.stack([h.ids for h in handles]),
                    np.stack([h.scores for h in handles]))

        def build(mesh):
            hqi = HQIIndex.build(db, wl, HQIConfig(
                min_partition_size=128, max_leaves=16,
                plan=PlanConfig(use_pallas=False)))
            hqi.cfg.mesh = mesh
            return HQIService(hqi, ServiceConfig(k=wl.k, nprobe=8, max_batch=16,
                                                 deadline_s=0.0, batch_vec=True))
        rng = np.random.default_rng(2)
        newv = db.vectors[rng.integers(0, db.n, 8)] + 0.01 * rng.normal(
            size=(8, db.d)).astype(np.float32)
        dels = rng.integers(0, db.n, 20)  # ONE draw: both services mutate alike
        outs = {}
        for R in (None, 4):
            svc = build(None if R is None else mesh_of(R))
            ids0, sc0 = stream(svc)
            svc.insert(newv)
            svc.delete(dels)
            ids1, sc1 = stream(svc)
            svc.refresh()  # fold -> arena rebuild -> shard views refresh
            ids2, sc2 = stream(svc)
            outs[R] = (ids0, sc0, ids1, sc1, ids2, sc2)
        for a, b in zip(outs[None], outs[4]):
            assert np.array_equal(a, b)
        print("sharded service flushes OK")
    """)


def test_sharded_property_parity():
    """Hypothesis: random workloads / partition layouts / shard bounds ->
    exact sharded-vs-single parity, and per-rank units partition the
    single-device plan's unit multiset."""
    pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
    run("""
        from hypothesis import given, settings, strategies as st
        from repro.core import build_plan, build_plan_sharded
        from repro.core.distributed import execute_sharded
        from repro.core.plan import EngineTask
        from repro.core.planner import execute_plan

        @settings(max_examples=12, deadline=None)
        @given(
            seed=st.integers(0, 2**16),
            n_parts=st.integers(1, 3),
            R=st.sampled_from([1, 2, 3, 5, 8]),
            nprobe=st.integers(1, 8),
            with_bitmap=st.booleans(),
            random_bounds=st.booleans(),
        )
        def prop(seed, n_parts, R, nprobe, with_bitmap, random_bounds):
            rng = np.random.default_rng(seed)
            d, m, k = 8, 11, 4
            parts = []
            for p in range(n_parts):
                n_p = int(rng.integers(40, 400))
                vecs = rng.normal(size=(n_p, d)).astype(np.float32)
                ivf = IVFIndex.build(vecs, metric="ip",
                                     n_centroids=int(rng.integers(2, 12)), seed=0)
                rows = 10_000 * p + np.arange(n_p, dtype=np.int64)
                parts.append((rows, ivf))
            arena = PackedArena.from_partitions(parts)
            q = rng.normal(size=(m, d)).astype(np.float32)
            cfg = PlanConfig(tq_unit=4, min_list_pad=8, use_pallas=False)
            tasks = []
            for p, (rows, ivf) in enumerate(parts):
                qrows = np.nonzero(rng.random(m) < 0.7)[0].astype(np.int64)
                if len(qrows) == 0:
                    continue
                bm = (rng.random(ivf.n) < 0.6) if with_bitmap else None
                tasks.append(EngineTask(
                    part=p, qrows=qrows, nprobe=int(min(nprobe, ivf.n_lists)),
                    packed_bitmap=None if bm is None else arena.packed_bitmap(p, bm)))
            single = build_plan(arena, tasks, q, m=m, k=k, cfg=cfg)
            ss, si = execute_plan(single, arena, q, cfg=cfg)
            bounds = None
            if random_bounds:
                G = arena.n_lists
                cuts = np.sort(rng.integers(0, G + 1, size=R - 1))
                bounds = np.concatenate([[0], cuts, [G]])
            sharded = arena.shard(R, bounds=bounds)
            splan = build_plan_sharded(sharded, tasks, q, m=m, k=k, cfg=cfg)
            assert splan.per_rank_units.sum() == single.n_units
            bs, bi, stt = execute_sharded(
                sharded, tasks, q, mesh=mesh_of(R), m=m, k=k, cfg=cfg)
            assert np.array_equal(ss, bs) and np.array_equal(si, bi), seed
            assert stt.per_rank_units.sum() == single.n_units

        prop()
        print("property parity OK")
    """)


def test_sharded_merge_layout_parity():
    """merge_layout="segmented" == "dense" on the SHARDED path, bit-for-bit,
    across mesh sizes, scan modes, and skewed per-template routing — and the
    segmented layout's flat per-rank gather keeps lut_expand_bytes at 0 on
    the pq path while the dense layout pays the expanded-LUT operand."""
    run("""
        import dataclasses
        from repro.kernels import ops as kops

        db = small_db(n=1100, seed=21)
        wl = small_workload(db, n_queries=40, seed=5)
        nprobe = {ti: (12 if ti == 0 else 1) for ti in range(len(wl.templates))}
        for scan_kw in ({}, dict(scan_mode="pq", pq_m=4)):
            hqi = HQIIndex.build(db, wl, HQIConfig(
                min_partition_size=128, max_leaves=32,
                plan=PlanConfig(use_pallas=False), **scan_kw))
            for R in MESH_SIZES:
                hqi.cfg.mesh = mesh_of(R)
                # snapshot scalars immediately: dispatch_stats() returns the
                # live singleton, which the next reset() zeroes in place
                hqi.cfg.plan.merge_layout = "dense"
                kops.reset_dispatch_stats()
                dres = hqi.search(wl, nprobe=nprobe)
                dense_peak = int(kops.dispatch_stats().peak_candidate_bytes)
                dense_lut = int(kops.dispatch_stats().lut_expand_bytes)
                hqi.cfg.plan.merge_layout = "segmented"
                kops.reset_dispatch_stats()
                sres = hqi.search(wl, nprobe=nprobe)
                seg_peak = int(kops.dispatch_stats().peak_candidate_bytes)
                seg_lut = int(kops.dispatch_stats().lut_expand_bytes)
                assert_exact(dres.scores, dres.ids, sres.scores, sres.ids,
                             f"{scan_kw} R={R}")
                assert seg_lut == 0
                if scan_kw:
                    assert dense_lut > 0
                # ragged per-rank gather strictly shrinks the merge buffer on
                # this skewed workload once ranks stack (R x dense padding)
                if R >= 4:
                    assert seg_peak < dense_peak, (R, seg_peak, dense_peak)
            hqi.cfg.mesh = None
        print("sharded merge-layout parity OK")
    """)
