"""Training substrate: optimizers, loss goes down, microbatch equivalence,

checkpoint round-trips, fault injection + restart determinism, compression."""
import dataclasses
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import compression as comp
from repro.models import api
from repro.train import checkpoint as ckpt
from repro.train.fault_tolerance import LoopConfig, TrainLoop, elastic_mesh, with_retries
from repro.train.optimizer import OptConfig, apply_opt, init_opt, make_schedule
from repro.train.train_step import TrainConfig, init_train_state, make_train_step

CFG = get_reduced("minicpm-2b")


def test_schedules():
    for name in ("cosine", "wsd", "constant"):
        sched = make_schedule(OptConfig(schedule=name, peak_lr=1.0, warmup_steps=10, total_steps=100))
        lrs = [float(sched(jnp.int32(s))) for s in (0, 5, 10, 50, 99)]
        assert lrs[0] == 0.0 and lrs[1] == pytest.approx(0.5)
        assert lrs[2] == pytest.approx(1.0)
        if name == "wsd":
            assert lrs[3] == pytest.approx(1.0)  # stable phase
            assert lrs[4] < 0.2  # decay tail


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_loss_decreases(opt):
    tcfg = TrainConfig(opt=OptConfig(name=opt, peak_lr=3e-3, warmup_steps=5, total_steps=60))
    params, state = init_train_state(CFG, tcfg, jax.random.key(0))
    step = jax.jit(make_train_step(CFG, tcfg))
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=32, global_batch=8))
    losses = []
    for s in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::8]


def test_microbatch_equivalence():
    """grad accumulation over M microbatches == one big batch (same update)."""
    t1 = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10), microbatches=1)
    t4 = dataclasses.replace(t1, microbatches=4)
    params, state = init_train_state(CFG, t1, jax.random.key(1))
    data = SyntheticLM(DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=8))
    batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p1, _, m1 = make_train_step(CFG, t1)(params, state, batch)
    p4, _, m4 = make_train_step(CFG, t4)(params, state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-3


def test_checkpoint_roundtrip(tmp_path):
    tcfg = TrainConfig(opt=OptConfig())
    params, state = init_train_state(CFG, tcfg, jax.random.key(2))
    tree = {"params": params, "opt": state, "step": 7}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.arange(16, dtype=jnp.float32)}
    path = ckpt.save(str(tmp_path), 1, tree)
    # flip bytes in the array file
    npz = os.path.join(path, "arrays.npz")
    data = bytearray(open(npz, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), tree)


def test_checkpoint_async(tmp_path):
    tree = {"w": jnp.arange(64, dtype=jnp.float32)}
    t = ckpt.save_async(str(tmp_path), 3, tree)
    t.join()
    restored, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(64))


def test_retry_wrapper():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient device failure")
        return 42

    assert with_retries(flaky, max_retries=3)() == 42


def test_trainloop_failure_recovery(tmp_path):
    """Inject a failure mid-run; the retry path must complete the run and

    match the no-failure run exactly (deterministic data replay)."""
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=4)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20))
    lc = LoopConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=0, log_every=1, max_retries=2)

    fails = {"left": 2}

    def injector(step):
        if step == 5 and fails["left"] > 0:
            fails["left"] -= 1
            raise RuntimeError("simulated node failure")

    loop1 = TrainLoop(CFG, tcfg, dcfg, lc, seed=0)
    h1 = loop1.run(10, fail_injector=injector)
    loop2 = TrainLoop(CFG, tcfg, dcfg, LoopConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=0, log_every=1), seed=0)
    h2 = loop2.run(10)
    assert h1[-1]["loss"] == pytest.approx(h2[-1]["loss"], rel=1e-5)


def test_trainloop_checkpoint_restart(tmp_path):
    """Kill after 10 steps, restore, continue to 20 == uninterrupted 20."""
    dcfg = DataConfig(vocab=CFG.vocab, seq_len=16, global_batch=4)
    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=40))
    d1 = str(tmp_path / "run1")
    loopA = TrainLoop(CFG, tcfg, dcfg, LoopConfig(ckpt_dir=d1, ckpt_every=10, log_every=1, async_ckpt=False), seed=3)
    loopA.run(10)
    # "crash"; new process restores and continues
    loopB = TrainLoop(CFG, tcfg, dcfg, LoopConfig(ckpt_dir=d1, ckpt_every=10, log_every=1, async_ckpt=False), seed=3)
    assert loopB.maybe_restore()
    hB = loopB.run(10)
    loopC = TrainLoop(CFG, tcfg, dcfg, LoopConfig(ckpt_dir=str(tmp_path / "run2"), ckpt_every=0, log_every=1), seed=3)
    hC = loopC.run(20)
    assert hB[-1]["loss"] == pytest.approx(hC[-1]["loss"], rel=1e-4)


def test_data_determinism_and_sharding():
    dcfg = DataConfig(vocab=997, seq_len=32, global_batch=8, n_shards=2)
    ds = SyntheticLM(dcfg)
    b1 = ds.batch(5, shard=0)
    b2 = ds.batch(5, shard=0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # replayable
    b3 = ds.batch(5, shard=1)
    assert not np.array_equal(b1["tokens"], b3["tokens"])  # shards differ
    assert b1["tokens"].shape == (4, 32)


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    q, s = comp.quantize_int8(g)
    deq = comp.dequantize_int8(q, s)
    rel = float(jnp.max(jnp.abs(deq - g)) / jnp.max(jnp.abs(g)))
    assert rel < 1.0 / 120  # half-step bound
    # error feedback: accumulated quantized sum converges to true sum
    res = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        q, s, res = comp.compress_tree(g, res)
        acc = acc + comp.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g), rtol=0, atol=float(s) * 1.1)


def test_elastic_mesh_single_device():
    m = elastic_mesh((8, 1), ("data", "model"))
    assert int(np.prod(list(m.shape.values()))) <= max(1, len(jax.devices()))
