"""Flight recorder: trigger matrix, bundle schema, ring bound, SLOs.

The contracts: each built-in trigger (flush crash, index swap, deadline
spike, health leaving ok, SLO breach) fires exactly once per incident —
edge-triggered with cooldowns, never a dump storm; every bundle passes
``validate_incident_bundle`` (atomic publish, required files, manifest
fields, Chrome-trace-valid span window); the on-disk incident ring stays
bounded; and a live service run with an armed ``service.flush`` failpoint
produces exactly one bundle whose trace contains the offending window —
the PR's acceptance scenario.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex
from repro.fault import failpoints
from repro.obs import trace
from repro.obs.flight import (
    INCIDENT_SCHEMA,
    FlightRecorder,
    TriggerRule,
    default_rules,
    slo_rule,
    validate_incident_bundle,
)
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    Objective,
    get_registry,
    set_registry,
)
from repro.service import HQIService, ServiceConfig

from conftest import small_db, small_workload

EXACT = 10_000


@pytest.fixture(autouse=True)
def _clean():
    failpoints.disarm_all()
    trace.disable()
    set_registry(None)
    yield
    failpoints.disarm_all()
    trace.disable()
    set_registry(None)


@pytest.fixture(scope="module")
def db():
    return small_db(n=800, seed=21)


@pytest.fixture(scope="module")
def workload(db):
    return small_workload(db, n_queries=16)


def _service(db, wl, **kw):
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    cfg = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    cfg.update(kw)
    return HQIService(hqi, ServiceConfig(**cfg))


def _recorder(svc, tmp_path, **kw):
    trace.enable(capacity=4096)
    return FlightRecorder(svc, str(tmp_path / "incidents"), **kw)


# ---------------------------------------------------------------------------
# trigger matrix (manual observe: deterministic, no polling thread)
# ---------------------------------------------------------------------------


def test_flush_crash_fires_exactly_once(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        assert rec.observe() is None  # first sample: nothing to diff
        for i in range(4):
            svc.submit(workload.vectors[i],
                       workload.templates[workload.template_of[i]])
        svc.flush()  # one clean flush: the record the bundle must carry
        for i in range(4):
            svc.submit(workload.vectors[i],
                       workload.templates[workload.template_of[i]])
        failpoints.arm("service.flush", count=1)
        svc.flush()  # crash contained by the service
        path = rec.observe()
        assert path is not None
        man = validate_incident_bundle(path)
        assert man["schema"] == INCIDENT_SCHEMA
        assert man["rules"] == ["flush_crash"]
        assert "flush_failures" in man["detail"]["flush_crash"]
        assert man["health"]["flush_failures"] == 1
        assert man["recent_flushes"], "bundle must carry the flush records"
        # same crash must not dump twice
        assert rec.observe() is None
        assert rec.incidents_written == 1
    finally:
        svc.stop(drain=False)


def test_swap_deadline_and_health_triggers(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        rec.observe()
        svc.telemetry.record_swap()
        p1 = rec.observe()
        assert p1 is not None and validate_incident_bundle(p1)["rules"] == [
            "index_swap"
        ]

        svc.telemetry.record_deadline_expired(3)
        assert rec.observe() is None  # below the spike threshold (8)
        svc.telemetry.record_deadline_expired(10)
        p2 = rec.observe()
        assert p2 is not None and validate_incident_bundle(p2)["rules"] == [
            "deadline_spike"
        ]

        svc._degraded = True  # health status ok -> degraded edge
        p3 = rec.observe()
        man = validate_incident_bundle(p3)
        assert man["rules"] == ["health"]
        assert man["health"]["status"] == "degraded"
        assert man["health_transitions"][-1]["to"] == "degraded"
        assert rec.observe() is None  # still degraded: edge already fired
        svc._degraded = False
        assert rec.observe() is None  # recovery is not an incident
    finally:
        svc.stop(drain=False)


def test_multiple_triggers_one_observe_one_bundle(db, workload, tmp_path):
    """Simultaneous trips produce ONE bundle listing every rule."""
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        rec.observe()
        svc.telemetry.record_swap()
        svc.telemetry.record_deadline_expired(10)
        path = rec.observe()
        man = validate_incident_bundle(path)
        assert set(man["rules"]) == {"index_swap", "deadline_spike"}
        assert rec.incidents_written == 1
    finally:
        svc.stop(drain=False)


def test_slo_objective_fires_on_breach_edge_only(db, workload, tmp_path):
    svc = _service(db, workload)
    obj = Objective("p99-latency", "svc.lat_ms", stat="p99", max_value=5.0,
                    min_count=4)
    rec = _recorder(svc, tmp_path, objectives=(obj,))
    try:
        h = get_registry().histogram("svc.lat_ms")
        rec.observe()
        for _ in range(8):
            h.observe(1.0)
        assert rec.observe() is None  # within objective
        for _ in range(8):
            h.observe(500.0)  # p99 blows through max_value
        path = rec.observe()
        man = validate_incident_bundle(path)
        assert man["rules"] == ["slo:p99-latency"]
        assert "> max 5" in man["detail"]["slo:p99-latency"]
        # continuous breach: histograms are cumulative, the edge fired once
        assert rec.observe() is None
        assert rec.observe() is None
        # bundle's metrics.json carries the offending distribution (detail)
        with open(os.path.join(path, "metrics.json")) as f:
            metrics = json.load(f)
        assert "buckets" in metrics["svc.lat_ms"]
    finally:
        svc.stop(drain=False)


def test_rule_cooldown_suppresses_refiring(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        rec.observe()
        svc.telemetry.record_swap()
        assert rec.observe() is not None
        svc.telemetry.record_swap()  # second swap inside the 5 s cooldown
        assert rec.observe() is None
    finally:
        svc.stop(drain=False)


def test_broken_rule_cannot_break_the_poll(db, workload, tmp_path):
    def boom(prev, cur):
        raise RuntimeError("bad rule")

    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path,
                    rules=default_rules() + [TriggerRule("boom", boom)])
    try:
        rec.observe()
        svc.telemetry.record_swap()
        path = rec.observe()  # boom must not mask the real trigger
        assert validate_incident_bundle(path)["rules"] == ["index_swap"]
    finally:
        svc.stop(drain=False)


# ---------------------------------------------------------------------------
# bundles: ring bound, sequencing, schema validation
# ---------------------------------------------------------------------------


def test_incident_ring_bounded_and_seq_monotonic(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path, max_incidents=3)
    try:
        paths = [rec.force(f"n{i}") for i in range(7)]
        assert len(set(paths)) == 7
        kept = rec.incidents()
        assert len(kept) == 3  # oldest pruned
        seqs = [validate_incident_bundle(p)["seq"] for p in kept]
        assert seqs == sorted(seqs) == [5, 6, 7]
        assert not any(p.endswith(".tmp") for p in os.listdir(rec.root))
    finally:
        svc.stop(drain=False)


def test_seq_resumes_past_existing_incidents(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        rec.force()
        rec2 = FlightRecorder(svc, rec.root)  # fresh recorder, same ring
        p = rec2.force()
        assert validate_incident_bundle(p)["seq"] == 2
    finally:
        svc.stop(drain=False)


def test_validate_rejects_tampered_bundles(db, workload, tmp_path):
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path)
    try:
        path = rec.force("tamper-target")
        validate_incident_bundle(path)

        os.remove(os.path.join(path, "profile.json"))
        with pytest.raises(ValueError, match="missing profile.json"):
            validate_incident_bundle(path)
        with open(os.path.join(path, "profile.json"), "w") as f:
            f.write("{}")

        man_path = os.path.join(path, "manifest.json")
        with open(man_path) as f:
            man = json.load(f)
        man.pop("armed_failpoints")
        with open(man_path, "w") as f:
            json.dump(man, f)
        with pytest.raises(ValueError, match="armed_failpoints"):
            validate_incident_bundle(path)

        man["armed_failpoints"] = []
        man["schema"] = "who-knows-v9"
        with open(man_path, "w") as f:
            json.dump(man, f)
        with pytest.raises(ValueError, match="schema"):
            validate_incident_bundle(path)
    finally:
        svc.stop(drain=False)


def test_bundle_records_armed_failpoints_and_generation(db, workload, tmp_path):
    store_root = tmp_path / "store"
    store_root.mkdir()
    svc = _service(db, workload)
    rec = _recorder(svc, tmp_path, store_root=str(store_root))
    try:
        failpoints.arm("compact.cycle", prob=1.0)
        man = validate_incident_bundle(rec.force())
        assert "compact.cycle" in man["armed_failpoints"]
        assert man["current_generation"] is None  # no snapshot written yet
    finally:
        svc.stop(drain=False)


# ---------------------------------------------------------------------------
# acceptance: live service + background recorder + injected flush crash
# ---------------------------------------------------------------------------


def test_live_service_crash_produces_one_bundle_with_trace(db, workload, tmp_path):
    svc = _service(db, workload, deadline_s=0.0)
    root = str(tmp_path / "incidents")
    rec = FlightRecorder(svc, root, poll_s=0.005)
    assert isinstance(trace.get_tracer(), trace.NullTracer)
    rec.start()  # installs its own bounded tracer (black box)
    svc.start(poll_s=1e-3)
    try:
        assert trace.get_tracer().enabled
        # healthy traffic first, so the trace window holds real serving spans
        hs = [
            svc.submit(workload.vectors[i],
                       workload.templates[workload.template_of[i]])
            for i in range(8)
        ]
        for h in hs:
            assert h.wait(timeout=120)
        time.sleep(0.05)  # a few clean polls establish the baseline sample
        failpoints.arm("service.flush", count=1)
        for i in range(8):
            svc.submit(workload.vectors[i],
                       workload.templates[workload.template_of[i]])
        deadline = time.time() + 30.0
        while time.time() < deadline and not rec.incidents():
            time.sleep(0.01)
        svc.drain()
        time.sleep(0.1)  # give the poller time to (wrongly) double-dump
    finally:
        svc.stop(drain=False)
        rec.stop()
    assert isinstance(trace.get_tracer(), trace.NullTracer)  # tracer returned
    bundles = rec.incidents()
    assert len(bundles) == 1, f"expected exactly one incident, got {bundles}"
    man = validate_incident_bundle(bundles[0])
    assert "flush_crash" in man["rules"]
    with open(os.path.join(bundles[0], "trace.json")) as f:
        doc = json.load(f)
    names = {e["name"] for e in doc["traceEvents"]}
    assert "flush" in names, "bundle trace must contain the offending window"
    threads = {
        e["args"]["thread"]
        for e in doc["traceEvents"]
        if e.get("args", {}).get("thread")
    }
    assert "service" in threads  # scheduler-thread spans labeled for triage


# ---------------------------------------------------------------------------
# satellites riding along: Histogram.to_json buckets, Objective.evaluate
# ---------------------------------------------------------------------------


def test_histogram_to_json_buckets_reconstruct_count():
    h = Histogram()
    vals = [0.0012, 0.5, 0.9, 1.7, 1.7, 42.0, 1e9]
    for v in vals:
        h.observe(v)
    doc = h.to_json()
    for key in ("count", "sum", "mean", "min", "max", "p50", "p99"):
        assert key in doc  # summary fields kept
    b = doc["buckets"]
    assert sum(b["counts"]) == doc["count"] == len(vals)
    assert len(b["le"]) == len(b["counts"])
    assert all(c >= 0 for c in b["counts"])
    # boundaries are the histogram's own ladder, increasing (overflow = None)
    finite = [x for x in b["le"] if x is not None]
    assert finite == sorted(finite)
    empty = Histogram().to_json()
    assert empty["buckets"] == {"first": 0, "le": [], "counts": []}


def test_registry_snapshot_detail_includes_buckets():
    reg = MetricsRegistry()
    reg.histogram("x").observe(3.0)
    assert "buckets" not in reg.snapshot()["x"]
    assert "buckets" in reg.snapshot(detail=True)["x"]
    assert "buckets" in json.loads(reg.to_json(detail=True))["x"]


def test_objective_evaluate_modes():
    reg = MetricsRegistry()
    assert Objective("o", "missing", max_value=1.0).evaluate(reg) is None
    g = reg.gauge("g")
    g.set(2.0)
    assert "> max" in Objective("o", "g", stat="value", max_value=1.0).evaluate(reg)
    assert Objective("o", "g", stat="value", max_value=3.0).evaluate(reg) is None
    assert "< min" in Objective("o", "g", stat="value", min_value=5.0).evaluate(reg)
    h = reg.histogram("h")
    h.observe(10.0)
    ob = Objective("o", "h", stat="p99", max_value=1.0, min_count=3)
    assert ob.evaluate(reg) is None  # below min_count: no breach yet
    h.observe(10.0)
    h.observe(10.0)
    assert "> max" in ob.evaluate(reg)


def test_slo_rule_rearms_after_recovery():
    reg = MetricsRegistry()
    set_registry(reg)
    g = reg.gauge("recall")
    g.set(0.95)
    rule = slo_rule(Objective("recall-floor", "recall", stat="value",
                              min_value=0.9), cooldown_s=0.0)
    ok = type("S", (), {"health": {}, "telemetry": {}, "t": 0.0})()
    assert rule.check(ok, ok) is None
    g.set(0.5)
    assert rule.check(ok, ok) is not None  # breach edge
    assert rule.check(ok, ok) is None  # still breached: no refire
    g.set(0.95)
    assert rule.check(ok, ok) is None  # recovered
    g.set(0.5)
    assert rule.check(ok, ok) is not None  # re-armed after recovery
