"""qd-tree invariants: disjoint complete partitioning + routing soundness."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.predicates import evaluate_filter
from repro.core.qdtree import build_qdtree
from repro.core.types import Workload

from conftest import small_db, small_workload


def _build(seed, n=1200, min_size=64, m_queries=40):
    db = small_db(n=n, seed=seed)
    wl = small_workload(db, n_queries=m_queries, seed=seed + 1)
    tree = build_qdtree(db, wl, min_size=min_size, max_leaves=64)
    return db, wl, tree


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_leaves_partition_db(seed):
    db, wl, tree = _build(seed)
    seen = np.concatenate([l.rows for l in tree.leaves])
    assert len(seen) == db.n
    assert len(np.unique(seen)) == db.n  # disjoint + complete


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_routing_soundness(seed):
    """Every tuple satisfying a template's filter lives in a routed-to leaf —

    semantic-description routing must never lose results."""
    db, wl, tree = _build(seed)
    for t in wl.templates:
        routed = tree.route_filter(t)
        sat = evaluate_filter(t, db)
        covered = np.zeros(db.n, dtype=bool)
        for li, leaf in enumerate(tree.leaves):
            if routed[li]:
                covered[leaf.rows] = True
        assert not (sat & ~covered).any(), f"routing dropped matches for {t}"


def test_routing_prunes_something(db, workload):
    tree = build_qdtree(db, workload, min_size=64, max_leaves=64)
    assert tree.n_leaves > 4
    routed = np.stack([tree.route_filter(t) for t in workload.templates])
    # the selective template must skip at least one leaf
    assert routed.sum() < routed.size, "no pruning at all"


def test_balanced_splits(db, workload):
    tree = build_qdtree(db, workload, min_size=64, max_leaves=64)
    sizes = np.array([len(l.rows) for l in tree.leaves])
    # no pathological giant leaf (> 70% of data) once the tree split at all
    assert sizes.max() < 0.7 * db.n


def test_empty_workload_single_leaf(db):
    wl = Workload(vectors=np.zeros((0, db.d), np.float32), templates=[], template_of=np.zeros(0, np.int32))
    tree = build_qdtree(db, wl)
    assert tree.n_leaves == 1
    assert len(tree.leaves[0].rows) == db.n


def test_centroid_routing(db, workload):
    from repro.core import kmeans as km

    cents = km.train_kmeans(db.vectors, 8, iters=4, metric=db.metric)
    c_of = km.assign_kmeans(db.vectors, cents, metric=db.metric)
    qc = km.topm_centroids(workload.vectors, cents, 2, metric=db.metric)
    tree = build_qdtree(
        db, workload, centroid_of=c_of, query_centroids=qc, n_centroids=8,
        min_size=64, max_leaves=64,
    )
    allowed = tree.centroid_allowed()
    assert allowed.shape == (tree.n_leaves, 8)
    # soundness: a leaf's tuples' centroids must all be allowed
    for li, leaf in enumerate(tree.leaves):
        present = np.unique(c_of[leaf.rows])
        assert allowed[li, present].all()
