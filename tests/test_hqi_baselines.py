"""End-to-end HQI + baselines: recall vs exhaustive truth, batch parity,

pruning effectiveness, temporal robustness."""
import numpy as np
import pytest

from repro.core import (
    HQIConfig, HQIIndex, PostFilterIndex, PreFilterIndex, RangeIndex,
    exhaustive_search, recall_at_k, tune_nprobe,
)
from repro.core.workload import kg_style, synthetic_bigann_style

from conftest import small_db, small_workload


@pytest.fixture(scope="module")
def truth(db, workload):
    return exhaustive_search(db, workload)


@pytest.fixture(scope="module")
def hqi(db, workload):
    return HQIIndex.build(db, workload, HQIConfig(min_partition_size=128, max_leaves=32))


def test_hqi_full_nprobe_recall_1(db, workload, truth, hqi):
    """With every posting list scanned and m=0 routing, HQI must be exact."""
    res = hqi.search(workload, nprobe=10_000)
    assert recall_at_k(res, truth) == 1.0


def test_hqi_batch_equals_online(db, workload, hqi):
    rb = hqi.search(workload, nprobe=6)
    ro = hqi.search_online(workload, nprobe=6)
    np.testing.assert_allclose(
        np.where(np.isfinite(rb.scores), rb.scores, -1e30),
        np.where(np.isfinite(ro.scores), ro.scores, -1e30),
        rtol=1e-4, atol=1e-4,
    )


def test_hqi_prunes_tuples(db, workload, truth, hqi):
    pre = PreFilterIndex.build(db)
    nprobe = tune_nprobe(lambda wl, np_: hqi.search(wl, nprobe=np_[0]), workload, truth)
    res = hqi.search(workload, nprobe=nprobe)
    pre_np = tune_nprobe(lambda wl, np_: pre.search(wl, nprobe=np_[0]), workload, truth)
    res_pre = pre.search(workload, nprobe=pre_np)
    assert recall_at_k(res, truth) >= 0.75
    assert recall_at_k(res_pre, truth) >= 0.75
    # workload-aware layout scans fewer tuples at comparable recall
    assert res.tuples_scanned < res_pre.tuples_scanned


def test_prefilter_recall(db, workload, truth):
    pre = PreFilterIndex.build(db)
    res = pre.search(workload, nprobe=1_000)  # full scan ⇒ exact
    assert recall_at_k(res, truth) == 1.0


def test_postfilter_low_recall_on_selective(db, workload, truth):
    post = PostFilterIndex.build(db)
    res = post.search(workload, nprobe=1_000, expansion=2)
    pre = PreFilterIndex.build(db).search(workload, nprobe=1_000)
    # Strategy D with bounded expansion cannot match pushdown on selective
    # templates (Section 2.3's recall argument)
    assert recall_at_k(res, truth) < recall_at_k(pre, truth)


def test_range_applicability():
    db, wl, _ = synthetic_bigann_style(n=3000, d=8, n_query_vecs=4, seed=0)
    assert RangeIndex.applicable(wl)
    kg = kg_style(n=2000, d=8, queries_per_split=50, seed=0)
    assert not RangeIndex.applicable(kg.splits[0])  # IN/NOTNULL → NA (Table 3)


def test_range_recall_on_partitioning_attr():
    db, wl, _ = synthetic_bigann_style(n=3000, d=8, n_query_vecs=4, seed=0)
    r = RangeIndex.build(db, "A", n_buckets=4)
    truth = exhaustive_search(db, wl)
    res = r.search(wl, nprobe=1_000)
    assert recall_at_k(res, truth) == 1.0


def test_hqi_m10_centroid_routing(db, workload, truth):
    hqi = HQIIndex.build(
        db, workload, HQIConfig(m=4, n_coarse_centroids=8, min_partition_size=128, max_leaves=32)
    )
    res = hqi.search(workload, nprobe=10_000)
    # centroid routing may trade recall for pruning, but must stay high at
    # full nprobe with m=4 fan-out
    assert recall_at_k(res, truth) >= 0.8


def test_temporal_robustness_smoke():
    """HQI trained on t0 serves t1..t3 without re-indexing (Table 5)."""
    kg = kg_style(n=4000, d=16, queries_per_split=120, seed=0)
    hqi = HQIIndex.build(kg.db, kg.splits[0], HQIConfig(min_partition_size=256, max_leaves=32))
    for split in kg.splits[1:]:
        truth = exhaustive_search(kg.db, split)
        res = hqi.search(split, nprobe=10_000)
        assert recall_at_k(res, truth) >= 0.99


def test_hqi_adaptive_executor(db, workload, hqi):
    """§6.5 adaptive executor: same results as full batching, picks the

    per-query path for small (template × partition) groups."""
    ra = hqi.search(workload, nprobe=6, batch_vec="auto")
    rb = hqi.search(workload, nprobe=6, batch_vec=True)
    np.testing.assert_allclose(
        np.where(np.isfinite(ra.scores), ra.scores, -1e30),
        np.where(np.isfinite(rb.scores), rb.scores, -1e30),
        rtol=1e-4, atol=1e-4,
    )


def test_pq_index_recall_with_rerank(db):
    """PQ+ADC: compression ≥ 8×, rerank recovers ≥0.8 recall@10 vs exact."""
    from repro.core.pq import PQIndex

    idx = PQIndex.build(db.vectors, m=8, metric=db.metric)
    assert idx.compression_ratio >= 8.0
    rng = np.random.default_rng(0)
    q = rng.normal(size=(32, db.d)).astype(np.float32)
    s, i = idx.search(q, k=10, rerank=8)
    ip = q @ db.vectors.T
    sc = 2 * ip - (db.vectors**2).sum(1)[None] - (q**2).sum(1)[:, None] if db.metric == "l2" else ip
    truth = np.argsort(-sc, axis=1)[:, :10]
    rec = np.mean([len(set(i[r].tolist()) & set(truth[r].tolist())) / 10 for r in range(32)])
    assert rec >= 0.8, rec
    # bitmap pushdown composes
    bitmap = rng.random(db.n) > 0.5
    s2, i2 = idx.search(q, k=10, bitmap=bitmap)
    ok = i2[i2 >= 0]
    assert bitmap[ok].all()
