"""Per-arch reduced smoke tests + family invariants (SSD parity, MoE

causality, decode==forward)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.configs.shapes import SHAPES, shapes_for
from repro.models import api
from repro.models import encdec as ed
from repro.models.ssm import SSMConfig, init_ssm, ssm_block
from repro.models.transformer import lm_forward

RNG = np.random.default_rng(0)


def _batch_for(cfg, b=2, s=16):
    batch = {
        "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(RNG.normal(size=(b, cfg.vision_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(RNG.normal(size=(b, cfg.encoder_frames, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke(arch):
    """One forward + loss + one train step on the reduced config: correct

    shapes, finite numbers."""
    cfg = get_reduced(arch)
    params = api.init_model(cfg, jax.random.key(0))
    batch = _batch_for(cfg)
    loss, aux = api.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    # one SGD-ish step must run and keep the loss finite
    from repro.train.optimizer import OptConfig
    from repro.train.train_step import TrainConfig, make_train_step, init_train_state

    tcfg = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10))
    params, opt = init_train_state(cfg, tcfg, jax.random.key(0))
    step = make_train_step(cfg, tcfg)
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "mamba2-130m": (24, 768, None, None, None, 50280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, None, 163840),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch]
    L, d, h, kv, ff, vocab = expect
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == vocab
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    if arch == "kimi-k2-1t-a32b":
        assert cfg.moe.n_experts == 384 and cfg.moe.top_k == 8 and cfg.moe.d_ff_expert == 2048
    if arch == "deepseek-moe-16b":
        assert cfg.moe.n_experts == 64 and cfg.moe.top_k == 6 and cfg.moe.d_ff_expert == 1408
        assert cfg.moe.n_shared_experts == 2
    if arch == "mamba2-130m":
        assert cfg.ssm.d_state == 128
    if arch == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64 and cfg.family == "hybrid"


def test_kimi_total_params_about_1t():
    from repro.launch.roofline import active_params, total_params

    cfg = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < total_params(cfg) < 1.3e12
    assert 15e9 < active_params(cfg) < 40e9  # a32b


@pytest.mark.parametrize(
    "arch", ["minicpm-2b", "gemma3-27b", "mamba2-130m", "zamba2-2.7b",
             "deepseek-moe-16b", "whisper-large-v3", "internvl2-2b"]
)
def test_decode_matches_forward(arch):
    """Prefill + decode must reproduce the teacher-forced forward logits."""
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0, serve_capacity_factor=64.0)
        )
    params = api.init_model(cfg, jax.random.key(1))
    B, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab, (B, S)), jnp.int32)
    pf = {"tokens": toks[:, : S - 1]}
    extra = {}
    if cfg.family == "vlm":
        extra["vision_embeds"] = jnp.asarray(RNG.normal(size=(B, cfg.vision_patches, cfg.d_model)), jnp.float32)
        pf["vision_embeds"] = extra["vision_embeds"]
    if cfg.family == "encdec":
        extra["frames"] = jnp.asarray(RNG.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.float32)
        pf["frames"] = extra["frames"]
    if cfg.family == "encdec":
        full = ed.encdec_forward(params, cfg, extra["frames"], toks)
    else:
        full, _ = lm_forward(params, cfg, toks, vision_embeds=extra.get("vision_embeds"))
        if cfg.family == "vlm":
            full = full[:, cfg.vision_patches:]
    logits_p, cache = api.serve_prefill(params, cfg, pf, max_len=S + cfg.vision_patches + 4)
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(full[:, S - 2]), rtol=2e-3, atol=2e-3)
    logits_d, cache = api.serve_decode(params, cfg, toks[:, S - 1], cache)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(full[:, S - 1]), rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    """Chunked SSD must be invariant to the chunk size (same math)."""
    cfg16 = SSMConfig(d_model=32, d_state=8, head_dim=8, expand=2, chunk=16)
    cfg4 = SSMConfig(d_model=32, d_state=8, head_dim=8, expand=2, chunk=4)
    p = init_ssm(jax.random.key(0), cfg16)
    x = jnp.asarray(RNG.normal(size=(2, 32, 32)), jnp.float32)
    y16, _ = ssm_block(p, x, cfg16)
    y4, _ = ssm_block(p, x, cfg4)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y4), rtol=1e-4, atol=1e-4)


def test_ssd_matches_recurrence():
    """Chunked SSD (training path) == token-by-token recurrence (decode)."""
    cfg = SSMConfig(d_model=16, d_state=8, head_dim=8, expand=2, chunk=8)
    p = init_ssm(jax.random.key(2), cfg)
    s = 20
    x = jnp.asarray(RNG.normal(size=(1, s, 16)), jnp.float32)
    y_chunk, _ = ssm_block(p, x, cfg)
    state = {
        "ssm": jnp.zeros((1, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
        "conv": jnp.zeros((1, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.d_state), jnp.float32),
    }
    ys = []
    for t in range(s):
        y, state = ssm_block(p, x[:, t : t + 1], cfg, state=state)
        ys.append(y)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec), rtol=5e-3, atol=5e-3)


def test_moe_dropless_batch_independent():
    """At near-dropless capacity the MoE output for a row must not depend on

    the other rows in the batch (causality/purity of routing)."""
    from repro.models.moe import MoEConfig, init_moe, moe_layer

    mcfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=64.0)
    p = init_moe(jax.random.key(0), 32, mcfg)
    x1 = jnp.asarray(RNG.normal(size=(1, 6, 32)), jnp.float32)
    x2 = jnp.asarray(RNG.normal(size=(1, 6, 32)), jnp.float32)
    y_joint, _ = moe_layer(p, jnp.concatenate([x1, x2], axis=0), mcfg)
    y1, _ = moe_layer(p, x1, mcfg)
    np.testing.assert_allclose(np.asarray(y_joint[0]), np.asarray(y1[0]), rtol=1e-4, atol=1e-4)


def test_moe_drops_at_low_capacity():
    from repro.models.moe import MoEConfig, init_moe, moe_layer

    mcfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=0.5)
    p = init_moe(jax.random.key(0), 32, mcfg)
    x = jnp.asarray(RNG.normal(size=(2, 32, 32)), jnp.float32)
    _, aux = moe_layer(p, x, mcfg)
    assert float(aux["dropped_frac"]) > 0.0


def test_gemma3_window_pattern():
    cfg = get_config("gemma3-27b")
    w = np.asarray(cfg.layer_windows())
    assert (w[:5] == 1024).all() and w[5] == 0 and len(w) == 62
    assert w[5::6].sum() == 0  # every 6th layer global


def test_long_500k_only_subquadratic():
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = shapes_for(cfg.family)
        if cfg.family in ("ssm", "hybrid"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
