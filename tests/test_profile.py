"""Kernel dispatch profiler: attribution math, coverage, zero disabled cost.

The contracts the perf-baseline gate leans on: ``record_dispatch``'s shape
facts must equal what the plan actually dispatched (bytes/FLOPs recomputed
here from the ExecutionPlan with independently written formulas), FLOP
attribution must agree with the engine's own ``dists_computed`` accounting,
every issued kernel must be attributed (coverage 1.0), the profiler must be
allocation-free when disabled, and enabling it must wire the process state
(fence hold, ops issue hook, registry source, trace instants) that the rest
of the observability stack reads.
"""
import threading
import tracemalloc

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex
from repro.core.arena import PackedArena
from repro.core.ivf import IVFIndex
from repro.core.plan import EngineTask, PlanConfig, build_plan, _next_pow2
from repro.core.planner import execute_plan
from repro.kernels import ops
from repro.obs import trace
from repro.obs.metrics import MetricsRegistry, get_registry, set_registry
from repro.obs.profile import (
    KernelProfiler,
    NullProfiler,
    disable_profiler,
    enable_profiler,
    get_profiler,
)

from conftest import small_db, small_workload

EXACT = 10_000


@pytest.fixture(autouse=True)
def _clean_profile():
    """Every test starts and leaves with profiler + tracer + registry reset."""
    disable_profiler()
    trace.disable()
    set_registry(None)
    ops.reset_dispatch_stats()
    yield
    disable_profiler()
    trace.disable()
    set_registry(None)
    ops.reset_dispatch_stats()


def _tiny_plan(n=300, d=8, m=5, k=3, seed=0):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    ivf = IVFIndex.build(vecs, metric="l2", n_centroids=4, kmeans_iters=5, seed=0)
    arena = PackedArena.from_ivf(ivf)
    q = rng.normal(size=(m, d)).astype(np.float32)
    task = EngineTask(part=0, qrows=np.arange(m, dtype=np.int64), nprobe=4,
                      packed_bitmap=None)
    cfg = PlanConfig(tq_unit=8, min_list_pad=8, max_bucket_shapes=4)
    plan = build_plan(arena, [task], q, m=m, k=k, cfg=cfg)
    return plan, arena, q, cfg, d, k


# ---------------------------------------------------------------------------
# attribution math
# ---------------------------------------------------------------------------


def test_f32_attribution_matches_hand_computed_plan_facts():
    """Scan-phase bytes/FLOPs/occupancy == formulas recomputed from the plan."""
    plan, arena, q, cfg, d, k = _tiny_plan()
    prof = enable_profiler()
    execute_plan(plan, arena, q, cfg=cfg)

    # independently recomputed from the plan's buckets: per bucket of padded
    # list length lp, W = next_pow2(#units) padded work units of tq query
    # rows each; operands are Q [W,tq,d] f32, V [W,lp,d] f32, valid [W,lp]
    # bool, output scores+ids [W,tq,min(k,lp)] (4+8 bytes)
    exp_bytes = exp_flops = exp_flops_pad = 0
    exp_rows = exp_rows_pad = 0
    exp_dispatches = 0
    tq = plan.tq
    for lp, units in plan.buckets.items():
        W = _next_pow2(len(units), 1)
        exp_dispatches += 1
        exp_bytes += W * tq * d * 4 + W * lp * d * 4 + W * lp
        exp_bytes += W * tq * min(k, lp) * 12
        real = sum(
            len(u.qrows) * int(arena.list_len[u.glist]) for u in units
        )
        exp_flops += 2 * d * real
        exp_flops_pad += 2 * d * W * tq * lp
        exp_rows += sum(int(arena.list_len[u.glist]) for u in units)
        exp_rows_pad += W * lp

    scan = prof.totals(phase="scan", mode="f32")
    assert scan["dispatches"] == exp_dispatches
    assert scan["bytes"] == exp_bytes
    assert scan["flops"] == exp_flops
    assert scan["flops_padded"] == exp_flops_pad
    assert scan["row_occupancy"] == pytest.approx(exp_rows / exp_rows_pad)
    assert 0.0 < scan["row_occupancy"] <= 1.0
    # roofline terms derive from the same totals
    assert scan["gbps"] == pytest.approx(exp_bytes / scan["device_s"] / 1e9)
    assert scan["device_s"] > 0.0


def test_f32_flops_agree_with_engine_dists_computed():
    """2·d·(query,row) pairs: the profiler's scan FLOPs must equal the plan
    accountant's ``dists_computed`` view of the same workload."""
    from repro.core.predicates import make_filter
    from repro.core.types import Workload

    db = small_db(n=900, seed=5)
    rng = np.random.default_rng(5)
    # single pure-vector template: no predicate bitmaps, so every tuple
    # scanned is a distance computed and the two accountants must agree
    wl = Workload(
        vectors=rng.normal(size=(24, db.d)).astype(np.float32),
        templates=[make_filter()],
        template_of=np.zeros(24, dtype=np.int32),
        k=5,
    )
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    prof = enable_profiler()
    res = hqi.search(wl, nprobe=EXACT, batch_vec=True)
    scan = prof.totals(phase="scan", mode="f32")
    assert scan["flops"] == 2.0 * db.d * res.tuples_scanned
    assert prof.report()["coverage"] == 1.0


def test_pq_attribution_and_coverage():
    """PQ scan FLOPs are one-hot LUT contractions (2·M·256 per pair); the
    re-rank is exact f32 over kprime candidates; all dispatches attributed."""
    from repro.core.predicates import make_filter
    from repro.core.types import Workload

    db = small_db(n=900, seed=7)  # >= 256 rows: train_pq needs 256 centroids
    rng = np.random.default_rng(7)
    wl = Workload(  # pure-vector template: tuples scanned == dists computed
        vectors=rng.normal(size=(24, db.d)).astype(np.float32),
        templates=[make_filter()],
        template_of=np.zeros(24, dtype=np.int32),
        k=5,
    )
    hqi = HQIIndex.build(
        db, wl,
        HQIConfig(min_partition_size=128, max_leaves=8, scan_mode="pq", pq_m=8),
    )
    prof = enable_profiler()
    res = hqi.search(wl, nprobe=EXACT, batch_vec=True)
    scan = prof.totals(phase="scan")
    assert scan["flops"] == 2.0 * 8 * 256 * res.tuples_scanned
    rerank = prof.totals(phase="rerank")
    assert rerank["dispatches"] >= 1
    assert 0.0 < rerank["flops"] <= rerank["flops_padded"]
    rep = prof.report()
    assert rep["coverage"] == 1.0
    assert rep["attributed"] == sum(rep["issued"].values())


def test_totals_filter_and_report_keys():
    plan, arena, q, cfg, d, k = _tiny_plan()
    prof = enable_profiler()
    execute_plan(plan, arena, q, cfg=cfg)
    rep = prof.report()
    assert rep["enabled"] is True
    assert set(rep["hardware"]) == {"name", "peak_flops", "hbm_bw", "link_bw"}
    assert all("/" in key for key in rep["phases"])
    all_phases = prof.totals()
    per_phase = [prof.totals(phase=p) for p in ("scan", "merge")]
    assert all_phases["dispatches"] == sum(
        t.get("dispatches", 0) for t in per_phase
    )
    assert prof.totals(phase="nope") == {}
    # format_table renders without error and names every aggregation key
    table = prof.format_table()
    for key in rep["phases"]:
        assert key in table


# ---------------------------------------------------------------------------
# process wiring
# ---------------------------------------------------------------------------


def test_enable_disable_wires_process_state():
    assert isinstance(get_profiler(), NullProfiler)
    assert not get_profiler().enabled
    prof = enable_profiler()
    try:
        assert get_profiler() is prof and prof.enabled
        assert trace._FENCE_HOLD  # dispatches fence even with tracing off
        assert ops._PROFILE_HOOK is not None
        assert "profile" in get_registry().snapshot()
    finally:
        disable_profiler()
    assert isinstance(get_profiler(), NullProfiler)
    assert not trace._FENCE_HOLD
    assert ops._PROFILE_HOOK is None
    assert "profile" not in get_registry().snapshot()


def test_profile_instants_land_in_trace():
    """With tracing AND profiling on, every dispatch emits a profile.dispatch
    instant carrying the attribution args (what check_obs requires)."""
    plan, arena, q, cfg, d, k = _tiny_plan()
    t = trace.enable(capacity=4096)
    prof = enable_profiler()
    execute_plan(plan, arena, q, cfg=cfg)
    evs = [e for e in t.events() if e["name"] == "profile.dispatch"]
    assert len(evs) == prof.report()["attributed"]
    for e in evs:
        assert e["ph"] == "i"
        assert {"phase", "mode", "shape", "device_us"} <= set(e["args"])
    doc = t.to_chrome_trace()
    assert trace.validate_chrome_trace(doc) > 0


def test_registry_source_snapshot_shape():
    plan, arena, q, cfg, d, k = _tiny_plan()
    enable_profiler()
    execute_plan(plan, arena, q, cfg=cfg)
    snap = get_registry().snapshot()["profile"]
    assert snap["enabled"] is True
    assert snap["attributed"] == snap["issued"] > 0
    assert "scan" in snap and snap["scan"]["dispatches"] >= 1


def test_reset_clears_aggregates_and_coverage():
    plan, arena, q, cfg, d, k = _tiny_plan()
    prof = enable_profiler()
    execute_plan(plan, arena, q, cfg=cfg)
    assert prof.totals()
    prof.reset()
    assert prof.totals() == {}
    rep = prof.report()
    assert rep["attributed"] == 0 and sum(rep["issued"].values()) == 0
    assert rep["coverage"] == 1.0  # vacuous, not 0/0


# ---------------------------------------------------------------------------
# disabled cost
# ---------------------------------------------------------------------------


def test_disabled_profiler_is_allocation_free():
    """The NullProfiler hot path retains nothing: planner guards are a bool
    check, ``t0()`` is a shared constant, record calls are no-ops."""
    disable_profiler()
    p = get_profiler()
    assert isinstance(p, NullProfiler)
    assert p.t0() == 0 and p.t0() is p.t0()
    assert ops._PROFILE_HOOK is None  # issue hook fully disarmed
    tracemalloc.start()
    base = tracemalloc.take_snapshot()
    for _ in range(1000):
        if p.enabled:  # the exact guard every planner site runs
            p.record_dispatch("scan", "f32", 64, p.t0(), nbytes=1, flops=1,
                              flops_padded=1, units=1, units_padded=1,
                              rows=1, rows_padded=1)
        p.t0()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    retained = sum(s.size_diff for s in after.compare_to(base, "lineno"))
    assert p.totals() == {} and p.snapshot() == {"enabled": False}
    assert retained < 16_384  # nothing retained beyond tracemalloc noise


def test_disabled_run_attributes_nothing():
    db = small_db(n=600, seed=9)
    wl = small_workload(db, n_queries=8)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    base = ops.dispatch_stats().snapshot()
    hqi.search(wl, nprobe=8, batch_vec=True)
    assert ops.dispatch_stats().delta_since(base).knn_calls > 0  # work ran
    assert get_profiler().totals() == {}


# ---------------------------------------------------------------------------
# thread labels (satellite: background-thread trace context)
# ---------------------------------------------------------------------------


def test_thread_name_tags_root_spans_and_emits_metadata():
    t = trace.enable(capacity=256)
    done = threading.Event()

    def worker():
        trace.set_thread_name("bg-worker")
        with trace.get_tracer().span("root"):
            with trace.get_tracer().span("child"):
                pass
        done.set()

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    assert done.is_set()
    evs = t.events()
    root = next(e for e in evs if e["name"] == "root")
    child = next(e for e in evs if e["name"] == "child")
    assert root["args"]["thread"] == "bg-worker"
    assert "thread" not in child.get("args", {})  # only roots carry the tag
    metas = [e for e in evs if e.get("ph") == "M"]
    assert any(
        m["name"] == "thread_name" and m["args"]["name"] == "bg-worker"
        and m["tid"] == root["tid"]
        for m in metas
    )
    # one metadata event per thread, not per span
    assert sum(1 for m in metas if m["args"].get("name") == "bg-worker") == 1
    trace.validate_chrome_trace(t.to_chrome_trace())


def test_service_loop_spans_tagged_in_chrome_export():
    db = small_db(n=600, seed=11)
    wl = small_workload(db, n_queries=8)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=8))
    from repro.service import HQIService, ServiceConfig

    svc = HQIService(hqi, ServiceConfig(k=wl.k, nprobe=8, max_batch=4,
                                        deadline_s=0.0))
    t = trace.enable(capacity=8192)
    svc.start(poll_s=1e-3)
    try:
        handles = [
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
            for i in range(8)
        ]
        for h in handles:
            assert h.wait(timeout=120)
    finally:
        svc.stop()
    evs = t.events()
    tagged = [
        e for e in evs if e.get("args", {}).get("thread") == "service"
    ]
    assert tagged, "scheduler-thread root spans must carry thread='service'"
    metas = [
        e for e in evs
        if e.get("ph") == "M" and e.get("args", {}).get("name") == "service"
    ]
    assert len(metas) == 1
    trace.validate_chrome_trace(t.to_chrome_trace())
