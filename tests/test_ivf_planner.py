"""IVF index + batch planner: structural invariants and batch==online parity."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need the [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.ivf import IVFIndex, ScanStats
from repro.core.planner import PlanConfig, batch_search_ivf

from conftest import small_db


@pytest.fixture(scope="module")
def ivf(db):
    return IVFIndex.build(db.vectors, metric=db.metric, n_centroids=24, seed=0)


def test_posting_lists_partition(ivf):
    assert ivf.offsets[-1] == ivf.n
    assert (np.sort(ivf.order) == np.arange(ivf.n)).all()


def test_full_nprobe_equals_exhaustive(db, ivf):
    """nprobe = n_lists must return the exact global top-k."""
    rng = np.random.default_rng(0)
    q = rng.normal(size=(db.d,)).astype(np.float32)
    s, i = ivf.search_single(q, nprobe=ivf.n_lists, k=5)
    ip = db.vectors @ q
    sc = 2 * ip - (db.vectors**2).sum(1) - q @ q if db.metric == "l2" else ip
    truth = np.argsort(-sc, kind="stable")[:5]
    assert set(i.tolist()) == set(truth.tolist())


def test_bitmap_pushdown_equals_postfilter_at_full_probe(db, ivf):
    """Pushdown must give exactly the matching tuples' top-k."""
    rng = np.random.default_rng(1)
    q = rng.normal(size=(db.d,)).astype(np.float32)
    bitmap = rng.random(db.n) < 0.2
    s, i = ivf.search_single(q, nprobe=ivf.n_lists, k=5, bitmap=bitmap)
    assert all(bitmap[x] for x in i if x >= 0)
    ip = db.vectors @ q
    sc = 2 * ip - (db.vectors**2).sum(1) - q @ q if db.metric == "l2" else ip
    sc[~bitmap] = -np.inf
    truth = np.argsort(-sc, kind="stable")[:5]
    assert set(i.tolist()) == set(truth.tolist())


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 100), st.integers(1, 12), st.booleans())
def test_batch_equals_single(seed, nprobe, with_bitmap):
    """Algorithm 3 batching returns identical results to per-query scans."""
    db = small_db(n=800, seed=seed)
    ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=12, seed=0)
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(17, db.d)).astype(np.float32)
    bitmap = (rng.random(db.n) < 0.5) if with_bitmap else None
    bs, bi = batch_search_ivf(ivf, q, nprobe=nprobe, k=4, bitmap=bitmap,
                              cfg=PlanConfig(tq_unit=8, min_list_pad=8))
    for r in range(q.shape[0]):
        ss, si = ivf.search_single(q[r], nprobe=nprobe, k=4, bitmap=bitmap)
        np.testing.assert_allclose(
            np.where(np.isfinite(bs[r]), bs[r], -1e30),
            np.where(np.isfinite(ss), ss, -1e30), rtol=1e-4, atol=1e-4,
        )
        assert set(bi[r][bi[r] >= 0].tolist()) == set(si[si >= 0].tolist())


def test_stats_accounting(db, ivf):
    rng = np.random.default_rng(2)
    q = rng.normal(size=(5, db.d)).astype(np.float32)
    bitmap = rng.random(db.n) < 0.3
    st1 = ScanStats()
    batch_search_ivf(ivf, q, nprobe=4, k=3, bitmap=bitmap, stats=st1)
    assert st1.tuples_scanned > 0
    assert 0 < st1.dists_computed <= st1.tuples_scanned
