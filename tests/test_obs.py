"""Observability layer: tracing, metrics registry, drift monitor.

Covers the obs contracts the serving stack now leans on: span nesting and
Chrome-trace schema (shared with the CI guard), the bounded ring buffer,
thread-safety under concurrent spans, the zero-cost-when-disabled guarantee,
streaming-histogram percentile bounds, ``DispatchStats.delta_since``, the
single-sort telemetry summary, drift template-share math on a synthetic
shifting stream, and — the acceptance criterion — a template shift injected
mid-stream through a real ``HQIService`` that ``drift_report()`` must see,
with the live recall probe scoring 1.0 in exact mode.
"""
import json
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.core import HQIConfig, HQIIndex
from repro.kernels.ops import DispatchStats
from repro.obs import trace
from repro.obs.drift import DriftConfig, DriftMonitor
from repro.obs.metrics import Histogram, MetricsRegistry, get_registry, set_registry
from repro.obs.trace import NullTracer, Tracer, validate_chrome_trace
from repro.service import HQIService, ServiceConfig
from repro.service.telemetry import ServiceTelemetry

from conftest import small_db, small_workload

EXACT = 10_000  # nprobe past every list count: search becomes exact


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts (and leaves) with the null tracer + a fresh registry."""
    trace.disable()
    set_registry(None)
    yield
    trace.disable()
    set_registry(None)


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_span_nesting_and_ordering():
    t = Tracer()
    with t.span("outer", m=4):
        with t.span("inner"):
            pass
        with t.span("inner2"):
            pass
    evs = t.events()
    by_name = {e["name"]: e for e in evs}
    # children record before the enclosing span closes
    assert [e["name"] for e in evs] == ["inner", "inner2", "outer"]
    assert by_name["inner"]["args"]["parent"] == "outer"
    assert by_name["inner2"]["args"]["parent"] == "outer"
    assert "parent" not in by_name["outer"].get("args", {})
    assert by_name["outer"]["args"]["m"] == 4
    # children are contained in the parent's [ts, ts+dur] interval
    o = by_name["outer"]
    for child in ("inner", "inner2"):
        c = by_name[child]
        assert c["ts"] >= o["ts"]
        assert c["ts"] + c["dur"] <= o["ts"] + o["dur"] + 1e-6
    assert evs[0]["ts"] <= evs[1]["ts"]


def test_add_span_shares_service_clock():
    t = Tracer()
    t0 = time.perf_counter()
    time.sleep(0.01)
    t1 = time.perf_counter()
    t.add_span("queue.wait", t0, t1, qid=7)
    (ev,) = t.events()
    assert ev["ph"] == "X"
    assert 8_000 <= ev["dur"] <= 1_000_000  # ~10ms in trace microseconds
    assert ev["args"]["qid"] == 7


def test_chrome_trace_schema_valid_and_mangled():
    t = Tracer()
    with t.span("a"):
        pass
    t.instant("mark", x=1)
    t.counter("depth", 3)
    doc = t.to_chrome_trace()
    assert validate_chrome_trace(doc) == 3
    assert json.loads(json.dumps(doc)) == doc  # JSON-serializable
    # mangled documents fail with the offending index
    with pytest.raises(ValueError):
        validate_chrome_trace({"no_events": []})
    with pytest.raises(ValueError, match="event 0"):
        validate_chrome_trace([{"ph": "X", "ts": 0, "pid": 1, "tid": 1}])
    with pytest.raises(ValueError, match="phase"):
        validate_chrome_trace(
            [{"name": "a", "ph": "?", "ts": 0, "pid": 1, "tid": 1}]
        )
    with pytest.raises(ValueError, match="dur"):
        validate_chrome_trace(
            [{"name": "a", "ph": "X", "ts": 0, "pid": 1, "tid": 1, "dur": -1}]
        )


def test_ring_buffer_bounds_memory():
    t = Tracer(capacity=16)
    for i in range(100):
        with t.span(f"s{i}"):
            pass
    assert t.span_count == 100  # lifetime total survives eviction
    evs = t.events()
    assert len(evs) == 16  # bounded retention
    assert [e["name"] for e in evs] == [f"s{i}" for i in range(84, 100)]  # newest


def test_export_round_trip(tmp_path):
    t = Tracer()
    with t.span("flush", size=8):
        pass
    path = t.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert validate_chrome_trace(doc) == 1
    assert doc["traceEvents"][0]["name"] == "flush"


def test_threaded_tracer_no_lost_spans():
    t = Tracer(capacity=4096)

    def hammer(tid):
        for i in range(200):
            with t.span("work", tid=tid, i=i):
                pass

    threads = [threading.Thread(target=hammer, args=(n,)) for n in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert t.span_count == 1600
    assert len(t.events()) == 1600
    validate_chrome_trace(t.to_chrome_trace())
    # per-thread nesting stacks never leaked across threads
    assert all("parent" not in e.get("args", {}) for e in t.events())


def test_null_tracer_is_free():
    trace.disable()
    t = trace.get_tracer()
    assert isinstance(t, NullTracer) and not t.enabled
    # one shared no-op span object — no per-call allocation
    assert t.span("a") is t.span("b")
    tracemalloc.start()
    base = tracemalloc.take_snapshot()
    for _ in range(1000):
        with t.span("hot"):
            pass
        t.instant("x")
        t.counter("c", 1.0)
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    retained = sum(s.size_diff for s in after.compare_to(base, "lineno"))
    assert t.span_count == 0 and t.events() == []
    assert retained < 16_384  # nothing retained beyond tracemalloc noise


def test_enable_disable_swaps_process_tracer():
    t = trace.enable(capacity=8)
    assert trace.get_tracer() is t and t.enabled
    with trace.get_tracer().span("x"):
        pass
    assert t.span_count == 1
    trace.disable()
    with trace.get_tracer().span("y"):
        pass
    assert t.span_count == 1  # recorded nothing after disable


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_histogram_percentiles_bounded_error():
    h = Histogram()
    vals = np.linspace(0.001, 0.01, 1000)
    for v in vals:
        h.observe(float(v))
    s = h.snapshot()
    assert s["count"] == 1000
    assert s["min"] == pytest.approx(0.001)
    assert s["max"] == pytest.approx(0.01)
    # quarter-decade buckets: interpolated quantiles within ~35% of truth
    assert s["p50"] == pytest.approx(np.percentile(vals, 50), rel=0.35)
    assert s["p99"] == pytest.approx(np.percentile(vals, 99), rel=0.35)
    # quantiles clamp to the observed range even at the bucket edge
    assert s["min"] <= s["p50"] <= s["p99"] <= s["max"]


def test_histogram_empty_and_overflow():
    h = Histogram(bounds=[1.0, 10.0])
    assert h.snapshot()["count"] == 0
    h.observe(0.5)
    h.observe(5.0)
    h.observe(1e9)  # overflow bucket
    s = h.snapshot()
    assert s["count"] == 3
    assert s["max"] == 1e9


def test_registry_snapshot_and_kind_mismatch():
    reg = MetricsRegistry()
    reg.counter("hits").inc()
    reg.counter("hits").inc(2)
    reg.gauge("depth").set(5)
    reg.histogram("lat_s").observe(0.002)
    reg.attach_source("svc", lambda: {"queries": 9})
    snap = reg.snapshot()
    assert snap["hits"] == 3
    assert snap["depth"] == 5.0
    assert snap["lat_s"]["count"] == 1
    assert snap["svc"] == {"queries": 9}
    with pytest.raises(TypeError):
        reg.gauge("hits")  # name already bound to a counter
    # a dead source reports its error instead of poisoning the read
    reg.attach_source("dead", lambda: 1 / 0)
    assert "error" in reg.snapshot()["dead"]
    json.loads(reg.to_json())  # serializable end to end


def test_default_registry_carries_dispatch_source():
    snap = get_registry().snapshot()
    assert "dispatch" in snap
    assert {"knn_calls", "merge_calls"} <= set(snap["dispatch"])


def test_dispatch_stats_delta_since():
    a = DispatchStats()
    a.record_knn((4, 8, 16, 5))
    base = a.snapshot()
    a.record_knn((4, 8, 16, 5))
    a.record_knn((2, 8, 32, 5))
    a.record_merge()
    d = a.delta_since(base)
    assert d.knn_calls == 2
    assert d.merge_calls == 1
    assert d.shapes == {(2, 8, 32, 5)}  # only shapes new since the baseline


def test_telemetry_summary_single_sort_consistency():
    t = ServiceTelemetry()
    rng = np.random.default_rng(3)
    lats = rng.random(1000).tolist()
    t.record_flush(size=len(lats), queue_depth=0, knn_dispatches=1,
                   merge_dispatches=1, seconds=0.1, latencies=lats)
    s = t.summary()
    assert s["p50_latency_s"] == t.latency_percentile(50.0)
    assert s["p99_latency_s"] == t.latency_percentile(99.0)
    arr = np.sort(lats)
    assert abs(s["p50_latency_s"] - arr[len(arr) // 2]) < 0.01
    assert s["p99_latency_s"] >= s["p50_latency_s"]


# ---------------------------------------------------------------------------
# drift
# ---------------------------------------------------------------------------


def test_drift_share_shift_synthetic():
    mon = DriftMonitor(DriftConfig(window=200))
    mon.observe_queries(["A"] * 70 + ["B"] * 30, t=1.0)  # older half
    mon.observe_queries(["A"] * 30 + ["B"] * 70, t=2.0)  # recent half
    rep = mon.report()
    assert rep.n_window == 200
    assert rep.window_span_s == pytest.approx(1.0)
    assert rep.reference_shares == {"A": 0.7, "B": 0.3}
    assert rep.template_shares == {"A": 0.3, "B": 0.7}
    # TV distance: 0.5 * (|0.3-0.7| + |0.7-0.3|) = 0.4
    assert rep.share_shift == pytest.approx(0.4)
    json.loads(rep.to_json())


def test_drift_disjoint_and_stationary_extremes():
    mon = DriftMonitor(DriftConfig(window=100))
    mon.observe_queries(["A"] * 50, t=0.0)
    mon.observe_queries(["B"] * 50, t=1.0)
    assert mon.report().share_shift == pytest.approx(1.0)  # disjoint mixes
    mon2 = DriftMonitor(DriftConfig(window=100))
    mon2.observe_queries(["A", "B"] * 50, t=0.0)
    assert mon2.report().share_shift == pytest.approx(0.0)  # stationary
    assert DriftMonitor().report().share_shift == 0.0  # empty window


def test_drift_heat_and_growth():
    mon = DriftMonitor()
    mon.observe_probes({0: 30, 1: 10})
    mon.observe_probes({0: 30, 2: 10})
    rep = mon.report()
    assert rep.part_heat == {0: 0.75, 1: 0.125, 2: 0.125}
    mon.observe_delta(0, t=10.0)
    mon.observe_delta(100, t=12.0)
    rep = mon.report()
    assert rep.delta_rows == 100
    assert rep.delta_growth_per_s == pytest.approx(50.0)


def test_drift_growth_nonnegative_across_fold():
    """Regression: the raw delta row count resets to 0 at every refresh fold,
    so differencing it reported NEGATIVE growth across a fold. The monitor
    now keeps a monotone cumulative-inserts series: growth stays >= 0 and
    matches the true insert rate over the window."""
    mon = DriftMonitor()
    mon.observe_delta(100, t=0.0)  # 100 rows buffered
    mon.observe_delta(0, t=1.0)  # fold mid-window: buffer emptied
    mon.observe_delta(50, t=2.0)  # 50 more arrive after the fold
    rep = mon.report()
    assert rep.delta_rows == 50  # report still shows the raw buffer size
    assert rep.delta_growth_per_s >= 0.0
    # 50 net new rows arrived over the 2 s window after the first sample
    assert rep.delta_growth_per_s == pytest.approx(25.0)
    # consecutive folds and same-size re-fills stay monotone too
    mon.observe_delta(0, t=3.0)
    mon.observe_delta(50, t=4.0)
    assert mon.report().delta_growth_per_s == pytest.approx(25.0)


def test_drift_growth_nonnegative_through_service_fold():
    """Same regression through the real service: insert → flush → refresh
    (buffer resets) → insert → flush must never report negative growth."""
    db = small_db(n=600)
    wl = small_workload(db, n_queries=10)
    svc = _exact_service(db, wl)
    rng = np.random.default_rng(7)

    def one_round():
        svc.insert(rng.normal(size=(30, db.d)).astype(np.float32))
        for i in range(4):
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        svc.drain()

    one_round()
    svc.refresh()  # fold: the delta row count the next flush sees resets to 0
    one_round()
    rep = svc.drift_report()
    assert rep.delta_growth_per_s >= 0.0


def test_drift_traffic_snapshot_and_reset():
    mon = DriftMonitor(DriftConfig(window=16, reservoir=4))
    mon.observe_queries([("f", 1), ("f", 2)], t=1.0)
    mon.maybe_sample(np.ones(4, np.float32), ("f", 1), np.array([3]))
    traffic, samples = mon.traffic_snapshot()
    # raw filter tuples intact (report() stringifies them; reconstruction
    # needs the originals)
    assert [k for _, k in traffic] == [("f", 1), ("f", 2)]
    assert samples[0][1] == ("f", 1)
    mon.observe_delta(10, t=0.0)
    mon.reset()
    traffic, samples = mon.traffic_snapshot()
    assert traffic == [] and samples == []
    rep = mon.report()
    assert rep.n_window == 0 and rep.delta_rows == 0


def test_drift_reservoir_bounded_and_deterministic():
    cfg = DriftConfig(reservoir=8, seed=0)
    a, b = DriftMonitor(cfg), DriftMonitor(cfg)
    for mon in (a, b):
        for i in range(100):
            mon.maybe_sample(np.full(4, i, np.float32), (), np.array([i]))
    assert len(a._reservoir) == 8 == len(b._reservoir)
    assert [int(s[2][0]) for s in a._reservoir] == [int(s[2][0]) for s in b._reservoir]


def _exact_service(db, wl, **cfg_kw):
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    kw = dict(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0)
    kw.update(cfg_kw)
    return HQIService(hqi, ServiceConfig(**kw))


def test_drift_detects_midstream_template_shift_in_service():
    """Acceptance criterion: a template-share shift injected mid-stream
    through the real service shows up in ``drift_report()``, and the live
    recall probe scores 1.0 against brute force in exact mode."""
    db = small_db(n=1200)
    wl = small_workload(db, n_queries=80)
    svc = _exact_service(db, wl, drift_window=160, recall_reservoir=32)
    rows_a = np.where(wl.template_of <= 2)[0]  # templates {0,1,2} first...
    rows_b = np.where(wl.template_of >= 3)[0]  # ...then {3,4,5}
    for i in np.concatenate([np.repeat(rows_a, 2), np.repeat(rows_b, 2)]):
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
    svc.drain()
    rep = svc.drift_report(probe_recall=True)
    assert rep.share_shift > 0.8  # near-disjoint template sets
    shifted = set(rep.template_shares) - set(rep.reference_shares)
    assert shifted  # templates present only in the recent half
    assert rep.part_heat and abs(sum(rep.part_heat.values()) - 1.0) < 1e-6
    assert rep.recall_samples > 0
    assert rep.recall_at_k == pytest.approx(1.0)  # exact serving = perfect recall


def test_drift_recall_probe_sees_delta_rows():
    db = small_db(n=800)
    wl = small_workload(db, n_queries=30)
    svc = _exact_service(db, wl)
    newv = np.random.default_rng(5).normal(size=(20, db.d)).astype(np.float32)
    svc.insert(newv)  # served from the delta store, not the frozen index
    for i in range(wl.m):
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
    svc.drain()
    rep = svc.drift_report(probe_recall=True)
    assert rep.delta_rows == 20
    assert rep.recall_at_k == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# end-to-end service trace
# ---------------------------------------------------------------------------


def test_service_trace_end_to_end(tmp_path):
    """A traced serving run exports schema-valid Chrome JSON carrying the
    nested submit → queue.wait → flush → dispatch → merge → WAL spans."""
    from repro.store.wal import WriteAheadLog

    db = small_db(n=1200)
    wl = small_workload(db, n_queries=40)
    hqi = HQIIndex.build(db, wl, HQIConfig(min_partition_size=128, max_leaves=16))
    wal = WriteAheadLog(str(tmp_path / "wal"))
    svc = HQIService(
        hqi,
        ServiceConfig(k=wl.k, nprobe=EXACT, max_batch=16, deadline_s=0.0,
                      batch_vec=True),
        wal=wal,
    )
    tracer = trace.enable()
    try:
        for i in range(wl.m):
            svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
        svc.drain()
        svc.insert(np.zeros((3, db.d), dtype=np.float32))
        svc.refresh()
    finally:
        trace.disable()
    doc = tracer.to_chrome_trace()
    n = validate_chrome_trace(doc)
    assert n == tracer.span_count
    names = {e["name"] for e in doc["traceEvents"]}
    required = {
        "submit", "queue.wait", "flush", "flush.build", "flush.fulfill",
        "engine.search", "engine.route", "plan.build", "plan.execute",
        "queue.depth", "service.insert", "service.refresh", "wal.fsync",
    }
    assert required <= names, f"missing spans: {sorted(required - names)}"
    assert any(n_.startswith("dispatch.") for n_ in names)
    assert any(n_.startswith("merge.") for n_ in names)
    # nested: every dispatch span records its parent chain back to the flush
    disp = [e for e in doc["traceEvents"] if e["name"] == "dispatch.scan"]
    assert disp and all(e["args"]["parent"] == "plan.execute" for e in disp)
    # queue.wait spans carry qids and live inside the trace timeline
    qw = [e for e in doc["traceEvents"] if e["name"] == "queue.wait"]
    assert len(qw) == wl.m and all("qid" in e["args"] for e in qw)
    path = tracer.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        assert validate_chrome_trace(json.load(f)) == n
    # metrics registry saw the same run
    snap = get_registry().snapshot()
    assert snap["service.queue_wait_s"]["count"] == wl.m
    assert snap["wal.fsync_s"]["count"] >= 1
    assert snap["service"]["queries"] == wl.m


def test_untraced_service_records_nothing(db, workload):
    svc = _exact_service(db, workload)
    for i in range(8):
        svc.submit(workload.vectors[i], workload.templates[workload.template_of[i]])
    svc.drain()
    assert trace.get_tracer().span_count == 0
    assert [h for h in ()] == []  # results still flow (drain answered all)
    assert svc.telemetry.summary()["queries"] == 8
