"""Compressed execution path: ADC scan -> exact re-rank through the engine.

Covers the engine-level guarantees of scan_mode="pq": recall against the
exact (f32) engine on the KG-style workload, dispatch accounting (one ADC
dispatch per bucket + one re-rank + two merges), degenerate bitmaps, k
larger than every posting list (where full-coverage re-rank makes pq exactly
equal to f32), PQ-code integrity across incremental arena rebuilds, and the
serving layer picking the compressed path up transparently.
"""
import numpy as np
import pytest

from repro.core import (
    HQIConfig,
    HQIIndex,
    PackedArena,
    PlanConfig,
    encode_pq,
    recall_at_k,
    train_pq,
)
from repro.core.ivf import IVFIndex
from repro.core.planner import batch_search_ivf
from repro.core.types import SearchResult, Workload
from repro.core.workload import kg_style
from repro.kernels import ops
from repro.service import HQIService, ServiceConfig

from conftest import assert_same_results as _assert_same_results
from conftest import small_db, small_workload


def _search_mode(hqi, wl, mode, **kw):
    """Run one search under the given scan_mode (codes persist either way)."""
    prev = hqi.cfg.plan.scan_mode
    hqi.cfg.plan.scan_mode = mode
    try:
        return hqi.search(wl, **kw)
    finally:
        hqi.cfg.plan.scan_mode = prev


@pytest.fixture(scope="module")
def db():
    return small_db()


@pytest.fixture(scope="module")
def workload(db):
    return small_workload(db)


@pytest.fixture(scope="module")
def hqi_pq(db, workload):
    return HQIIndex.build(
        db,
        workload,
        HQIConfig(min_partition_size=128, max_leaves=32, scan_mode="pq", refine_factor=4),
    )


def test_pq_recall_kg_workload():
    """pq + re-rank recall@10 >= 0.8 vs the exact engine on KG-style data,
    with >= 4x less scan traffic (d=64, M=8: 32x on code tiles; the fixed
    per-query re-rank gather is what keeps the end-to-end ratio below that
    at this toy scale)."""
    kg = kg_style(n=4000, d=64, queries_per_split=120, seed=0)
    wl = kg.splits[0]
    assert wl.k == 10
    hqi = HQIIndex.build(
        kg.db,
        wl,
        HQIConfig(min_partition_size=256, max_leaves=16, scan_mode="pq", refine_factor=2),
    )
    exact = _search_mode(hqi, wl, "f32", nprobe=8)
    comp = _search_mode(hqi, wl, "pq", nprobe=8)
    r = recall_at_k(comp, exact)
    assert r >= 0.8, r
    assert exact.bytes_scanned >= 4 * comp.bytes_scanned, (
        exact.bytes_scanned,
        comp.bytes_scanned,
    )


def test_pq_dispatch_budget(db, workload, hqi_pq):
    """Compressed execution dispatches one ADC call per bucket + ONE re-rank
    + two merges (candidate merge + final merge) — O(buckets), never O(T×L)."""
    ops.reset_dispatch_stats()
    res = hqi_pq.search(workload, nprobe=6)
    st = ops.dispatch_stats()
    budget = hqi_pq.cfg.plan.max_bucket_shapes
    assert 0 < st.knn_calls <= budget + 1, st.knn_calls  # ADC buckets + re-rank
    assert st.merge_calls == 2
    # ADC dispatches are tagged ("pq-res" = resident-LUT segmented dispatch,
    # "pq" = the dense layout's expanded-LUT dispatch)
    assert any(s[0] in ("pq", "pq-res") for s in st.shapes)
    # and it still answers well vs the exact engine at the same nprobe
    exact = _search_mode(hqi_pq, workload, "f32", nprobe=6)
    assert recall_at_k(res, exact) >= 0.8


def test_pq_all_false_bitmap(db, workload, hqi_pq):
    """A template matching nothing yields (-inf, -1) rows through the ADC path."""
    from repro.core.predicates import Between, make_filter

    templates = [make_filter(Between("A", 5.0, 6.0))]  # A ∈ [0, 1): empty
    wl = Workload(
        vectors=workload.vectors[:7],
        templates=templates,
        template_of=np.zeros(7, dtype=np.int32),
        k=4,
    )
    res = hqi_pq.search(wl, nprobe=6)
    assert (res.ids == -1).all()
    assert np.isneginf(res.scores).all()


def test_pq_bitmap_pushdown(db, workload, hqi_pq):
    """ADC candidates already satisfy the filter: no dead row ever surfaces."""
    from repro.core.predicates import evaluate_filter

    res = hqi_pq.search(workload, nprobe=6)
    for ti, filt in enumerate(workload.templates):
        bitmap = evaluate_filter(filt, db)
        for q in workload.queries_for_template(ti):
            ids = res.ids[q]
            assert bitmap[ids[ids >= 0]].all(), (ti, q)


def test_pq_k_exceeds_posting_lists(db):
    """k past every list length: refine covers ALL candidates, so the
    compressed path re-ranks everything and equals f32 exactly."""
    ivf = IVFIndex.build(db.vectors[:300], metric=db.metric, n_centroids=32, seed=0)
    pq = train_pq(db.vectors[:300], 8, metric=db.metric, seed=0)
    rng = np.random.default_rng(5)
    q = rng.normal(size=(9, db.d)).astype(np.float32)
    k = 64  # lists average ~10 vectors; k' = 4k dwarfs every candidate set
    cfg_f = PlanConfig(tq_unit=4, min_list_pad=8)
    cfg_p = PlanConfig(tq_unit=4, min_list_pad=8, scan_mode="pq", refine_factor=4)
    fs, fi = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=cfg_f)
    ps, pi = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=cfg_p, pq=pq)
    _assert_same_results(ps, pi, fs, fi)
    assert (pi == -1).any()  # some padding must exist


def test_pq_uint8_codes_across_dispatch():
    """Both backends accept uint8 codes; the pallas path ships uint8 tiles."""
    rng = np.random.default_rng(3)
    luts = rng.normal(size=(2, 4, 8, 256)).astype(np.float32)
    codes = rng.integers(0, 256, size=(2, 60, 8), dtype=np.uint8)
    valid = rng.random((2, 60)) > 0.2
    s_j, i_j = ops.workunit_pq_topk(luts, codes, valid, 5, use_pallas=False)
    s_p, i_p = ops.workunit_pq_topk(luts, codes, valid, 5, use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(s_j), np.asarray(s_p), rtol=1e-4, atol=1e-4)
    for w in range(2):
        for r in range(4):
            a, b = np.asarray(i_j)[w, r], np.asarray(i_p)[w, r]
            assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())


def test_arena_codes_integrity_across_extend(db, workload):
    """extend() keeps arena codes row-aligned with packed storage: every code
    block (reused views AND re-encoded partitions) equals a fresh encode."""
    hqi = HQIIndex.build(
        db, workload, HQIConfig(min_partition_size=128, max_leaves=32, scan_mode="pq")
    )
    arena0 = hqi.arena  # materialize so extend() takes the updated() path
    assert arena0.codes is not None and arena0.codes.dtype == np.uint8
    np.testing.assert_array_equal(arena0.codes, encode_pq(hqi.pq, arena0.packed))

    new_db = small_db(n=150, seed=99, metric=db.metric)
    hqi.extend(new_db)
    arena1 = hqi.arena
    assert arena1 is not arena0 and arena1.n == db.n + 150
    assert arena1.codes.shape == (arena1.n, hqi.pq.m)
    np.testing.assert_array_equal(arena1.codes, encode_pq(hqi.pq, arena1.packed))
    # compressed search still works and respects the grown id space
    res = hqi.search(workload, nprobe=6)
    assert res.ids.max() < arena1.n


def test_arena_updated_reuses_unchanged_code_blocks(db, workload, monkeypatch):
    """PackedArena.updated re-encodes ONLY changed partitions' code blocks."""
    import repro.core.arena as arena_mod

    hqi = HQIIndex.build(
        db, workload, HQIConfig(min_partition_size=128, max_leaves=32, scan_mode="pq")
    )
    old = hqi.arena
    parts = [(p.rows, p.ivf) for p in hqi.partitions]
    calls = []
    real_encode = arena_mod.encode_pq
    monkeypatch.setattr(
        arena_mod, "encode_pq", lambda cb, v: calls.append(len(v)) or real_encode(cb, v)
    )
    new = PackedArena.updated(old, parts, changed=[])
    assert calls == []  # nothing changed -> nothing re-encoded
    np.testing.assert_array_equal(new.codes, old.codes)

    new2 = PackedArena.updated(old, parts, changed=[0])
    assert len(calls) == 1  # exactly the one changed partition
    np.testing.assert_array_equal(new2.codes, old.codes)


def test_scan_mode_override_does_not_mutate_shared_plan():
    """HQIConfig(scan_mode=...) must not flip a caller-shared PlanConfig."""
    plan = PlanConfig()
    HQIConfig(plan=plan, scan_mode="pq", refine_factor=2)
    assert plan.scan_mode == "f32" and plan.refine_factor == 4
    cfg = HQIConfig(plan=plan, scan_mode="pq")
    assert cfg.plan.scan_mode == "pq" and cfg.plan is not plan


def test_service_picks_up_compressed_path(db, workload):
    """HQIService flushes run the compressed engine transparently; delta rows
    stay exact f32 brute-force, so fresh inserts surface immediately."""
    hqi = HQIIndex.build(
        db,
        workload,
        HQIConfig(min_partition_size=128, max_leaves=16, scan_mode="pq", refine_factor=4),
    )
    svc = HQIService(
        hqi, ServiceConfig(k=workload.k, nprobe=10_000, max_batch=16, deadline_s=0.0)
    )
    handles = [
        svc.submit(workload.vectors[i], workload.templates[workload.template_of[i]])
        for i in range(workload.m)
    ]
    ops.reset_dispatch_stats()
    assert svc.drain() == workload.m
    ids = np.stack([h.ids for h in handles])
    scores = np.stack([h.scores for h in handles])

    exact = _search_mode(hqi, workload, "f32", nprobe=10_000)
    got = SearchResult(ids=ids, scores=scores)
    assert recall_at_k(got, exact) >= 0.8

    # a fresh insert that exactly matches a pure-vector query must be found
    # through the (exact) delta path at the very next flush
    pure_ti = workload.templates.index(())
    qrow = int(workload.queries_for_template(pure_ti)[0])
    new_ids = svc.insert(workload.vectors[qrow][None, :])
    h = svc.submit(workload.vectors[qrow], ())
    svc.drain()
    assert int(h.ids[0]) == int(new_ids[0])
