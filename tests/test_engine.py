"""Plan/execute engine: parity with per-query scans, dispatch budget, edges.

Covers the workload-wide execution engine (core/plan.py + core/planner.py +
core/arena.py): results must match the per-query ``search_single`` path
exactly across metrics, nprobe-as-dict, degenerate bitmaps, oversized k, and
single-partition trees — while issuing a bounded number of kernel dispatches.
"""
import numpy as np
import pytest

from repro.core import (
    HQIConfig,
    HQIIndex,
    PackedArena,
    PlanConfig,
    exhaustive_search,
    recall_at_k,
)
from repro.core.ivf import IVFIndex
from repro.core.plan import EngineTask, build_plan
from repro.core.planner import batch_search_ivf, execute_plan
from repro.kernels import ops

from conftest import assert_same_results as _assert_same_results
from conftest import small_db, small_workload


@pytest.mark.parametrize("metric", ["ip", "l2"])
def test_engine_matches_single_scan(metric):
    """batch_search_ivf (engine) == search_single, both metrics, with bitmap."""
    db = small_db(n=900, seed=11, metric=metric)
    ivf = IVFIndex.build(db.vectors, metric=metric, n_centroids=16, seed=0)
    rng = np.random.default_rng(11)
    q = rng.normal(size=(23, db.d)).astype(np.float32)
    bitmap = rng.random(db.n) < 0.4
    bs, bi = batch_search_ivf(
        ivf, q, nprobe=6, k=5, bitmap=bitmap, cfg=PlanConfig(tq_unit=8, min_list_pad=8)
    )
    ss = np.zeros_like(bs)
    si = np.zeros_like(bi)
    for r in range(q.shape[0]):
        ss[r], si[r] = ivf.search_single(q[r], nprobe=6, k=5, bitmap=bitmap)
    _assert_same_results(bs, bi, ss, si)


def test_engine_parity_sweep():
    """Seed/nprobe/bitmap sweep replacing the hypothesis property test."""
    for seed, nprobe, with_bitmap in [(0, 1, False), (7, 3, True), (42, 12, True)]:
        db = small_db(n=800, seed=seed)
        ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=12, seed=0)
        rng = np.random.default_rng(seed)
        q = rng.normal(size=(17, db.d)).astype(np.float32)
        bitmap = (rng.random(db.n) < 0.5) if with_bitmap else None
        bs, bi = batch_search_ivf(
            ivf, q, nprobe=nprobe, k=4, bitmap=bitmap,
            cfg=PlanConfig(tq_unit=8, min_list_pad=8),
        )
        for r in range(q.shape[0]):
            ss, si = ivf.search_single(q[r], nprobe=nprobe, k=4, bitmap=bitmap)
            _assert_same_results(bs[r : r + 1], bi[r : r + 1], ss[None], si[None])


@pytest.fixture(scope="module")
def db():
    return small_db()


@pytest.fixture(scope="module")
def workload(db):
    return small_workload(db)


@pytest.fixture(scope="module")
def hqi(db, workload):
    return HQIIndex.build(db, workload, HQIConfig(min_partition_size=128, max_leaves=32))


def test_dispatch_budget(db, workload, hqi):
    """The whole workload executes in ≤ max_bucket_shapes knn dispatches and
    one device-side merge, with results equal to the per-query path."""
    ops.reset_dispatch_stats()
    rb = hqi.search(workload, nprobe=6)
    st = ops.dispatch_stats()
    assert 0 < st.knn_calls <= hqi.cfg.plan.max_bucket_shapes, st.knn_calls
    assert st.merge_calls == 1
    assert len(st.shapes) <= hqi.cfg.plan.max_bucket_shapes

    ro = hqi.search_online(workload, nprobe=6)
    _assert_same_results(rb.scores, rb.ids, ro.scores, ro.ids)


def test_dispatch_budget_tight(db, workload):
    """A one-shape budget still returns exact results (everything coalesces)."""
    cfg = HQIConfig(
        min_partition_size=128,
        max_leaves=32,
        plan=PlanConfig(max_bucket_shapes=1, tq_unit=16, min_list_pad=8),
    )
    hqi = HQIIndex.build(db, workload, cfg)
    ops.reset_dispatch_stats()
    rb = hqi.search(workload, nprobe=6)
    assert ops.dispatch_stats().knn_calls == 1
    ro = hqi.search_online(workload, nprobe=6)
    _assert_same_results(rb.scores, rb.ids, ro.scores, ro.ids)


def test_nprobe_dict(db, workload, hqi):
    """Per-template nprobe dict routes through the engine unchanged."""
    nprobe = {ti: 3 + (ti % 4) for ti in range(len(workload.templates))}
    rb = hqi.search(workload, nprobe=nprobe)
    ro = hqi.search_online(workload, nprobe=nprobe)
    _assert_same_results(rb.scores, rb.ids, ro.scores, ro.ids)


def test_all_false_bitmap(db, workload, hqi):
    """A template matching nothing yields (-inf, -1) rows, no crash."""
    from repro.core.predicates import Between, make_filter
    from repro.core.types import Workload

    templates = [make_filter(Between("A", 5.0, 6.0))]  # A ∈ [0, 1): empty
    wl = Workload(
        vectors=workload.vectors[:7],
        templates=templates,
        template_of=np.zeros(7, dtype=np.int32),
        k=4,
    )
    res = hqi.search(wl, nprobe=6)
    assert (res.ids == -1).all()
    assert np.isneginf(res.scores).all()


def test_k_exceeds_posting_lists(db):
    """k larger than every posting list: engine pads with (-inf, -1)."""
    ivf = IVFIndex.build(db.vectors[:300], metric=db.metric, n_centroids=32, seed=0)
    rng = np.random.default_rng(5)
    q = rng.normal(size=(9, db.d)).astype(np.float32)
    k = 64  # lists average ~10 vectors
    bs, bi = batch_search_ivf(ivf, q, nprobe=3, k=k, cfg=PlanConfig(tq_unit=4, min_list_pad=8))
    for r in range(q.shape[0]):
        ss, si = ivf.search_single(q[r], nprobe=3, k=k)
        _assert_same_results(bs[r : r + 1], bi[r : r + 1], ss[None], si[None])
    assert (bi == -1).any()  # some padding must exist


def test_single_partition_tree(db, workload):
    """Degenerate qd-tree (one leaf) routes everything through one partition."""
    hqi = HQIIndex.build(
        db, workload, HQIConfig(min_partition_size=db.n + 1, max_leaves=1)
    )
    assert len(hqi.partitions) == 1
    truth = exhaustive_search(db, workload)
    res = hqi.search(workload, nprobe=10_000)
    assert recall_at_k(res, truth) == 1.0


def test_adaptive_mixes_paths(db, workload, hqi):
    """'auto' mixes engine tasks and host scans into one merged result."""
    ra = hqi.search(workload, nprobe=6, batch_vec="auto")
    rb = hqi.search(workload, nprobe=6, batch_vec=True)
    _assert_same_results(ra.scores, ra.ids, rb.scores, rb.ids)


def test_prefilter_stats_parity_with_dead_template(db):
    """batch_vec must report the same tuples_scanned as per-query scans even
    when a template's bitmap kills everything (the lists are still scanned)."""
    from repro.core import PreFilterIndex
    from repro.core.predicates import Between, make_filter
    from repro.core.types import Workload

    templates = [make_filter(Between("A", 5.0, 6.0)), make_filter(Between("A", 0.0, 0.5))]
    rng = np.random.default_rng(0)
    wl = Workload(
        vectors=rng.normal(size=(30, db.d)).astype(np.float32),
        templates=templates,
        template_of=(np.arange(30) % 2).astype(np.int32),
        k=5,
    )
    pre = PreFilterIndex.build(db)
    r_single = pre.search(wl, nprobe=6, batch_vec=False)
    r_batch = pre.search(wl, nprobe=6, batch_vec=True)
    assert r_single.tuples_scanned == r_batch.tuples_scanned
    _assert_same_results(r_batch.scores, r_batch.ids, r_single.scores, r_single.ids)


def test_lazy_arena(db, workload):
    """Per-query-only configurations never pay the arena concatenation."""
    hqi = HQIIndex.build(db, workload, HQIConfig(min_partition_size=128, max_leaves=32))
    hqi.search_online(workload, nprobe=6)
    assert hqi._arena is None
    hqi.search(workload, nprobe=6)
    assert hqi._arena is not None


def test_configs_not_shared():
    """Mutable-default regression: each build/search gets a fresh config."""
    db = small_db(n=400, seed=2)
    wl = small_workload(db, n_queries=10)
    h1 = HQIIndex.build(db, wl)
    h2 = HQIIndex.build(db, wl)
    assert h1.cfg is not h2.cfg
    assert h1.cfg.plan is not h2.cfg.plan


def test_workunit_entry_point_paths():
    """ops.workunit_topk: pallas (query- and db-stationary) == jnp reference."""
    rng = np.random.default_rng(9)
    for tq, tv in [(8, 64), (64, 32)]:  # tv≫tq picks db-stationary, other not
        q = rng.normal(size=(3, tq, 16)).astype(np.float32)
        v = rng.normal(size=(3, tv, 16)).astype(np.float32)
        valid = rng.random((3, tv)) < 0.7
        s_ref, i_ref = ops.workunit_topk(q, v, valid, 4, metric="ip", use_pallas=False)
        s_pl, i_pl = ops.workunit_topk(
            q, v, valid, 4, metric="ip", use_pallas=True, interpret=True
        )
        np.testing.assert_allclose(np.asarray(s_ref), np.asarray(s_pl), rtol=1e-5, atol=1e-5)
        for w in range(3):
            for r in range(tq):
                a = np.asarray(i_ref)[w, r]
                b = np.asarray(i_pl)[w, r]
                assert set(a[a >= 0].tolist()) == set(b[b >= 0].tolist())


def test_plan_shape_budget_structure(db):
    """build_plan never emits more buckets than the compile-shape budget."""
    ivf = IVFIndex.build(db.vectors, metric=db.metric, n_centroids=64, seed=0)
    arena = PackedArena.from_ivf(ivf)
    rng = np.random.default_rng(1)
    q = rng.normal(size=(50, db.d)).astype(np.float32)
    task = EngineTask(
        part=0, qrows=np.arange(50, dtype=np.int64), nprobe=16, packed_bitmap=None
    )
    for budget in (1, 2, 4):
        plan = build_plan(
            arena, [task], q, m=50, k=5,
            cfg=PlanConfig(max_bucket_shapes=budget, tq_unit=8, min_list_pad=8),
        )
        assert plan.n_dispatches <= budget
        s, i = execute_plan(plan, arena, q, cfg=PlanConfig())
        ss, si = batch_search_ivf(ivf, q, nprobe=16, k=5)
        _assert_same_results(s, i, ss, si)
