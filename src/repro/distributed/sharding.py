"""Logical-axis sharding rules → mesh shardings (DP / FSDP / TP / EP / SP).

Model code annotates activations with *logical* names via
``shard_activation(x, kind)`` and parameters are matched by path patterns.
The mapping from logical axes to mesh axes is a per-run table, so scaling
from the 8-device test mesh to the 512-chip multi-pod mesh only changes the
rules, never the model code.

Conventions (mesh axes: optional "pod", "data", "model"):
  batch        -> ("pod", "data")     activations' batch dim
  embed        -> None (replicated) or "data" under FSDP for params
  heads/mlp/kv -> "model"             tensor parallel param dims
  expert       -> "model"             expert parallel
  vocab        -> "model"
  seq          -> "model"             sequence parallelism for long prefill
"""
from __future__ import annotations

import dataclasses
import re
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# Beyond-paper optimization switches (EXPERIMENTS.md §Perf). Baseline mode
# (all False) reproduces the first-cut sharding/remat scheme so both variants
# stay measurable; dryrun.py --baseline flips them off.
OPT = {
    "kv_repeat": True,        # GQA: broadcast KV heads when TP > n_kv_heads
    "attn_inner_remat": True, # checkpoint the kv-scan body (flash-bwd style)
    "fsdp_dim0": True,        # FSDP over the stacked-layer dim (ZeRO-3 gathers)
    "moe_ep_data": True,      # experts sharded over data axis (EP) + TP inner
    "kv_cache_time_shard": False,  # decode: shard KV cache over time (see §Perf 6)
    "serve_bf16": False,      # serving params in bf16 (see §Perf 6)
}


def set_opt(**kw) -> None:
    for k, v in kw.items():
        if k not in OPT:
            raise KeyError(k)
        OPT[k] = v


def set_all_opt(value: bool) -> None:
    for k in OPT:
        OPT[k] = value


@dataclasses.dataclass
class ShardingRules:
    """How logical dims map to mesh axes for this run."""

    batch: Any = ("pod", "data")  # tuple = nested mapping over multiple axes
    seq: Any = None  # set to "model" for sequence-parallel prefill
    heads: Any = "model"
    kv: Any = "model"
    mlp: Any = "model"
    # EP axis: "data" (optimized — weights stationary per shard, tokens move
    # via a2a; composes with TP over "mlp") or "model" (baseline)
    expert: Any = None  # resolved lazily against OPT["moe_ep_data"]
    vocab: Any = "model"
    embed: Any = None  # "data" => FSDP: shard params' embed dim over data
    fsdp: bool = False
    # ZeRO-3-style stacked-dim placement: a big train win (per-layer gather
    # amortized over the batch) but a temp-memory loss for decode (§Perf 6) —
    # so it is a per-run choice, train-only by default.
    fsdp_stacked: bool = True
    mesh: Optional[Mesh] = None

    def axis(self, name: Optional[str]):
        if name is None:
            return None
        v = getattr(self, name)
        if name == "expert" and v is None:
            v = "data" if OPT["moe_ep_data"] else "model"
        if v is None:
            return None
        if isinstance(v, tuple):
            # only keep axes that exist in the mesh
            if self.mesh is None:
                return v
            kept = tuple(a for a in v if a in self.mesh.axis_names)
            return kept if kept else None
        if self.mesh is not None and v not in self.mesh.axis_names:
            return None
        return v


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions (check_vma vs check_rep kwarg)."""
    try:
        from jax import shard_map as _sm  # jax >= 0.6

        fn = _sm.shard_map if hasattr(_sm, "shard_map") else _sm
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
    except (TypeError, ImportError):
        from jax.experimental.shard_map import shard_map as fn  # type: ignore

        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


def set_rules(rules: Optional[ShardingRules]) -> None:
    _STATE.rules = rules


def get_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


class use_rules:
    def __init__(self, rules: Optional[ShardingRules]):
        self.rules = rules

    def __enter__(self):
        self.prev = get_rules()
        set_rules(self.rules)
        return self.rules

    def __exit__(self, *exc):
        set_rules(self.prev)


# -- activation annotations ---------------------------------------------------

_ACTIVATION_SPECS = {
    # kind -> logical dim names per trailing axis meaning; leading dims padded None
    "batch_seq": ("batch", "seq"),  # e.g. tokens [B, S]
    "hidden": ("batch", "seq", None),  # [B, S, D]
    "hidden_sp": ("batch", "seq", None),
    "mlp": ("batch", "seq", "mlp"),  # [B, S, F]
    "heads": ("batch", "seq", "heads", None),  # [B, S, H, Dh]
    "kv": ("batch", "seq", "kv", None),
    "kv_cache": ("batch", None, "kv", None),  # [B, T, Hkv, Dh]
    "logits": ("batch", "seq", "vocab"),
    "expert_buf": ("expert", None, None),  # [E, C, D]
}


def shard_activation(x: jax.Array, kind: str) -> jax.Array:
    """Annotate an intermediate with its logical sharding (no-op w/o rules)."""
    rules = get_rules()
    if rules is None:
        return x
    names = _ACTIVATION_SPECS[kind]
    if len(names) > x.ndim:
        return x
    pad = (None,) * (x.ndim - len(names))
    spec = P(*(pad + tuple(rules.axis(n) for n in names)))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x  # outside a mesh context


# -- parameter shardings ------------------------------------------------------

# path-pattern -> logical names per dim (matched right-aligned to the shape;
# leading stacked-layer dims are replicated). First match wins.
PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"router", ("embed", None)),  # router stays small; replicate experts dim
    # experts: EP over the expert dim + TP over the expert-ffn dim
    (r"experts?.*w_(up|gate)", ("expert", None, "mlp")),
    (r"experts?.*w_down", ("expert", "mlp", None)),
    (r"w_(up|gate)", ("embed", "mlp")),
    (r"w_down", ("mlp", "embed")),
    (r"(wq|w_q)", ("embed", "heads")),
    (r"(wk|w_k|wv|w_v)", ("embed", "kv")),
    (r"(wo|w_o)", ("heads", "embed")),
    (r"(bq)", ("heads",)),
    (r"(bk|bv)", ("kv",)),
    (r"embedding|unembed|lm_head", ("vocab", "embed")),
    (r"in_proj", ("embed", "mlp")),  # ssm projections: tp over inner dim
    (r"out_proj", ("mlp", "embed")),
    (r"conv", (None, "mlp")),
    (r".*", (None,)),  # default: replicate (norm scales, A_log, dt_bias, ...)
)


def _axes_size(rules: ShardingRules, axis) -> int:
    if axis is None or rules.mesh is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= rules.mesh.shape[a]
        return out
    return rules.mesh.shape[axis]


def _sanitize(axes, shape, rules: ShardingRules):
    """Drop mesh axes that don't evenly divide their dim (jit requires it)

    and duplicate mesh-axis uses (keep the first occurrence)."""
    out = []
    seen = set()
    for a, d in zip(axes, shape):
        ok = a is not None and d % _axes_size(rules, a) == 0
        names = a if isinstance(a, tuple) else (a,)
        if ok and any(n in seen for n in names):
            ok = False
        if ok:
            seen.update(names)
        out.append(a if ok else None)
    return out


def param_spec_for(path: str, shape: Tuple[int, ...], rules: ShardingRules) -> P:
    ndim = len(shape)
    for pat, names in PARAM_RULES:
        if re.search(pat, path):
            names = tuple(names)
            if len(names) > ndim:
                names = names[-ndim:] if ndim > 0 else ()
            pad = (None,) * (ndim - len(names))
            axes = _sanitize([rules.axis(n) for n in pad + names], shape, rules)
            # FSDP / ZeRO: shard one remaining replicated dim over the batch
            # axes (pod, data). Prefer the second-to-last dim (embed for
            # matmuls), skip the stacked-layer dim 0 of scanned params when
            # another choice exists.
            if rules.fsdp and rules.mesh is not None and "data" in rules.mesh.axis_names:
                batch_ax = rules.axis("batch") or "data"
                used = set()
                for a in axes:
                    used.update(a if isinstance(a, tuple) else (a,))
                b_names = batch_ax if isinstance(batch_ax, tuple) else (batch_ax,)
                if not any(n in used for n in b_names):
                    # Prefer the stacked-layer dim (dim 0 of scanned params):
                    # the scan's dynamic-slice then lowers to a per-layer
                    # one-shot gather (ZeRO-3 schedule). Sharding a matmul's
                    # contraction dim instead makes XLA either all-gather the
                    # weights per use or psum activation-sized partials —
                    # both measured catastrophically worse (§Perf log).
                    if ndim >= 3 and OPT["fsdp_dim0"] and rules.fsdp_stacked:
                        order = [0] + [i for i in range(ndim - 2, 0, -1)] + [ndim - 1]
                    elif ndim >= 3:
                        order = [i for i in range(ndim - 2, 0, -1)] + [ndim - 1, 0]
                    else:
                        order = list(range(ndim - 2, -1, -1)) + ([ndim - 1] if ndim else [])
                    for i in order:
                        if axes[i] is None and shape[i] % _axes_size(rules, batch_ax) == 0:
                            axes[i] = batch_ax
                            break
            return P(*axes)
    return P()


def tree_param_specs(params: Any, rules: ShardingRules) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def visit(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        return param_spec_for(pstr, tuple(leaf.shape), rules)

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_shardings(params: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    specs = tree_param_specs(params, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
