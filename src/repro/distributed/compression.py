"""Gradient compression for the data-parallel all-reduce path.

int8 uniform quantization with error feedback (EF-SGD style): each step
quantizes (grad + residual), all-gathers the int8 payload over the data axis,
dequantizes and averages locally, and carries the quantization error into the
next step. 4× less DP traffic than fp32 (2× vs bf16) at the cost of an
all-gather instead of an all-reduce (int8 summation would overflow and TPUs
reduce in the wide type anyway).

Used by the shard_map data-parallel training mode (train/fault_tolerance.py's
``dp_train_step_compressed``) and unit-tested for unbiasedness under error
feedback. The pjit path keeps XLA-native reductions.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residual):
    """(grads + residual) -> (q_tree, scale_tree, new_residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    trees = jax.tree.map(one, grads, residual)
    q = jax.tree.map(lambda t: t[0], trees, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], trees, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[2], trees, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, res


def allreduce_compressed(q_tree, s_tree, axis_name: str):
    """All-gather int8 payloads across ``axis_name`` and average locally."""

    def one(q, s):
        qg = jax.lax.all_gather(q, axis_name)  # [N, ...] int8
        sg = jax.lax.all_gather(s, axis_name)  # [N]
        deq = qg.astype(jnp.float32) * sg.reshape((-1,) + (1,) * (qg.ndim - 1))
        return deq.mean(axis=0)

    return jax.tree.map(one, q_tree, s_tree)


def zero_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
