"""Prefill + single-token decode for every family (the serving path).

Cache layouts (stacked over layers for scan):
  dense/vlm/moe: k/v [L, B, T, Hkv, dh] + cache_len int32 [B]
  ssm:           ssm [L, B, H, N, P], conv [L, B, W-1, C]   (O(1) per token)
  hybrid:        ssm [G·E ssm states] + per-group KV for the shared block

``decode_32k`` lowers ``decode_step`` with a 32k cache; ``long_500k`` only
applies to ssm/hybrid where per-token state is O(1)/O(window).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation
from .attention import attention_block
from .layers import embed, rmsnorm, unembed
from .moe import moe_layer
from .ssm import ssm_block
from .transformer import ModelConfig, _dense_body, _moe_body, _ssm_body


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Dict[str, Any]:
    dtype = dtype or cfg.dtype
    dh = cfg.dh
    cache: Dict[str, Any] = {"len": jnp.zeros((batch,), jnp.int32)}
    if cfg.family in ("dense", "vlm", "moe"):
        n_attn = cfg.n_layers
        cache["k"] = jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads, dh), dtype)
        cache["v"] = jnp.zeros((n_attn, batch, max_len, cfg.n_kv_heads, dh), dtype)
    elif cfg.family == "ssm":
        s = cfg.ssm
        cache["ssm"] = jnp.zeros((cfg.n_layers, batch, s.n_heads, s.d_state, s.head_dim), jnp.float32)
        cache["conv"] = jnp.zeros(
            (cfg.n_layers, batch, s.conv_width - 1, s.d_inner + 2 * s.d_state), dtype
        )
    elif cfg.family == "hybrid":
        s = cfg.ssm
        e = cfg.hybrid_attn_every
        g = cfg.n_layers // e
        cache["ssm"] = jnp.zeros((g, e, batch, s.n_heads, s.d_state, s.head_dim), jnp.float32)
        cache["conv"] = jnp.zeros(
            (g, e, batch, s.conv_width - 1, s.d_inner + 2 * s.d_state), dtype
        )
        cache["k"] = jnp.zeros((g, batch, max_len, cfg.n_kv_heads, dh), dtype)
        cache["v"] = jnp.zeros((g, batch, max_len, cfg.n_kv_heads, dh), dtype)
    else:
        raise ValueError(cfg.family)
    return cache


# ---------------------------------------------------------------------------
# prefill — full-sequence forward that also fills the cache
# ---------------------------------------------------------------------------


def prefill(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,  # [B, S]
    *,
    vision_embeds: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (logits of last position [B, V], cache covering the prompt)."""
    x = embed(params["embedding"], tokens, cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = shard_activation(x, "hidden")
    cache: Dict[str, Any] = {"len": jnp.full((b,), s, jnp.int32)}

    if cfg.family in ("dense", "vlm", "moe"):
        windows = cfg.layer_windows()
        body = _moe_body if cfg.family == "moe" else _dense_body

        nd = cfg.moe_first_dense if cfg.family == "moe" else 0
        ks, vs = [], []
        if nd:
            def dense_scan(x, inp):
                lp, w = inp
                xh = rmsnorm(x, lp["attn_norm"])
                h, (k, v) = attention_block(
                    lp["attn"], xh, cfg.attn_cfg(), positions=positions, window=w,
                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                )
                x = x + h
                from .layers import mlp as _mlp

                x = x + _mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]))
                return x, (k, v)

            x, (kd, vd) = jax.lax.scan(dense_scan, x, (params["dense_layers"], windows[:nd]))
            ks.append(kd)
            vs.append(vd)

        def scan_body(x, inp):
            lp, w = inp
            if cfg.family == "moe":
                xh = rmsnorm(x, lp["attn_norm"])
                h, (k, v) = attention_block(
                    lp["attn"], xh, cfg.attn_cfg(), positions=positions, window=w,
                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                )
                x = x + h
                y, _ = moe_layer(lp["moe"], rmsnorm(x, lp["mlp_norm"]), cfg.moe)
                x = x + y
            else:
                xh = rmsnorm(x, lp["attn_norm"])
                h, (k, v) = attention_block(
                    lp["attn"], xh, cfg.attn_cfg(), positions=positions, window=w,
                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                )
                x = x + h
                from .layers import mlp as _mlp

                x = x + _mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]))
            return x, (k, v)

        fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
        x, (km, vm) = jax.lax.scan(fn, x, (params["layers"], windows[nd:]))
        ks.append(km)
        vs.append(vm)
        cache["k"] = jnp.concatenate(ks, axis=0) if len(ks) > 1 else ks[0]
        cache["v"] = jnp.concatenate(vs, axis=0) if len(vs) > 1 else vs[0]

    elif cfg.family == "ssm":
        def scan_body(x, lp):
            x, st = _ssm_body(cfg, lp, x, None)
            return x, (st["ssm"], st["conv"])

        fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
        x, (ssm_st, conv_st) = jax.lax.scan(fn, x, params["layers"])
        cache["ssm"], cache["conv"] = ssm_st, conv_st

    elif cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        g = cfg.n_layers // e
        grouped = jax.tree.map(lambda a: a.reshape((g, e) + a.shape[1:]), params["layers"])
        shared = params["shared_attn"]

        def group_body(x, glp):
            def inner(x, lp):
                x, st = _ssm_body(cfg, lp, x, None)
                return x, (st["ssm"], st["conv"])

            x, (s_st, c_st) = jax.lax.scan(inner, x, glp)
            xh = rmsnorm(x, shared["attn_norm"])
            h, (k, v) = attention_block(
                shared["attn"], xh, cfg.attn_cfg(), positions=positions,
                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
            )
            x = x + h
            from .layers import mlp as _mlp

            x = x + _mlp(shared["mlp"], rmsnorm(x, shared["mlp_norm"]))
            return x, (s_st, c_st, k, v)

        fn = jax.checkpoint(group_body) if cfg.remat else group_body
        x, (s_st, c_st, k, v) = jax.lax.scan(fn, x, grouped)
        cache["ssm"], cache["conv"], cache["k"], cache["v"] = s_st, c_st, k, v
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"])
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x[:, -1:], head)[:, 0]
    return logits, cache


# ---------------------------------------------------------------------------
# decode — one new token against the cache
# ---------------------------------------------------------------------------


def decode_step(
    params: Dict[str, Any],
    cfg: ModelConfig,
    token: jax.Array,  # int32 [B] — the newest token
    cache: Dict[str, Any],
) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (logits [B, V], updated cache). cache["len"] counts tokens

    already in the cache; the new token is written at index cache["len"]."""
    b = token.shape[0]
    new_len = cache["len"] + 1
    positions = (new_len - 1)[:, None]  # [B, 1]
    x = embed(params["embedding"], token[:, None], cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)

    if cfg.family in ("dense", "vlm", "moe"):
        windows = cfg.layer_windows()

        def scan_body(x, inp):
            lp, w, kc, vc = inp
            if cfg.family == "moe" and "moe" in lp:
                x, (kc, vc), _ = _moe_body(cfg, lp, x, positions, w, (kc, vc), new_len)
            else:
                x, (kc, vc), _ = _dense_body(cfg, lp, x, positions, w, (kc, vc), new_len)
            return x, (kc, vc)

        nd = cfg.moe_first_dense if cfg.family == "moe" else 0
        if nd:
            x, (new_k, new_v) = _decode_scan_split(
                cfg, params, x, positions, windows, cache, new_len
            )
        else:
            x, (new_k, new_v) = jax.lax.scan(
                scan_body, x, (params["layers"], windows, cache["k"], cache["v"])
            )
        cache = dict(cache, k=new_k, v=new_v, len=new_len)

    elif cfg.family == "ssm":
        def scan_body(x, inp):
            lp, s_st, c_st = inp
            x, st = _ssm_body(cfg, lp, x, {"ssm": s_st, "conv": c_st})
            return x, (st["ssm"], st["conv"])

        x, (s_st, c_st) = jax.lax.scan(scan_body, x, (params["layers"], cache["ssm"], cache["conv"]))
        cache = dict(cache, ssm=s_st, conv=c_st, len=new_len)

    elif cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        g = cfg.n_layers // e
        grouped = jax.tree.map(lambda a: a.reshape((g, e) + a.shape[1:]), params["layers"])
        shared = params["shared_attn"]

        def group_body(x, inp):
            glp, s_st, c_st, kc, vc = inp

            def inner(x, inp2):
                lp, s1, c1 = inp2
                x, st = _ssm_body(cfg, lp, x, {"ssm": s1, "conv": c1})
                return x, (st["ssm"], st["conv"])

            x, (s_st, c_st) = jax.lax.scan(inner, x, (glp, s_st, c_st))
            x, (kc, vc), _ = _dense_body(cfg, shared, x, positions, jnp.int32(0), (kc, vc), new_len)
            return x, (s_st, c_st, kc, vc)

        x, (s_st, c_st, kc, vc) = jax.lax.scan(
            group_body, x, (grouped, cache["ssm"], cache["conv"], cache["k"], cache["v"])
        )
        cache = dict(cache, ssm=s_st, conv=c_st, k=kc, v=vc, len=new_len)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"])
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, head)[:, 0]
    return logits, cache


def _decode_scan_split(cfg, params, x, positions, windows, cache, new_len):
    """MoE models with leading dense layers: two scans over the shared cache."""
    nd = cfg.moe_first_dense

    def dense_scan(x, inp):
        lp, w, kc, vc = inp
        x, (kc, vc), _ = _dense_body(cfg, lp, x, positions, w, (kc, vc), new_len)
        return x, (kc, vc)

    def moe_scan(x, inp):
        lp, w, kc, vc = inp
        x, (kc, vc), _ = _moe_body(cfg, lp, x, positions, w, (kc, vc), new_len)
        return x, (kc, vc)

    x, (k0, v0) = jax.lax.scan(
        dense_scan, x, (params["dense_layers"], windows[:nd], cache["k"][:nd], cache["v"][:nd])
    )
    x, (k1, v1) = jax.lax.scan(
        moe_scan, x, (params["layers"], windows[nd:], cache["k"][nd:], cache["v"][nd:])
    )
    return x, (jnp.concatenate([k0, k1], 0), jnp.concatenate([v0, v1], 0))
