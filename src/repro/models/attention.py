"""Attention: GQA with RoPE, qk-norm, optional QKV bias, sliding windows.

Training/prefill uses a double-chunked online-softmax ("flash") formulation:
outer ``lax.map`` over query chunks, inner ``lax.scan`` over KV chunks with
running (max, sum, acc) — peak memory O(q_chunk × kv_chunk) instead of
O(S²). This pure-JAX path is what the 512-device dry-run lowers; the Pallas
TPU kernel (kernels/flash_attention.py) is the on-hardware hot path and is
validated against the same oracle.

Decode (single new token against a KV cache) is a masked single-step
softmax — O(T) with no materialized S×T anything.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation
from .layers import _dense_init, init_rmsnorm, rmsnorm, rope

NEG_INF = float(-3.0e38)


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: Optional[int] = None
    qk_norm: bool = False  # qwen3-style per-head RMSNorm on q, k
    qkv_bias: bool = False  # qwen1.5-style
    rope_theta: float = 10000.0
    causal: bool = True

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads


def init_attention(key, cfg: AttnConfig) -> Dict[str, Any]:
    dh = cfg.dh
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(kq, (cfg.d_model, cfg.n_heads * dh)),
        "wk": _dense_init(kk, (cfg.d_model, cfg.n_kv_heads * dh)),
        "wv": _dense_init(kv, (cfg.d_model, cfg.n_kv_heads * dh)),
        "wo": _dense_init(ko, (cfg.n_heads * dh, cfg.d_model), scale=(cfg.n_heads * dh) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * dh,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(dh)
        p["k_norm"] = init_rmsnorm(dh)
    return p


def qkv_project(
    p: Dict[str, Any], x: jax.Array, cfg: AttnConfig, positions: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [B, S, D] -> q [B,S,Hq,dh], k/v [B,S,Hkv,dh] (roped, normed)."""
    b, s, _ = x.shape
    dh = cfg.dh
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, dh)
    k = k.reshape(b, s, cfg.n_kv_heads, dh)
    v = v.reshape(b, s, cfg.n_kv_heads, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard_activation(q, "heads")
    k = shard_activation(k, "kv")
    v = shard_activation(v, "kv")
    return q, k, v


def flash_attention(
    q: jax.Array,  # [B, S, Hq, dh]
    k: jax.Array,  # [B, T, Hkv, dh]
    v: jax.Array,  # [B, T, Hkv, dh]
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,  # int32 scalar; 0/None = global
    q_offset: int | jax.Array = 0,  # absolute position of q[0] (decode)
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Double-chunked online-softmax attention. Returns [B, S, Hq, dh]."""
    b, s, hq, dh = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    scale = dh**-0.5

    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    # pad to multiples
    sp = ((s + q_chunk - 1) // q_chunk) * q_chunk
    tp = ((t + kv_chunk - 1) // kv_chunk) * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    nq, nk = sp // q_chunk, tp // kv_chunk

    qp = qp.reshape(b, nq, q_chunk, hkv, g, dh)
    kp = kp.reshape(b, nk, kv_chunk, hkv, dh)
    vp = vp.reshape(b, nk, kv_chunk, hkv, dh)

    w = window if window is not None else jnp.int32(0)
    w = jnp.asarray(w, jnp.int32)

    def q_block(args):
        qi, qc = args  # qi scalar chunk index, qc [b, q_chunk, hkv, g, dh]
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset  # [q_chunk]

        def kv_step(carry, inp):
            m, l, acc = carry
            ki, kc, vc = inp  # kc/vc [b, kv_chunk, hkv, dh]
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            logits = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qc.astype(jnp.float32) * scale, kc.astype(jnp.float32)
            )  # [b, hkv, g, q_chunk, kv_chunk]
            mask = k_pos[None, :] < t  # in-range (unpadded)
            mask = jnp.broadcast_to(mask, (q_chunk, kv_chunk))
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            mask = mask & ((w <= 0) | (k_pos[None, :] > q_pos[:, None] - w))
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, q_chunk, dh), jnp.float32)
        ks = jnp.arange(nk)
        # checkpointed body: the scan's backward then recomputes the chunk
        # probabilities instead of stacking O(S²/chunk) softmax residuals —
        # flash-attention backward semantics without a custom VJP
        # (measured: removes the dominant 4×4.5TB DUS traffic, §Perf log)
        from ..distributed.sharding import OPT

        step_fn = (
            jax.checkpoint(kv_step, policy=jax.checkpoint_policies.nothing_saveable)
            if OPT["attn_inner_remat"]
            else kv_step
        )
        (m, l, acc), _ = jax.lax.scan(
            step_fn, (m0, l0, a0), (ks, jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0))
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.einsum("bhgqd->bqhgd", out)  # [b, q_chunk, hkv, g, dh]

    outs = jax.lax.map(q_block, (jnp.arange(nq), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sp, hq, dh)[:, :s]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # [B, 1, Hq, dh] — one new token
    k_cache: jax.Array,  # [B, T, Hkv, dh]
    v_cache: jax.Array,  # [B, T, Hkv, dh]
    cache_len: jax.Array,  # int32 [B] — valid prefix length (incl. new token)
    *,
    window: Optional[jax.Array] = None,
) -> jax.Array:
    b, _, hq, dh = q.shape
    t, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = dh**-0.5
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32) * scale
    logits = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(t)[None, :]  # [1, T]
    mask = pos < cache_len[:, None]
    if window is not None:
        w = jnp.asarray(window, jnp.int32)
        mask = mask & ((w <= 0) | (pos > cache_len[:, None] - 1 - w))
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def _kv_repeat_for_tp(k: jax.Array, v: jax.Array, hq: int):
    """GQA sharding alignment: when the tensor-parallel degree exceeds the

    number of KV heads, the (hkv, group) head split forces XLA to reshard the
    S×S logits between incompatible layouts (observed as 'involuntary full
    rematerialization' + TB-scale logit all-gathers in the lowered HLO).
    Broadcasting KV to the full query-head count keeps ONE head axis that
    shards evenly everywhere; the extra KV bytes are chunk-local and ~100×
    smaller than the logit traffic they remove. See EXPERIMENTS.md §Perf."""
    from ..distributed.sharding import OPT, get_rules

    rules = get_rules()
    hkv = k.shape[2]
    if not OPT["kv_repeat"] or rules is None or rules.mesh is None or hkv == hq:
        return k, v
    tp = rules.mesh.shape.get("model", 1)
    if hkv % tp == 0:
        return k, v  # already evenly shardable
    g = hq // hkv
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    return k, v


def attention_block(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: AttnConfig,
    *,
    positions: jax.Array,
    window: Optional[jax.Array] = None,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_len: Optional[jax.Array] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Full attention sub-block (projections + attention + output proj).

    Without cache: training/prefill; returns (out, (k, v)) for cache init.
    With cache: decode; x is [B, 1, D], cache is updated at ``cache_len - 1``.
    """
    b, s, _ = x.shape
    q, k, v = qkv_project(p, x, cfg, positions)
    if kv_cache is None:
        ka, va = _kv_repeat_for_tp(k, v, cfg.n_heads)
        # O(S²) residuals are avoided by the checkpointed kv-scan body inside
        # flash_attention (flash-backward semantics); a second whole-attention
        # checkpoint here was measured to only add a redundant forward
        # recompute (§Perf log iteration 3).
        out = flash_attention(
            q, ka, va, causal=cfg.causal, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk
        )
        new_cache = (k, v)  # cache keeps the compact GQA heads
    else:
        kc, vc = kv_cache
        idx = cache_len - 1  # position of the new token, per batch row
        kc = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0)))(
            kc, k, idx
        )
        vc = jax.vmap(lambda c, upd, i: jax.lax.dynamic_update_slice(c, upd, (i, 0, 0)))(
            vc, v, idx
        )
        out = decode_attention(q, kc, vc, cache_len, window=window)
        new_cache = (kc, vc)
    out = out.reshape(b, s, -1)
    return out @ p["wo"].astype(x.dtype), new_cache
