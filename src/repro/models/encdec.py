"""Whisper-style encoder-decoder backbone.

Per the assignment spec the conv/audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [B, F, d_model] (as if emitted by the
two-conv downsampler). The encoder is bidirectional attention over frames;
the decoder is a causal LM with cross-attention to the encoder output.

Decode shapes exercise the decoder with cached self-KV and precomputed
cross-KV; ``long_500k`` is skipped (full quadratic attention).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation
from .attention import AttnConfig, attention_block, decode_attention, init_attention, qkv_project
from .layers import _dense_init, embed, init_embedding, init_mlp, init_rmsnorm, mlp, rmsnorm, unembed
from .transformer import ModelConfig


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    half = channels // 2
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def _cross_attention(p, x, enc_kv, cfg: AttnConfig):
    """x [B, S, D] attends to precomputed encoder K/V [B, F, Hkv, dh]."""
    b, s, _ = x.shape
    dh = cfg.dh
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, cfg.n_heads, dh)
    k, v = enc_kv
    f = k.shape[1]
    hkv = k.shape[2]
    g = cfg.n_heads // hkv
    scale = dh**-0.5
    logits = jnp.einsum(
        "bshgd,bfhd->bhgsf",
        q.reshape(b, s, hkv, g, dh).astype(jnp.float32) * scale,
        k.astype(jnp.float32),
    )
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgsf,bfhd->bshgd", probs, v.astype(jnp.float32))
    out = out.reshape(b, s, -1).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype)


def cross_kv(p, enc_out: jax.Array, cfg: AttnConfig):
    b, f, _ = enc_out.shape
    dh = cfg.dh
    k = (enc_out @ p["wk"].astype(enc_out.dtype)).reshape(b, f, cfg.n_kv_heads, dh)
    v = (enc_out @ p["wv"].astype(enc_out.dtype)).reshape(b, f, cfg.n_kv_heads, dh)
    return k, v


def init_encdec(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, cfg.attn_cfg(causal=False)),
            "mlp_norm": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, gated=False),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "attn_norm": init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, cfg.attn_cfg(causal=True)),
            "xattn_norm": init_rmsnorm(cfg.d_model),
            "xattn": init_attention(k2, cfg.attn_cfg(causal=False)),
            "mlp_norm": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, gated=False),
        }

    def stack(key, n, fn):
        return jax.vmap(fn)(jax.random.split(key, n))

    return {
        "embedding": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "enc_layers": stack(ks[1], cfg.encoder_layers, enc_layer),
        "enc_norm": init_rmsnorm(cfg.d_model),
        "dec_layers": stack(ks[2], cfg.n_layers, dec_layer),
        "final_norm": init_rmsnorm(cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames [B, F, D] (stub frontend output) -> encoder states [B, F, D]."""
    b, f, _ = frames.shape
    x = frames.astype(cfg.dtype) + sinusoids(f, cfg.d_model).astype(cfg.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(f)[None, :], (b, f))
    x = shard_activation(x, "hidden")

    def scan_body(x, lp):
        h, _ = attention_block(
            lp["attn"], rmsnorm(x, lp["attn_norm"]), cfg.attn_cfg(causal=False),
            positions=positions, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
        x = x + h
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]), act="gelu")
        return shard_activation(x, "hidden"), None

    fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
    x, _ = jax.lax.scan(fn, x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"])


def decode_train(params, cfg: ModelConfig, tokens: jax.Array, enc_out: jax.Array) -> jax.Array:
    """Teacher-forced decoder -> logits [B, S, V]."""
    b, s = tokens.shape
    x = embed(params["embedding"], tokens, cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = shard_activation(x, "hidden")
    acfg = cfg.attn_cfg()

    def scan_body(x, lp):
        h, _ = attention_block(
            lp["attn"], rmsnorm(x, lp["attn_norm"]), acfg, positions=positions,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
        x = x + h
        kv = cross_kv(lp["xattn"], enc_out, acfg)
        x = x + _cross_attention(lp["xattn"], rmsnorm(x, lp["xattn_norm"]), kv, acfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]), act="gelu")
        return shard_activation(x, "hidden"), None

    fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
    x, _ = jax.lax.scan(fn, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"])
    return unembed(x, params["embedding"])


def encdec_forward(params, cfg: ModelConfig, frames, tokens):
    enc_out = encode(params, cfg, frames)
    return decode_train(params, cfg, tokens, enc_out)


# -- serving ------------------------------------------------------------------


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int):
    dh = cfg.dh
    return {
        "len": jnp.zeros((batch,), jnp.int32),
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, dh), cfg.dtype),
        # cross K/V filled at prefill from the encoder output
        "xk": jnp.zeros((cfg.n_layers, batch, cfg.encoder_frames, cfg.n_kv_heads, dh), cfg.dtype),
        "xv": jnp.zeros((cfg.n_layers, batch, cfg.encoder_frames, cfg.n_kv_heads, dh), cfg.dtype),
    }


def encdec_prefill(params, cfg: ModelConfig, frames, tokens):
    """Encode audio + teacher-force the prompt; returns (last logits, cache)."""
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    x = embed(params["embedding"], tokens, cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    acfg = cfg.attn_cfg()

    def scan_body(x, lp):
        h, (k, v) = attention_block(
            lp["attn"], rmsnorm(x, lp["attn_norm"]), acfg, positions=positions,
            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
        )
        x = x + h
        xk, xv = cross_kv(lp["xattn"], enc_out, acfg)
        x = x + _cross_attention(lp["xattn"], rmsnorm(x, lp["xattn_norm"]), (xk, xv), acfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]), act="gelu")
        return x, (k, v, xk, xv)

    fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
    x, (k, v, xk, xv) = jax.lax.scan(fn, x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"])
    logits = unembed(x[:, -1:], params["embedding"])[:, 0]
    cache = {"len": jnp.full((b,), s, jnp.int32), "k": k, "v": v, "xk": xk, "xv": xv}
    return logits, cache


def encdec_decode_step(params, cfg: ModelConfig, token: jax.Array, cache):
    b = token.shape[0]
    new_len = cache["len"] + 1
    positions = (new_len - 1)[:, None]
    x = embed(params["embedding"], token[:, None], cfg.dtype)
    acfg = cfg.attn_cfg()

    def scan_body(x, inp):
        lp, kc, vc, xk, xv = inp
        h, (kc, vc) = attention_block(
            lp["attn"], rmsnorm(x, lp["attn_norm"]), acfg, positions=positions,
            kv_cache=(kc, vc), cache_len=new_len,
        )
        x = x + h
        x = x + _cross_attention(lp["xattn"], rmsnorm(x, lp["xattn_norm"]), (xk, xv), acfg)
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]), act="gelu")
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(
        scan_body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"])
    )
    x = rmsnorm(x, params["final_norm"])
    logits = unembed(x, params["embedding"])[:, 0]
    return logits, dict(cache, k=k, v=v, len=new_len)
