"""Decoder-only LM assembly covering the assigned families:

  dense  — llama-like GQA (minicpm, qwen3 w/ qk_norm, qwen1.5 w/ qkv bias)
  window — gemma3-style repeating local:global attention pattern
  moe    — deepseek/kimi-style shared+routed experts with leading dense layers
  ssm    — mamba2 pure SSD stacks
  hybrid — zamba2-style: groups of SSD layers + one weight-shared attention
           block applied per group (distinct KV per invocation)
  vlm    — internvl2-style: precomputed patch embeddings prepended to tokens

One config dataclass drives init/forward/prefill/decode; layers are stacked
and scanned (one compiled layer body — keeps dry-run compile time and HLO
size flat in depth), with optional remat on the layer body.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation
from .attention import AttnConfig, attention_block, init_attention
from .layers import embed, init_embedding, init_mlp, init_rmsnorm, mlp, rmsnorm, unembed
from .moe import MoEConfig, init_moe, moe_layer
from .ssm import SSMConfig, init_ssm, ssm_block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # cycle of per-layer sliding windows; 0 = global. gemma3: (w,w,w,w,w,0)
    window_pattern: Optional[Tuple[int, ...]] = None
    moe: Optional[MoEConfig] = None
    moe_first_dense: int = 0
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2: shared attn after every k ssm layers
    # enc-dec (whisper): see encdec.py
    encoder_layers: int = 0
    encoder_frames: int = 0
    # vlm: number of stub patch embeddings prepended
    vision_patches: int = 0
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scaling
    dtype: Any = jnp.bfloat16
    remat: bool = True
    q_chunk: int = 1024
    kv_chunk: int = 1024

    @property
    def dh(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def attn_cfg(self, causal: bool = True) -> AttnConfig:
        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qk_norm=self.qk_norm,
            qkv_bias=self.qkv_bias,
            rope_theta=self.rope_theta,
            causal=causal,
        )

    def layer_windows(self) -> jnp.ndarray:
        """int32 [n_layers] sliding window per layer (0 = global)."""
        if self.window_pattern is None:
            return jnp.zeros((self.n_layers,), jnp.int32)
        pat = list(self.window_pattern)
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return jnp.asarray((pat * reps)[: self.n_layers], jnp.int32)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _stack_init(key, n: int, init_fn):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_lm(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embedding": init_embedding(ks[0], cfg.vocab, cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(ks[1], cfg.vocab, cfg.d_model)

    def dense_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "attn_norm": init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, cfg.attn_cfg()),
            "mlp_norm": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
        }

    if cfg.family in ("dense", "vlm"):
        params["layers"] = _stack_init(ks[2], cfg.n_layers, dense_layer)
    elif cfg.family == "moe":
        def moe_layer_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "attn_norm": init_rmsnorm(cfg.d_model),
                "attn": init_attention(k1, cfg.attn_cfg()),
                "mlp_norm": init_rmsnorm(cfg.d_model),
                "moe": init_moe(k2, cfg.d_model, cfg.moe),
            }

        nd = cfg.moe_first_dense
        if nd:
            params["dense_layers"] = _stack_init(ks[2], nd, dense_layer)
        params["layers"] = _stack_init(ks[3], cfg.n_layers - nd, moe_layer_init)
    elif cfg.family == "ssm":
        def ssm_layer_init(k):
            return {"norm": init_rmsnorm(cfg.d_model), "ssm": init_ssm(k, cfg.ssm)}

        params["layers"] = _stack_init(ks[2], cfg.n_layers, ssm_layer_init)
    elif cfg.family == "hybrid":
        def ssm_layer_init(k):
            return {"norm": init_rmsnorm(cfg.d_model), "ssm": init_ssm(k, cfg.ssm)}

        params["layers"] = _stack_init(ks[2], cfg.n_layers, ssm_layer_init)
        k1, k2 = jax.random.split(ks[3])
        params["shared_attn"] = {
            "attn_norm": init_rmsnorm(cfg.d_model),
            "attn": init_attention(k1, cfg.attn_cfg()),
            "mlp_norm": init_rmsnorm(cfg.d_model),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff),
        }
    else:
        raise ValueError(f"init_lm does not handle family {cfg.family!r}")
    return params


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------


def _dense_body(cfg: ModelConfig, lp, x, positions, window, cache, cache_len):
    h, new_cache = attention_block(
        lp["attn"],
        rmsnorm(x, lp["attn_norm"]),
        cfg.attn_cfg(),
        positions=positions,
        window=window,
        kv_cache=cache,
        cache_len=cache_len,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
    )
    x = x + h
    x = x + mlp(lp["mlp"], rmsnorm(x, lp["mlp_norm"]))
    return shard_activation(x, "hidden"), new_cache, {}


def _moe_body(cfg: ModelConfig, lp, x, positions, window, cache, cache_len):
    h, new_cache = attention_block(
        lp["attn"],
        rmsnorm(x, lp["attn_norm"]),
        cfg.attn_cfg(),
        positions=positions,
        window=window,
        kv_cache=cache,
        cache_len=cache_len,
        q_chunk=cfg.q_chunk,
        kv_chunk=cfg.kv_chunk,
    )
    x = x + h
    y, aux = moe_layer(lp["moe"], rmsnorm(x, lp["mlp_norm"]), cfg.moe, serving=cache is not None)
    return shard_activation(x + y, "hidden"), new_cache, aux


def _ssm_body(cfg: ModelConfig, lp, x, state):
    h, new_state = ssm_block(lp["ssm"], rmsnorm(x, lp["norm"]), cfg.ssm, state=state)
    return shard_activation(x + h, "hidden"), new_state


# ---------------------------------------------------------------------------
# forward (training / scoring): full sequence, no cache
# ---------------------------------------------------------------------------


def lm_forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: jax.Array,  # int32 [B, S]
    *,
    vision_embeds: Optional[jax.Array] = None,  # [B, P, D] (vlm stub frontend)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (logits f32 [B, S(+P), V], aux losses)."""
    x = embed(params["embedding"], tokens, cfg.dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cfg.dtype)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = shard_activation(x, "hidden")

    aux_sum = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0), "dropped_frac": jnp.float32(0)}

    def add_aux(a):
        for k in aux_sum:
            if k in a:
                aux_sum[k] = aux_sum[k] + a[k]

    if cfg.family in ("dense", "vlm", "moe"):
        windows = cfg.layer_windows()
        body = _moe_body if cfg.family == "moe" else _dense_body

        if cfg.family == "moe" and cfg.moe_first_dense:
            d_windows = windows[: cfg.moe_first_dense]
            windows = windows[cfg.moe_first_dense :]

            def dense_scan(x, inp):
                lp, w = inp
                x, _, _ = _dense_body(cfg, lp, x, positions, w, None, None)
                return x, None

            fn = jax.checkpoint(dense_scan) if cfg.remat else dense_scan
            x, _ = jax.lax.scan(fn, x, (params["dense_layers"], d_windows))

        def scan_body(carry, inp):
            x, aux = carry
            lp, w = inp
            x, _, a = body(cfg, lp, x, positions, w, None, None)
            new_aux = tuple(
                aux[i] + a.get(k, jnp.float32(0)) for i, k in enumerate(("lb_loss", "z_loss", "dropped_frac"))
            )
            return (x, new_aux), None

        fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
        (x, aux_t), _ = jax.lax.scan(
            fn, (x, (jnp.float32(0), jnp.float32(0), jnp.float32(0))), (params["layers"], windows)
        )
        aux_sum = dict(zip(("lb_loss", "z_loss", "dropped_frac"), aux_t))

    elif cfg.family == "ssm":
        def scan_body(x, lp):
            x, _ = _ssm_body(cfg, lp, x, None)
            return x, None

        fn = jax.checkpoint(scan_body) if cfg.remat else scan_body
        x, _ = jax.lax.scan(fn, x, params["layers"])

    elif cfg.family == "hybrid":
        e = cfg.hybrid_attn_every
        g = cfg.n_layers // e
        grouped = jax.tree.map(lambda a: a.reshape((g, e) + a.shape[1:]), params["layers"])
        shared = params["shared_attn"]

        def group_body(x, glp):
            def inner(x, lp):
                x, _ = _ssm_body(cfg, lp, x, None)
                return x, None

            x, _ = jax.lax.scan(inner, x, glp)
            x, _, _ = _dense_body(cfg, shared, x, positions, jnp.int32(0), None, None)
            return x, None

        fn = jax.checkpoint(group_body) if cfg.remat else group_body
        x, _ = jax.lax.scan(fn, x, grouped)
    else:
        raise ValueError(cfg.family)

    x = rmsnorm(x, params["final_norm"])
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = unembed(x, head)
    logits = shard_activation(logits, "logits")
    return logits, aux_sum


def lm_hidden_embed(params, cfg: ModelConfig, tokens) -> jax.Array:
    """Mean-pooled final hidden state — the entity-embedding producer used by

    the HQI integration examples (models emit vectors; HQI indexes them)."""
    x = embed(params["embedding"], tokens, cfg.dtype)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.family in ("dense", "vlm"):
        windows = cfg.layer_windows()

        def scan_body(x, inp):
            lp, w = inp
            x, _, _ = _dense_body(cfg, lp, x, positions, w, None, None)
            return x, None

        x, _ = jax.lax.scan(scan_body, x, (params["layers"], windows))
    else:
        logits, _ = lm_forward(params, cfg, tokens)
        return logits.mean(axis=1)  # fallback
    x = rmsnorm(x, params["final_norm"])
    return x.mean(axis=1).astype(jnp.float32)
