"""Mixture-of-Experts layer: shared + routed experts, top-k routing,

capacity-based static dispatch (sort-free scatter), expert parallelism.

Dispatch is the standard static-shape formulation: flatten tokens, rank each
(token, slot) pair within its expert via a cumulative count, drop past
capacity, scatter into an [E, C, D] buffer, run all expert FFNs as one
batched einsum (sharded over the ``expert``/model axis), and combine with
router gates. Aux outputs: load-balancing loss (Switch-style), router z-loss,
dropped-token fraction (tests assert it stays sane at even load).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation
from .layers import _dense_init, init_mlp, mlp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int  # per-expert FFN width (fine-grained experts are narrow)
    n_shared_experts: int = 0
    d_ff_shared: Optional[int] = None  # defaults to d_ff_expert * n_shared
    capacity_factor: float = 1.25
    # serving path: capacity dropping would make decode outputs depend on the
    # batch composition — use a near-dropless factor there instead.
    serve_capacity_factor: float = 8.0
    router_noise: float = 0.0


def init_moe(key, d_model: int, cfg: MoEConfig) -> Dict[str, Any]:
    kr, ke, ks = jax.random.split(key, 3)
    e, f = cfg.n_experts, cfg.d_ff_expert
    k1, k2, k3 = jax.random.split(ke, 3)
    p: Dict[str, Any] = {
        "router": _dense_init(kr, (d_model, e)),
        "experts": {
            "w_up": _dense_init(k1, (e, d_model, f)),
            "w_gate": _dense_init(k2, (e, d_model, f)),
            "w_down": _dense_init(k3, (e, f, d_model)),
        },
    }
    if cfg.n_shared_experts > 0:
        d_sh = cfg.d_ff_shared or cfg.d_ff_expert * cfg.n_shared_experts
        p["shared"] = init_mlp(ks, d_model, d_sh)
    return p


def moe_layer(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: MoEConfig,
    serving: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Entry point: uses the explicit expert-parallel shard_map path when a

    mesh is active (training on the production mesh), else the dense
    single-device formulation below."""
    from ..distributed.sharding import OPT, get_rules

    rules = get_rules()
    if (
        not serving
        and OPT["moe_ep_data"]
        and rules is not None
        and rules.mesh is not None
        and "data" in rules.mesh.axis_names
        and cfg.n_experts % rules.mesh.shape["data"] == 0
    ):
        return moe_layer_ep(p, x, cfg, rules)
    return moe_layer_dense(p, x, cfg, serving)


def moe_layer_dense(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: MoEConfig,
    serving: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32)) @ p["router"].astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # [T, k]
    gates = gates / jnp.maximum(gates.sum(axis=-1, keepdims=True), 1e-9)

    # --- capacity-based dispatch --------------------------------------------
    cf = cfg.serve_capacity_factor if serving else cfg.capacity_factor
    cap = int(min(t, max(1, (t * k * cf) // e)))
    flat_e = eidx.reshape(-1)  # [T*k]
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gates.reshape(-1)
    # rank of each pair within its expert (stable by token order)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [T*k, E]
    pos = (jnp.cumsum(onehot, axis=0) - 1)  # exclusive rank per expert
    rank = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*k]
    keep = rank < cap
    dropped_frac = 1.0 - keep.mean()

    buf = jnp.zeros((e, cap, d), x.dtype)
    safe_rank = jnp.where(keep, rank, cap - 1)
    buf = buf.at[flat_e, safe_rank].add(
        jnp.where(keep[:, None], xt[flat_t], 0).astype(x.dtype)
    )
    buf = shard_activation(buf, "expert_buf")

    # --- expert FFNs as one batched einsum (EP over "expert") ----------------
    w_up = p["experts"]["w_up"].astype(x.dtype)
    w_gate = p["experts"]["w_gate"].astype(x.dtype)
    w_down = p["experts"]["w_down"].astype(x.dtype)
    h = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = jax.nn.silu(h) * u
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)  # [E, C, D]

    # --- combine --------------------------------------------------------------
    gathered = out_buf[flat_e, safe_rank]  # [T*k, D]
    contrib = jnp.where(keep[:, None], gathered * flat_g[:, None].astype(x.dtype), 0)
    yt = jnp.zeros((t, d), x.dtype).at[flat_t].add(contrib)
    y = yt.reshape(b, s, d)

    if "shared" in p:
        y = y + mlp(p["shared"], x)

    # --- aux losses -----------------------------------------------------------
    # Switch load-balance: E * Σ_e (frac tokens to e) * (mean router prob e)
    me = jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), axis=0)
    pe = probs.mean(axis=0)
    lb_loss = e * jnp.sum(me * pe)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped_frac}
    return y, aux


# ---------------------------------------------------------------------------
# Expert parallelism via shard_map (the production path)
# ---------------------------------------------------------------------------
#
# Naive pjit lowering of the scatter-based dispatch degenerates into
# all-reduces of the FULL flat token tensor per layer (measured 1.9 GB ×
# ~8 ops × layers × microbatches on the 1T config — §Perf log). The explicit
# formulation below is the standard production schedule:
#
#   * experts sharded over "data" (E_loc = E / dp per shard), expert-FFN width
#     over "model" (TP);
#   * each data shard routes its tokens, packs them into per-(destination
#     shard, local expert) capacity slots, and exchanges ONE bf16 all_to_all;
#   * received tokens are already grouped per local expert → batched FFN
#     einsums; the down-projection partial sums psum over "model";
#   * a reverse all_to_all returns expert outputs to the token's home shard,
#     where gates combine them.
#
# Communication per device per layer ≈ 2 · T_loc · k · D bytes (bf16), vs the
# token-tensor all-reduces the automatic partitioner produced.


def _ep_local(xt, router, w_gate, w_up, w_down, cfg: MoEConfig, dp: int, cap_e: int):
    """Per-device body under shard_map. xt [T_loc, D] (this shard's tokens);

    experts local [E_loc, D, F_loc]. Returns (yt [T_loc, D], aux)."""
    t_loc, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // dp

    logits = xt.astype(jnp.float32) @ router.astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)  # [T*k] global expert ids
    flat_t = jnp.repeat(jnp.arange(t_loc), k)
    flat_g = gates.reshape(-1)
    # rank of each pair within its (global) expert
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    rank = jnp.take_along_axis(jnp.cumsum(onehot, axis=0) - 1, flat_e[:, None], 1)[:, 0]
    keep = rank < cap_e
    dst = flat_e // e_loc  # destination data shard
    loc = flat_e % e_loc  # local expert id at the destination
    safe_rank = jnp.where(keep, rank, cap_e - 1)

    # pack into per-(dst, local expert, slot) send buffer
    sbuf = jnp.zeros((dp, e_loc, cap_e, d), xt.dtype)
    sbuf = sbuf.at[dst, loc, safe_rank].add(jnp.where(keep[:, None], xt[flat_t], 0))
    svalid = jnp.zeros((dp, e_loc, cap_e), jnp.bool_).at[dst, loc, safe_rank].max(keep)

    # exchange: rbuf[src] = what src sent to us
    rbuf = jax.lax.all_to_all(sbuf, "data", split_axis=0, concat_axis=0, tiled=False)
    rvalid = jax.lax.all_to_all(svalid, "data", split_axis=0, concat_axis=0, tiled=False)
    buf = jnp.moveaxis(rbuf, 0, 1).reshape(e_loc, dp * cap_e, d)  # [E_loc, C, D]
    bvalid = jnp.moveaxis(rvalid, 0, 1).reshape(e_loc, dp * cap_e)

    # local expert FFNs (F sharded over "model": psum the down partials)
    h = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(buf.dtype))
    h = jax.nn.silu(h) * u
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(buf.dtype))
    out = jax.lax.psum(out, "model")
    out = jnp.where(bvalid[..., None], out, 0)

    # return trip
    out_r = jnp.moveaxis(out.reshape(e_loc, dp, cap_e, d), 1, 0)  # [dst_src, E_loc, cap, D]
    back = jax.lax.all_to_all(out_r, "data", split_axis=0, concat_axis=0, tiled=False)
    # back[dst, loc, rank] = expert output for our pair routed to (dst, loc)
    fetched = back[dst, loc, safe_rank]  # [T*k, D]
    contrib = jnp.where(keep[:, None], fetched * flat_g[:, None].astype(xt.dtype), 0)
    yt = jnp.zeros((t_loc, d), xt.dtype).at[flat_t].add(contrib)

    # global routing statistics (pmean BEFORE the product so the loss equals
    # the dense single-device formulation exactly)
    me = jax.lax.pmean(jnp.mean(jax.nn.one_hot(eidx[:, 0], e, dtype=jnp.float32), axis=0), "data")
    pe = jax.lax.pmean(probs.mean(axis=0), "data")
    lb_loss = e * jnp.sum(me * pe)
    z_loss = jax.lax.pmean(jnp.mean(jax.nn.logsumexp(logits, -1) ** 2), "data")
    dropped = jax.lax.pmean(1.0 - keep.mean(), "data")
    return yt, lb_loss, z_loss, dropped


def moe_layer_ep(p, x, cfg: MoEConfig, rules) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import shard_map_compat

    mesh = rules.mesh
    dp = mesh.shape["data"]  # expert shards live on the data axis
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bp = 1
    for a in batch_axes:
        bp *= mesh.shape[a]
    b, s, d = x.shape
    if b % bp != 0:
        # batch not shardable over the batch axes (e.g. batch=1) — dense path
        return moe_layer_dense(p, x, cfg)
    t_loc = (b // bp) * s  # tokens per device; experts replicate across pods
    cap_e = int(max(1, (t_loc * cfg.top_k * cfg.capacity_factor) // cfg.n_experts))

    # tokens flattened per shard; weights: E over data, F over model
    fn = shard_map_compat(
        lambda xt, r, wg, wu, wd: _ep_local(xt, r, wg, wu, wd, cfg, dp, cap_e),
        mesh=mesh,
        in_specs=(
            P(batch_axes, None),
            P(),
            P("data", None, "model"),
            P("data", None, "model"),
            P("data", "model", None),
        ),
        out_specs=(P(batch_axes, None), P(), P(), P()),
    )
    xt = x.reshape(b * s, d)
    yt, lb, zl, dr = fn(
        xt, p["router"], p["experts"]["w_gate"], p["experts"]["w_up"], p["experts"]["w_down"]
    )
    y = yt.reshape(b, s, d)
    if "shared" in p:
        y = y + mlp(p["shared"], x)
    return y, {"lb_loss": lb, "z_loss": zl, "dropped_frac": dr}
