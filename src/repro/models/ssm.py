"""Mamba2 (SSD — state-space duality) blocks, chunked, TPU-friendly.

Implements the chunked SSD algorithm of Dao & Gu 2024 (arXiv:2405.21060):
sequence split into chunks; within a chunk the SSD computation is a masked
(decay-weighted) attention-like matmul; across chunks a small recurrent scan
carries the [H, N, P] state. All heavy ops are batched einsums (MXU-friendly);
the cross-chunk scan has O(S / chunk) steps.

Decode is the SSM recurrence proper: state [B, H, dstate, P] updated per
token in O(1) — this is why ``long_500k`` runs for the SSM/hybrid archs.

Shapes follow the Mamba2 convention: d_inner = expand * d_model split into
H heads of P = head_dim; B/C are per-group [N = d_state] (n_groups = 1 here).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import _dense_init, init_rmsnorm, rmsnorm


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_ssm(key, cfg: SSMConfig) -> Dict[str, Any]:
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj emits [z, x, B, C, dt]
    d_proj = 2 * di + 2 * n + h
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "in_proj": _dense_init(k1, (cfg.d_model, d_proj)),
        "conv_w": _dense_init(k2, (cfg.conv_width, di + 2 * n), scale=0.5),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) in (-inf, 0)
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus(-2) ≈ 0.12
        "D": jnp.ones((h,), jnp.float32),
        "norm": init_rmsnorm(di),
        "out_proj": _dense_init(k3, (di, cfg.d_model)),
    }


def _split_proj(p, x, cfg: SSMConfig):
    di, n, h = cfg.d_inner, cfg.d_state, cfg.n_heads
    proj = x @ p["in_proj"].astype(x.dtype)  # [B, S, 2di + 2n + h]
    z, xbc, dt = jnp.split(proj, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv, width W. xbc [B, S, C], w [W, C].

    state (decode): last W-1 inputs [B, W-1, C]; returns (out, new_state)."""
    bsz, s, c = xbc.shape
    wlen = w.shape[0]
    if state is None:
        pad = jnp.zeros((bsz, wlen - 1, c), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # [B, S + W - 1, C]
    out = jnp.zeros((bsz, s, c), jnp.float32)
    for i in range(wlen):
        out = out + full[:, i : i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_state = full[:, -(wlen - 1) :] if wlen > 1 else jnp.zeros((bsz, 0, c), xbc.dtype)
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def ssd_chunked(
    xh: jax.Array,  # [B, S, H, P]
    dt: jax.Array,  # [B, S, H]  (post-softplus)
    A: jax.Array,  # [H] negative
    Bm: jax.Array,  # [B, S, N]
    Cm: jax.Array,  # [B, S, N]
    chunk: int,
    init_state: Optional[jax.Array] = None,  # [B, H, N, P]
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    b, s, h, p_ = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, s)
    sp = ((s + q - 1) // q) * q
    pad = sp - s
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = sp // q

    xh = xh.reshape(b, nc, q, h, p_).astype(jnp.float32)
    dt = dt.reshape(b, nc, q, h).astype(jnp.float32)
    Bm = Bm.reshape(b, nc, q, n).astype(jnp.float32)
    Cm = Cm.reshape(b, nc, q, n).astype(jnp.float32)

    dA = dt * A[None, None, None, :]  # [B, NC, Q, H] (negative increments)
    cum = jnp.cumsum(dA, axis=2)  # within-chunk cumulative log-decay
    seg_end = cum[:, :, -1:, :]  # [B, NC, 1, H]

    # ---- intra-chunk (block-diagonal) term ----------------------------------
    # L[i, j] = exp(cum_i - cum_j) for i >= j  (decay from j to i)
    li = cum[:, :, :, None, :]  # [B,NC,Q,1,H]
    lj = cum[:, :, None, :, :]  # [B,NC,1,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)  # [B,NC,Q,Q,H]
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)  # [B,NC,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjh,bcjhp->bcihp", cb, L, dt, xh)

    # ---- chunk summary states ------------------------------------------------
    # state contribution of chunk: Σ_j exp(seg_end - cum_j) dt_j B_j ⊗ x_j
    decay_to_end = jnp.exp(seg_end - cum)  # [B,NC,Q,H]
    S_chunk = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchnp", decay_to_end, dt, Bm, xh)

    # ---- inter-chunk recurrence (scan over chunks) ---------------------------
    seg = jnp.exp(seg_end[:, :, 0, :])  # [B, NC, H] total chunk decay

    def step(carry, inp):
        s_prev = carry  # [B, H, N, P]
        s_c, g = inp  # [B,H,N,P], [B,H]
        s_new = s_prev * g[:, :, None, None] + s_c
        return s_new, s_prev

    init = (
        init_state.astype(jnp.float32)
        if init_state is not None
        else jnp.zeros((b, h, n, p_), jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(S_chunk, 1, 0), jnp.moveaxis(seg, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B, NC, H, N, P]

    # ---- inter-chunk output term ----------------------------------------------
    decay_from_start = jnp.exp(cum)  # [B,NC,Q,H]
    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", Cm, decay_from_start, prev_states
    )

    y = (y_intra + y_inter).reshape(b, sp, h, p_)[:, :s]
    return y, final_state


def ssm_block(
    p: Dict[str, Any],
    x: jax.Array,  # [B, S, D]
    cfg: SSMConfig,
    *,
    state: Optional[Dict[str, jax.Array]] = None,  # decode: {"ssm", "conv"}
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full Mamba2 block. Without state: chunked SSD over the sequence.

    With state: single-token recurrent decode (x is [B, 1, D])."""
    b, s, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    z, xbc, dt_raw = _split_proj(p, x, cfg)
    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], conv_state)
    xs, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xs.reshape(b, s, h, pdim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])  # [H]

    if state is None:
        y, fin = ssd_chunked(xh, dt, A, Bm, Cm, cfg.chunk)
    else:
        # recurrence: h' = exp(dt A) h + dt * (B ⊗ x); y = C·h'
        s_prev = state["ssm"].astype(jnp.float32)  # [B, H, N, P]
        g = jnp.exp(dt[:, 0, :] * A[None, :])  # [B, H]
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0, :], Bm[:, 0].astype(jnp.float32), xh[:, 0].astype(jnp.float32))
        fin = s_prev * g[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), fin)[:, None]

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    new_state = {"ssm": fin, "conv": new_conv}
    return out, new_state
