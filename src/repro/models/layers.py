"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Raw-JAX functional style: params are pytrees of jnp arrays, every layer is a
pure function. Initializers take explicit PRNG keys. Activations default to
bf16 with fp32 accumulation in norms/softmax; params are created fp32 and
cast per config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard_activation


def _dense_init(key, shape, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / (fan_in**0.5)
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_rmsnorm(d: int) -> jax.Array:
    return jnp.zeros((d,), jnp.float32)  # stored as (1 + w) offset form


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x [..., S, H, Dh] (Dh even), positions [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs[None, :]  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, gated: bool = True) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": _dense_init(k1, (d_model, d_ff)),
        "w_down": _dense_init(k2, (d_ff, d_model)),
    }
    if gated:
        p["w_gate"] = _dense_init(k3, (d_model, d_ff))
    return p


def mlp(p: Dict[str, Any], x: jax.Array, act: str = "silu") -> jax.Array:
    up = x @ p["w_up"].astype(x.dtype)
    if "w_gate" in p:
        g = x @ p["w_gate"].astype(x.dtype)
        g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
        h = g * up
    else:
        h = jax.nn.silu(up) if act == "silu" else jax.nn.gelu(up)
    h = shard_activation(h, "mlp")
    return h @ p["w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), dtype=jnp.float32) * (d_model**-0.5)


def embed(table: jax.Array, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return table.astype(dtype)[tokens]


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    # logits in fp32 for a stable softmax/loss
    return (x.astype(jnp.float32) @ table.astype(jnp.float32).T)
