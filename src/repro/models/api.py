"""Family-dispatching model API: init / loss / prefill / decode / input_specs.

This is the single surface the trainer, server, dry-run, and tests call.
``input_specs`` returns ShapeDtypeStructs (weak-type-correct, shardable, no
allocation) for every step kind — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import decode as dec
from . import encdec as ed
from .transformer import ModelConfig, init_lm, lm_forward


def init_model(cfg: ModelConfig, key: jax.Array):
    if cfg.family == "encdec":
        return ed.init_encdec(cfg, key)
    return init_lm(cfg, key)


def count_params(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict]:
    """Causal-LM cross entropy (+ MoE aux). batch:

      tokens int32 [B, S]; labels int32 [B, S] (-100 = ignore);
      vlm: + vision_embeds [B, P, D]; encdec: + frames [B, F, D].
    """
    tokens, labels = batch["tokens"], batch["labels"]
    if cfg.family == "encdec":
        logits = ed.encdec_forward(params, cfg, batch["frames"], tokens)
        aux = {}
    elif cfg.family == "vlm":
        logits, aux = lm_forward(params, cfg, tokens, vision_embeds=batch["vision_embeds"])
        logits = logits[:, cfg.vision_patches :]  # loss over text positions only
    else:
        logits, aux = lm_forward(params, cfg, tokens)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss
    if aux:
        total = total + 0.01 * aux.get("lb_loss", 0.0) + 1e-4 * aux.get("z_loss", 0.0)
    return total, {"ce_loss": loss, **{k: v for k, v in (aux or {}).items()}}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def serve_prefill(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *, max_len: Optional[int] = None):
    """max_len pads the KV cache past the prompt to leave room for decoding

    (SSM states are O(1) and need no padding)."""
    if cfg.family == "encdec":
        logits, cache = ed.encdec_prefill(params, cfg, batch["frames"], batch["tokens"])
    elif cfg.family == "vlm":
        logits, cache = dec.prefill(params, cfg, batch["tokens"], vision_embeds=batch["vision_embeds"])
    else:
        logits, cache = dec.prefill(params, cfg, batch["tokens"])
    if max_len is not None and "k" in cache:
        t = cache["k"].shape[2]
        if max_len > t:
            pad = [(0, 0)] * cache["k"].ndim
            pad[2] = (0, max_len - t)
            cache = dict(cache, k=jnp.pad(cache["k"], pad), v=jnp.pad(cache["v"], pad))
    return logits, cache


def serve_decode(params, cfg: ModelConfig, token: jax.Array, cache):
    if cfg.family == "encdec":
        return ed.encdec_decode_step(params, cfg, token, cache)
    return dec.decode_step(params, cfg, token, cache)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    if cfg.family == "encdec":
        return ed.init_encdec_cache(cfg, batch, max_len)
    return dec.init_cache(cfg, batch, max_len)


# ---------------------------------------------------------------------------
# ShapeDtypeStruct stand-ins for the dry-run
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, kind: str, *, batch: int, seq_len: int) -> Dict[str, Any]:
    """kind ∈ {train, prefill, decode}. No device allocation — shapes only."""
    sds = jax.ShapeDtypeStruct
    i32, f32 = jnp.int32, jnp.float32
    if kind == "train":
        spec = {
            "tokens": sds((batch, seq_len), i32),
            "labels": sds((batch, seq_len), i32),
        }
        if cfg.family == "vlm":
            spec["vision_embeds"] = sds((batch, cfg.vision_patches, cfg.d_model), f32)
        if cfg.family == "encdec":
            spec["frames"] = sds((batch, cfg.encoder_frames, cfg.d_model), f32)
        return spec
    if kind == "prefill":
        spec = {"tokens": sds((batch, seq_len), i32)}
        if cfg.family == "vlm":
            spec["vision_embeds"] = sds((batch, cfg.vision_patches, cfg.d_model), f32)
        if cfg.family == "encdec":
            spec["frames"] = sds((batch, cfg.encoder_frames, cfg.d_model), f32)
        return spec
    if kind == "decode":
        cache = init_cache_specs(cfg, batch, seq_len)
        return {"token": sds((batch,), i32), "cache": cache}
    raise ValueError(kind)


def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStruct mirror of init_cache (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len))


def params_specs(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: init_model(cfg, jax.random.key(0)))
