import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import, giving 512 host
placeholder devices for the production meshes. For every cell we:

    1. build the production mesh (16×16 single-pod / 2×16×16 multi-pod),
    2. construct the step fn (train_step / prefill / decode / hqi-search),
    3. lower with ShapeDtypeStruct inputs carrying NamedShardings,
    4. compile — success proves the distribution config is coherent,
    5. record memory_analysis + cost_analysis + parsed collective bytes
       into dryrun_results.json (incremental; re-runs skip finished cells).

Usage:
    python -m repro.launch.dryrun                    # all cells
    python -m repro.launch.dryrun --arch qwen3-32b   # one arch
    python -m repro.launch.dryrun --arch hqi-search  # the paper's step
    python -m repro.launch.dryrun --shape train_4k --mesh single
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import ARCHS, get_config, optimizer_for  # noqa: E402
from ..configs.shapes import SHAPES, shapes_for  # noqa: E402
from ..core.distributed import make_roofline_search_step, roofline_search_specs  # noqa: E402
from ..distributed.sharding import ShardingRules, tree_param_specs, use_rules  # noqa: E402
from ..models import api  # noqa: E402
from ..models.transformer import ModelConfig  # noqa: E402
from ..train.optimizer import OptConfig  # noqa: E402
from ..train.train_step import TrainConfig, make_train_step  # noqa: E402
from . import hlo_cost  # noqa: E402
from . import roofline as rl  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..", "dryrun_results.json")

# FSDP (param/optimizer-state sharding over data) for models too big for pure TP.
FSDP_MIN_PARAMS = 5e9
# microbatches for train cells: bound activation memory at 4k×256.
TRAIN_MICROBATCHES = {"default": 8}

HQI_SEARCH_SHAPES = {
    # the paper's step: N DB vectors × d, M queries per batch
    "hqi_100m_batch64k": dict(n=100_000_000, d=128, m=65_536),
    "hqi_100m_online4k": dict(n=100_000_000, d=128, m=4_096),
}


def _rules_for(cfg: ModelConfig, mesh, shape_kind: str) -> ShardingRules:
    n_params = rl.total_params(cfg)
    fsdp = n_params >= FSDP_MIN_PARAMS
    # ZeRO-3 stacked-dim gathers pay off when amortized over a training
    # batch; decode gathers per token and blows temp memory (§Perf iter 6)
    return ShardingRules(mesh=mesh, fsdp=fsdp, fsdp_stacked=(shape_kind == "train"))


def _sds(tree, shardings):
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype, sharding=s), tree, shardings
    )


def _effective_batch_axes(mesh, batch_size: int):
    """Largest prefix of (pod, data) that divides the batch; () = replicate."""
    baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    while baxes:
        prod = int(np.prod([mesh.shape[a] for a in baxes]))
        if batch_size % prod == 0:
            return baxes
        baxes = baxes[1:]
    return ()


def _batch_sharding(mesh, batch_tree, batch_size: int):
    baxes = _effective_batch_axes(mesh, batch_size)
    bspec = baxes if baxes else None

    def spec(leaf):
        return NamedSharding(mesh, P(bspec, *([None] * (len(leaf.shape) - 1))))

    return jax.tree.map(spec, batch_tree)


def _cache_sharding(mesh, cfg: ModelConfig, cache_tree, batch_size: int):
    """KV caches: batch over data axes, kv-heads over model. SSM states:

    batch over data, ssd-heads over model."""
    baxes = _effective_batch_axes(mesh, batch_size)
    baxes = baxes if baxes else None

    msize = mesh.shape["model"]

    def place_model(shape, axes, prefs):
        """Put "model" on the first preferred dim it evenly divides."""
        for i in prefs:
            if shape[i] % msize == 0:
                axes[i] = "model"
                break
        return axes

    from ..distributed.sharding import OPT

    def visit(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        nd = len(leaf.shape)
        shape = leaf.shape
        if key.endswith("len"):
            return NamedSharding(mesh, P(baxes))
        axes = [None] * nd
        if key.split("/")[-1] in ("k", "v", "xk", "xv"):
            # [L, B, T, Hkv, dh] (or [G, B, T, Hkv, dh]): batch over data;
            # optimized scheme shards the TIME axis over model (uniform for
            # any head count, turns the decode softmax into a psum — measured
            # 67× less decode collective traffic than uneven head sharding);
            # baseline: kv-heads if divisible, else head_dim.
            axes[1] = baxes
            prefs = (2, 3, 4) if OPT["kv_cache_time_shard"] else (3, 4)
            axes = place_model(shape, axes, prefs=prefs)
        elif key.endswith("ssm"):
            # [L, B, H, N, P] or [G, E, B, H, N, P]
            b_i = 1 if nd == 5 else 2
            axes[b_i] = baxes
            axes = place_model(shape, axes, prefs=(b_i + 1, b_i + 2))
        elif key.endswith("conv"):
            b_i = 1 if nd == 4 else 2
            axes[b_i] = baxes
            axes = place_model(shape, axes, prefs=(nd - 1,))
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(visit, cache_tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool) -> Dict[str, Any]:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))

    if arch == "hqi-search":
        spec = HQI_SEARCH_SHAPES[shape_name]
        step = make_roofline_search_step(mesh, k=10, metric="ip")
        in_sds = roofline_search_specs(mesh, **spec)
        with mesh:
            lowered = step.lower(*in_sds)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        hc = hlo_cost.analyze(compiled.as_text())
        # model flops: the useful work is 2·N·d·M MACs = 2·N·M·d flops
        mf = 2.0 * spec["n"] * spec["m"] * spec["d"]
        terms = rl.RooflineTerms(
            flops_per_dev=hc.flops,
            bytes_per_dev=hc.bytes,
            coll_bytes_per_dev=hc.coll_bytes,
            coll_breakdown={k: int(v) for k, v in hc.coll.items()},
            model_flops=mf,
            chips=chips,
        )
        return _result(arch, shape_name, multi_pod, terms, ma, t0, chips)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = _rules_for(cfg, mesh, shape.kind)
    params_sds0 = api.params_specs(cfg)
    from ..distributed.sharding import OPT

    if shape.kind in ("prefill", "decode") and OPT["serve_bf16"]:
        # serving runs bf16 weights (capacity); training keeps fp32 masters
        params_sds0 = jax.tree.map(
            lambda t: jax.ShapeDtypeStruct(t.shape, jnp.bfloat16)
            if t.dtype == jnp.float32
            else t,
            params_sds0,
        )
    pspecs = tree_param_specs(params_sds0, rules)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    params_sds = _sds(params_sds0, pshard)

    with mesh, use_rules(rules):
        if shape.kind == "train":
            mb = TRAIN_MICROBATCHES.get(arch, TRAIN_MICROBATCHES["default"])
            opt_name = optimizer_for(arch)
            tcfg = TrainConfig(opt=OptConfig(name=opt_name), microbatches=mb)
            from ..train.optimizer import init_opt

            opt_sds0 = jax.eval_shape(lambda p: init_opt(p, tcfg.opt), params_sds0)
            ospecs = tree_param_specs(opt_sds0, rules)
            oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                                  is_leaf=lambda x: isinstance(x, P))
            opt_sds = _sds(opt_sds0, oshard)
            batch0 = api.input_specs(cfg, "train", batch=shape.global_batch, seq_len=shape.seq_len)
            batch_sds = _sds(batch0, _batch_sharding(mesh, batch0, shape.global_batch))
            step_fn = make_train_step(cfg, tcfg)
            lowered = jax.jit(step_fn).lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            batch0 = api.input_specs(cfg, "prefill", batch=shape.global_batch, seq_len=shape.seq_len)
            batch_sds = _sds(batch0, _batch_sharding(mesh, batch0, shape.global_batch))
            step_fn = lambda p, b: api.serve_prefill(p, cfg, b)
            lowered = jax.jit(step_fn).lower(params_sds, batch_sds)
        elif shape.kind == "decode":
            spec0 = api.input_specs(cfg, "decode", batch=shape.global_batch, seq_len=shape.seq_len)
            tok_sds = _sds(
                {"t": spec0["token"]},
                _batch_sharding(mesh, {"t": spec0["token"]}, shape.global_batch),
            )["t"]
            cache_sds = _sds(
                spec0["cache"],
                _cache_sharding(mesh, cfg, spec0["cache"], shape.global_batch),
            )
            step_fn = lambda p, t, c: api.serve_decode(p, cfg, t, c)
            lowered = jax.jit(step_fn).lower(params_sds, tok_sds, cache_sds)
        else:
            raise ValueError(shape.kind)
        compiled = lowered.compile()

    ma = compiled.memory_analysis()
    hc = hlo_cost.analyze(compiled.as_text())
    terms = rl.RooflineTerms(
        flops_per_dev=hc.flops,
        bytes_per_dev=hc.bytes,
        coll_bytes_per_dev=hc.coll_bytes,
        coll_breakdown={k: int(v) for k, v in hc.coll.items()},
        model_flops=rl.model_flops(cfg, shape.kind, shape.global_batch, shape.seq_len),
        chips=chips,
    )
    return _result(arch, shape_name, multi_pod, terms, ma, t0, chips)


def _mem_dict(ma) -> Dict[str, Any]:
    out = {}
    for attr in (
        "temp_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        try:
            out[attr] = int(getattr(ma, attr))
        except Exception:
            pass
    if not out:
        out["repr"] = str(ma)
    return out


def _result(arch, shape_name, multi_pod, terms: rl.RooflineTerms, ma, t0, chips):
    mem = _mem_dict(ma)
    live = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "ok": True,
        "compile_seconds": round(time.time() - t0, 1),
        "memory": mem,
        "bytes_per_device_live": live,
        "fits_16gb": bool(live <= 16 * 2**30) if live else None,
        "roofline": terms.as_dict(),
    }


def all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape_name in shapes_for(cfg.family):
            yield arch, shape_name
    for shape_name in HQI_SEARCH_SHAPES:
        yield "hqi-search", shape_name


def load_results() -> Dict[str, Any]:
    if os.path.exists(RESULTS_PATH):
        with open(RESULTS_PATH) as f:
            return json.load(f)
    return {}


def save_results(res: Dict[str, Any]):
    with open(RESULTS_PATH, "w") as f:
        json.dump(res, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful first-cut scheme (all OPT flags off)")
    args = ap.parse_args()

    global RESULTS_PATH
    if args.baseline:
        from ..distributed.sharding import set_all_opt

        set_all_opt(False)
        RESULTS_PATH = RESULTS_PATH.replace("dryrun_results", "dryrun_results_baseline")
    results = load_results()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    todo = [
        (a, s, mp)
        for a, s in all_cells()
        for mp in meshes
        if (args.arch is None or a == args.arch) and (args.shape is None or s == args.shape)
    ]
    print(f"dry-run: {len(todo)} cells")
    for arch, shape_name, mp in todo:
        key = f"{arch}|{shape_name}|{'multi' if mp else 'single'}"
        if key in results and results[key].get("ok") and not args.force:
            print(f"SKIP {key} (cached)")
            continue
        print(f"RUN  {key} ...", flush=True)
        try:
            res = lower_cell(arch, shape_name, mp)
            r = res["roofline"]
            print(
                f"  OK  {res['compile_seconds']}s  flops/dev={r['flops_per_dev']:.3e} "
                f"bytes/dev={r['bytes_per_dev']:.3e} coll/dev={r['coll_bytes_per_dev']:.3e} "
                f"bottleneck={r['bottleneck']} useful={r['useful_flop_ratio']:.2f}",
                flush=True,
            )
        except Exception as e:  # noqa: BLE001
            res = {
                "arch": arch, "shape": shape_name,
                "mesh": "multi" if mp else "single",
                "ok": False, "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
            print(f"  FAIL {type(e).__name__}: {e}", flush=True)
        results[key] = res
        save_results(results)
    n_ok = sum(1 for v in results.values() if v.get("ok"))
    print(f"done: {n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
