"""Trip-count-aware cost analysis of compiled (post-SPMD, post-fusion) HLO.

``compiled.cost_analysis()`` counts every while-loop body ONCE, which makes
it useless for scanned-layer models (a 61-layer scan under-counts 61×, nested
under a microbatch scan 488×). This module re-derives the three roofline
inputs by walking the HLO text with loop trip counts applied:

  * FLOPs       — from ``dot`` ops (shape × contracting dims; matmuls are
                  ≥99% of model FLOPs) + ``convolution`` results;
  * HBM bytes   — a traffic model of post-fusion HLO: every top-level op
                  reads its operands and writes its result once; fusions that
                  only dynamic-slice a parameter read just the slice (this is
                  exactly the scan-over-stacked-weights access pattern);
  * collectives — result bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, per kind.

Trip counts come from the loop-condition computation's comparison constant
(jax lowers ``lax.scan``/``fori_loop`` to a 0..N counter while). All numbers
are per-device (the compiled module is the per-device SPMD program).

Caveat recorded in EXPERIMENTS.md: this container compiles with the CPU
backend, so fusion boundaries differ from a real TPU compile; FLOPs and
collective bytes are backend-independent, the bytes term is an estimate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\(.*\)|[a-z0-9]+\[[^\]]*\](?:\{[^}]*\})?)\s+"  # tuple or array type
    r"([a-z][a-z0-9\-]*)\((.*)$"
)
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w\.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "after-all", "add-dependency", "partition-id", "replica-id", "iota"}


def _shape_elems_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(typestr: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(typestr):
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


@dataclasses.dataclass
class Op:
    name: str
    typestr: str
    opcode: str
    rest: str  # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    table: Dict[str, str]  # op name -> result typestr


@dataclasses.dataclass
class HLOCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def coll_bytes(self) -> float:
        return float(sum(self.coll.values()))

    def __add__(self, o: "HLOCost") -> "HLOCost":
        coll = dict(self.coll)
        for k, v in o.coll.items():
            coll[k] = coll.get(k, 0.0) + v
        return HLOCost(self.flops + o.flops, self.bytes + o.bytes, coll)

    def __mul__(self, k: float) -> "HLOCost":
        return HLOCost(self.flops * k, self.bytes * k, {a: b * k for a, b in self.coll.items()})


def parse_computations(hlo_text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line)
        if m:
            cur = Computation(m.group(2), [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        om = _OP_RE.match(line)
        if om:
            op = Op(om.group(1), om.group(2), om.group(3), om.group(4))
            cur.ops.append(op)
            cur.table[op.name] = op.typestr
    return comps, entry


def _dot_flops(op: Op, table: Dict[str, str]) -> float:
    # result elements × 2 × contracted size
    res = _shape_dims(op.typestr)
    if not res:
        return 0.0
    res_elems = 1
    for d in res[0][1]:
        res_elems *= d
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    contracted = 1
    if cm and operands:
        lhs_type = table.get(operands[0], "")
        lhs = _shape_dims(lhs_type)
        if lhs:
            for idx in cm.group(1).split(","):
                if idx and int(idx) < len(lhs[0][1]):
                    contracted *= lhs[0][1][int(idx)]
    return 2.0 * res_elems * contracted


def _fusion_root_write_bytes(op: Op, comps: Dict[str, Computation]) -> float:
    """Write traffic of a fusion: normally its result bytes, BUT a fusion

    rooted in dynamic-update-slice aliases its buffer in place — only the
    updated slice is written (counting the full carried buffer per scan
    iteration would charge scans O(L²) traffic they don't do)."""
    res = _shape_elems_bytes(op.typestr)
    cm = _CALLS_RE.search(op.rest)
    if not cm or cm.group(1) not in comps:
        return res
    callee = comps[cm.group(1)]
    roots = [o for o in callee.ops if o.opcode == "dynamic-update-slice"]
    if roots:
        # updated slice = second operand of the DUS
        total = 0.0
        for r in roots:
            ops_in = _OPERAND_RE.findall(r.rest)
            if len(ops_in) >= 2:
                total += _shape_elems_bytes(callee.table.get(ops_in[1], ""))
        if total:
            return total
    return res


def _fusion_read_bytes(op: Op, comps: Dict[str, Computation], table: Dict[str, str]) -> float:
    """Reads of a fusion: params consumed only via dynamic-slice read just the

    slices; everything else reads the full operand."""
    cm = _CALLS_RE.search(op.rest)
    operand_names = _OPERAND_RE.findall(op.rest.split("), ")[0] + ")")
    operand_names = [o for o in operand_names if o in table]
    if not cm or cm.group(1) not in comps:
        return float(sum(_shape_elems_bytes(table.get(o, "")) for o in operand_names))
    callee = comps[cm.group(1)]
    # param index -> param op name (parameter(i))
    param_of: Dict[int, str] = {}
    for o in callee.ops:
        if o.opcode == "parameter":
            pm = re.match(r"(\d+)\)", o.rest)
            if pm:
                param_of[int(pm.group(1))] = o.name
    total = 0.0
    for i, oname in enumerate(operand_names):
        full = _shape_elems_bytes(table.get(oname, ""))
        pname = param_of.get(i)
        if pname is None:
            total += full
            continue
        uses = [o for o in callee.ops if pname in _OPERAND_RE.findall(o.rest)]
        if uses and all(u.opcode in ("dynamic-slice", "dynamic-update-slice") for u in uses):
            sliced = 0.0
            for u in uses:
                if u.opcode == "dynamic-slice":
                    sliced += _shape_elems_bytes(u.typestr)
                else:  # DUS: the touched region is the update operand's size
                    ops_in = _OPERAND_RE.findall(u.rest)
                    if len(ops_in) >= 2:
                        sliced += _shape_elems_bytes(callee.table.get(ops_in[1], ""))
            total += sliced
        else:
            total += full
    return total


def _fusion_dot_flops(op: Op, comps: Dict[str, Computation]) -> float:
    """dots folded inside fusions (CPU backend does this for small dots)."""
    cm = _CALLS_RE.search(op.rest)
    if not cm or cm.group(1) not in comps:
        return 0.0
    callee = comps[cm.group(1)]
    total = 0.0
    for o in callee.ops:
        if o.opcode == "dot":
            total += _dot_flops(o, callee.table)
        elif o.opcode == "fusion":
            total += _fusion_dot_flops(o, comps)
    return total


def _analyze_comp(name: str, comps: Dict[str, Computation], memo: Dict[str, HLOCost]) -> HLOCost:
    if name in memo:
        return memo[name]
    comp = comps[name]
    cost = HLOCost()
    for op in comp.ops:
        if op.opcode in _FREE_OPS:
            continue
        if op.opcode == "while":
            wm = _WHILE_RE.search(op.rest)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trip = 1
                if cond in comps:
                    consts = [int(c) for o in comps[cond].ops for c in _CONST_RE.findall(op_line(o))]
                    if consts:
                        trip = max(consts)
                cost = cost + _analyze_comp(body, comps, memo) * trip
            continue
        if op.opcode in ("call", "async-start"):
            tm = _TO_APPLY_RE.search(op.rest) or _CALLS_RE.search(op.rest)
            if tm and tm.group(1) in comps:
                cost = cost + _analyze_comp(tm.group(1), comps, memo)
            continue
        if op.opcode == "conditional":
            # count the max-cost branch once
            branches = [b for b in _OPERAND_RE.findall(op.rest) if b in comps]
            if branches:
                sub = [_analyze_comp(b, comps, memo) for b in branches]
                cost = cost + max(sub, key=lambda c: c.flops + c.bytes)
            continue
        res_bytes = _shape_elems_bytes(op.typestr)
        if op.opcode == "dynamic-update-slice":
            ops_in = _OPERAND_RE.findall(op.rest)
            upd = _shape_elems_bytes(comp.table.get(ops_in[1], "")) if len(ops_in) >= 2 else 0
            cost.bytes += 2.0 * upd  # read-modify-write of the slice region
            continue
        if op.opcode in COLLECTIVES:
            cost.coll[op.opcode] = cost.coll.get(op.opcode, 0.0) + res_bytes
            cost.bytes += res_bytes  # collectives also touch HBM
            continue
        if op.opcode == "dot":
            cost.flops += _dot_flops(op, comp.table)
        elif op.opcode == "convolution":
            cost.flops += 2.0 * res_bytes  # rough: 2 flops per result byte-ish
        if op.opcode == "fusion":
            cost.bytes += _fusion_root_write_bytes(op, comps) + _fusion_read_bytes(op, comps, comp.table)
            cost.flops += _fusion_dot_flops(op, comps)
        else:
            operands = _OPERAND_RE.findall(op.rest)
            reads = sum(_shape_elems_bytes(comp.table.get(o, "")) for o in operands)
            cost.bytes += res_bytes + reads
    memo[name] = cost
    return cost


def op_line(o: Op) -> str:
    return f"{o.name} = {o.typestr} {o.opcode}({o.rest}"


def analyze(hlo_text: str) -> HLOCost:
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return HLOCost()
    # exclude computations only reachable as fusion bodies from double count:
    # _analyze_comp never recurses into `calls=` of fusion ops, so safe.
    return _analyze_comp(entry, comps, {})


def top_ops(hlo_text: str, n: int = 20, weight_trips: bool = True):
    """Largest single-op contributors (bytes), trip-count weighted — the

    profiler view used by the §Perf hypothesis loop."""
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        return []
    # compute trip multiplier per computation by walking whiles from entry
    mult: Dict[str, float] = {entry: 1.0}
    stack = [entry]
    while stack:
        name = stack.pop()
        comp = comps[name]
        for op in comp.ops:
            if op.opcode == "while":
                wm = _WHILE_RE.search(op.rest)
                if wm and wm.group(2) in comps:
                    trip = 1
                    cond = wm.group(1)
                    if cond in comps:
                        consts = [int(c) for o in comps[cond].ops for c in _CONST_RE.findall(op_line(o))]
                        trip = max(consts) if consts else 1
                    m = mult[name] * (trip if weight_trips else 1)
                    if mult.get(wm.group(2), 0) < m:
                        mult[wm.group(2)] = m
                        stack.append(wm.group(2))
            elif op.opcode == "call":
                tm = _TO_APPLY_RE.search(op.rest)
                if tm and tm.group(1) in comps:
                    if mult.get(tm.group(1), 0) < mult[name]:
                        mult[tm.group(1)] = mult[name]
                        stack.append(tm.group(1))
    rows = []
    for cname, m in mult.items():
        comp = comps[cname]
        for op in comp.ops:
            if op.opcode in _FREE_OPS or op.opcode in ("while", "call", "conditional"):
                continue
            res = _shape_elems_bytes(op.typestr)
            if op.opcode == "fusion":
                b = _fusion_root_write_bytes(op, comps) + _fusion_read_bytes(op, comps, comp.table)
            else:
                reads = sum(_shape_elems_bytes(comp.table.get(o, "")) for o in _OPERAND_RE.findall(op.rest))
                b = res + reads
            fl = _dot_flops(op, comp.table) if op.opcode == "dot" else (
                _fusion_dot_flops(op, comps) if op.opcode == "fusion" else 0.0
            )
            rows.append((b * m, fl * m, m, cname, op.opcode, op.name, op.typestr[:60]))
    rows.sort(key=lambda r: -r[0])
    return rows[:n]
