"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing never touches jax
device state. Single-pod: 16×16 = 256 chips (v5e-256); multi-pod:
2×16×16 = 512 chips with a leading "pod" axis.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    dev_array = np.asarray(devices[:need]).reshape(shape)
    return Mesh(dev_array, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 4), axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(f"test mesh {shape} needs {need} devices, found {len(devices)}")
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)
