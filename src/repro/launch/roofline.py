"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs_per_device / peak_FLOPs        (seconds)
    memory term     = HLO_bytes_per_device / HBM_bw            (seconds)
    collective term = collective_bytes_per_device / link_bw    (seconds)

``compiled.cost_analysis()`` reports the per-device (post-SPMD) module, so
per-device numbers are used directly (equivalent to the global-sum/chips
formulation). collective bytes are parsed from the compiled HLO text: the sum
of result-shape bytes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Dict, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link


@dataclasses.dataclass(frozen=True)
class HardwareTerms:
    """Peak terms the dispatch profiler normalizes achieved throughput by."""

    name: str
    peak_flops: float  # FLOP/s
    hbm_bw: float  # bytes/s
    link_bw: float  # bytes/s per link

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


# The cpu profile is deliberately conservative (a few-core container running
# interpret mode): the roofline *fractions* the profiler reports are only
# meaningful relative to a fixed denominator, so any stable figure works for
# regression tracking — what matters is that the same baseline always divides
# by the same terms.
_HW_PROFILES: Dict[str, HardwareTerms] = {
    "tpu-v5e": HardwareTerms("tpu-v5e", PEAK_FLOPS, HBM_BW, LINK_BW),
    "cpu": HardwareTerms("cpu", 5e11, 5e10, 1e10),
}


def current_hardware() -> HardwareTerms:
    """Hardware terms for the machine running now.

    ``REPRO_HW`` names a profile explicitly; otherwise a TPU jax backend maps
    to tpu-v5e and anything else (CPU / interpret mode) to the cpu profile.
    """
    name = os.environ.get("REPRO_HW")
    if name:
        try:
            return _HW_PROFILES[name]
        except KeyError:
            raise ValueError(
                f"unknown REPRO_HW={name!r}; one of {sorted(_HW_PROFILES)}"
            ) from None
    try:
        import jax

        backend = jax.default_backend()
    except Exception:  # pragma: no cover - jax is a hard dep everywhere else
        backend = "cpu"
    return _HW_PROFILES["tpu-v5e" if backend == "tpu" else "cpu"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?"  # optional tuple result
    r"(?:[a-z0-9_]+\[[^\]]*\][^ ]*\s+)?"  # typed result
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result-shape sum)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(kind)[0]
        b = _shape_bytes(lhs)
        if b == 0:  # fall back to whole-line parse (covers tuple shapes)
            b = _shape_bytes(line.split(kind)[0])
        out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    coll_breakdown: Dict[str, int]
    model_flops: float  # analytic 6·N_active·D (train) / 2·N_active·D (serve)
    chips: int

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        hlo_global = self.flops_per_dev * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline-model step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-model step time."""
        t = self.step_time_s
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t if t else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "mfu": self.mfu,
        }


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS per arch/shape
# ---------------------------------------------------------------------------


def active_params(cfg) -> int:
    """Per-token active parameter count (MoE: shared + top-k routed only)."""
    d = cfg.d_model
    dh = cfg.dh
    emb = cfg.vocab * d

    def attn_params():
        return d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh + cfg.n_heads * dh * d

    def dense_mlp(ff):
        return 3 * d * ff  # swiglu

    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + dense_mlp(cfg.d_ff)
        return cfg.n_layers * per_layer + emb
    if cfg.family == "moe":
        m = cfg.moe
        routed = m.top_k * 3 * d * m.d_ff_expert
        shared = 3 * d * (m.d_ff_shared or m.d_ff_expert * m.n_shared_experts) if m.n_shared_experts else 0
        router = d * m.n_experts
        moe_layer = attn_params() + routed + shared + router
        dense_layer = attn_params() + dense_mlp(cfg.d_ff)
        return (cfg.n_layers - cfg.moe_first_dense) * moe_layer + cfg.moe_first_dense * dense_layer + emb
    if cfg.family == "ssm":
        s = cfg.ssm
        per = s.d_model * (2 * s.d_inner + 2 * s.d_state + s.n_heads) + s.d_inner * s.d_model
        return cfg.n_layers * per + emb
    if cfg.family == "hybrid":
        s = cfg.ssm
        per = s.d_model * (2 * s.d_inner + 2 * s.d_state + s.n_heads) + s.d_inner * s.d_model
        shared = attn_params() + dense_mlp(cfg.d_ff)
        groups = cfg.n_layers // cfg.hybrid_attn_every
        return cfg.n_layers * per + groups * shared + emb
    if cfg.family == "encdec":
        dec = cfg.n_layers * (2 * attn_params() + 2 * d * cfg.d_ff)  # self+cross, ungated mlp
        enc = cfg.encoder_layers * (attn_params() + 2 * d * cfg.d_ff)
        return dec + enc + emb
    raise ValueError(cfg.family)


def total_params(cfg) -> int:
    if cfg.family != "moe":
        return active_params(cfg)
    d = cfg.d_model
    dh = cfg.dh
    m = cfg.moe
    attn = d * cfg.n_heads * dh + 2 * d * cfg.n_kv_heads * dh + cfg.n_heads * dh * d
    routed_all = m.n_experts * 3 * d * m.d_ff_expert
    shared = 3 * d * (m.d_ff_shared or m.d_ff_expert * m.n_shared_experts) if m.n_shared_experts else 0
    moe_layer = attn + routed_all + shared + d * m.n_experts
    dense_layer = attn + 3 * d * cfg.d_ff
    return (
        (cfg.n_layers - cfg.moe_first_dense) * moe_layer
        + cfg.moe_first_dense * dense_layer
        + cfg.vocab * d
    )


def model_flops(cfg, kind: str, batch: int, seq_len: int) -> float:
    n_act = active_params(cfg)
    if kind == "train":
        return 6.0 * n_act * batch * seq_len
    if kind == "prefill":
        return 2.0 * n_act * batch * seq_len
    if kind == "decode":
        return 2.0 * n_act * batch  # one token per sequence
    raise ValueError(kind)
