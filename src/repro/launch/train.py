"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --reduced \
        --steps 200 --batch 16 --seq 128

Runs the resilient TrainLoop (checkpoint/restart, retries, deterministic
data) on the local devices; on a real fleet the same entrypoint runs under
``jax.distributed`` with the production mesh.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os

import jax

from ..configs import get_config, get_reduced, optimizer_for, schedule_for
from ..data.pipeline import DataConfig
from ..train.fault_tolerance import LoopConfig, TrainLoop
from ..train.optimizer import OptConfig
from ..train.train_step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(message)s")
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    opt = OptConfig(
        name=optimizer_for(args.arch),
        schedule=schedule_for(args.arch),
        peak_lr=args.lr,
        warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
    )
    tcfg = TrainConfig(opt=opt, microbatches=args.microbatches)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    loop = TrainLoop(
        cfg, tcfg, dcfg,
        LoopConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
    )
    if args.resume:
        loop.maybe_restore()
    hist = loop.run(args.steps)
    print(json.dumps(hist[-3:], indent=1))


if __name__ == "__main__":
    main()
