"""Render EXPERIMENTS.md roofline tables from dryrun_results*.json.

    PYTHONPATH=src python -m repro.launch.report [--mesh single]
"""
from __future__ import annotations

import argparse
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")


def load(name):
    p = os.path.join(ROOT, name)
    return json.load(open(p)) if os.path.exists(p) else {}


def fmt_s(x):
    if x >= 100:
        return f"{x:.0f}"
    if x >= 1:
        return f"{x:.1f}"
    return f"{x:.3f}"


def roofline_table(res, mesh="single"):
    rows = []
    for k, v in sorted(res.items()):
        if not v.get("ok") or v["mesh"] != mesh:
            continue
        r = v["roofline"]
        live = v.get("bytes_per_device_live") or 0
        rows.append(
            (
                f"{v['arch']}|{v['shape']}",
                r["compute_s"],
                r["memory_s"],
                r["collective_s"],
                r["bottleneck"],
                r["useful_flop_ratio"],
                live / 2**30,
                "✓" if v.get("fits_16gb") else ("✗" if v.get("fits_16gb") is False else "?"),
            )
        )
    out = [
        "| cell | compute s | memory s | collective s | bottleneck | useful | GiB/dev | ≤16G |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r[0]} | {fmt_s(r[1])} | {fmt_s(r[2])} | {fmt_s(r[3])} | {r[4]} "
            f"| {r[5]:.2f} | {r[6]:.1f} | {r[7]} |"
        )
    return "\n".join(out)


def ab_table(base, opt, mesh="single"):
    out = [
        "| cell | compute s (b→o) | memory s (b→o) | collective s (b→o) | coll. gain |",
        "|---|---|---|---|---|",
    ]
    for k in sorted(opt):
        v = opt[k]
        b = base.get(k)
        if not v.get("ok") or v["mesh"] != mesh or not b or not b.get("ok"):
            continue
        ro, rb = v["roofline"], b["roofline"]
        gain = rb["collective_s"] / ro["collective_s"] if ro["collective_s"] > 1e-9 else float("inf")
        out.append(
            f"| {v['arch']}|{v['shape']} | {fmt_s(rb['compute_s'])}→{fmt_s(ro['compute_s'])} "
            f"| {fmt_s(rb['memory_s'])}→{fmt_s(ro['memory_s'])} "
            f"| {fmt_s(rb['collective_s'])}→{fmt_s(ro['collective_s'])} | {gain:.1f}× |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--ab", action="store_true")
    args = ap.parse_args()
    opt = load("dryrun_results.json")
    base = load("dryrun_results_baseline.json")
    if args.ab and base:
        print(ab_table(base, opt, args.mesh))
    else:
        print(roofline_table(opt, args.mesh))


if __name__ == "__main__":
    main()
