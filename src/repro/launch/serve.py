"""Serving driver: slot-based continuous batching over a reduced model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_reduced
from ..models import api
from ..serve.server import Request, SlotServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.family in ("encdec",):
        raise SystemExit("slot server demo covers decoder-only archs")
    params = api.init_model(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, cfg.vocab, args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    srv = SlotServer(params, cfg, n_slots=args.slots,
                     max_len=args.prompt_len + args.max_new + 8)
    t0 = time.perf_counter()
    srv.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"served {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, batch-slots={args.slots})")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
