"""Observability dump: trace + metrics + drift report from a live stream.

    PYTHONPATH=src python -m repro.launch.obsdump --n 5000 --queries 200
    PYTHONPATH=src python -m repro.launch.obsdump --trace-out trace.json \
        --probe-recall

Builds a small KG-style service, enables tracing, streams the query log with
a template shift injected at the midpoint (plus one insert/delete +
``refresh()`` cycle), then prints the unified metrics snapshot and the
drift monitor's report and exports the Chrome-trace JSON — open it at
https://ui.perfetto.dev to see submit → queue wait → flush → dispatch →
merge → WAL spans per query.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import numpy as np

from ..core import HQIConfig, HQIIndex
from ..core.workload import kg_style
from ..obs import trace
from ..obs.metrics import get_registry
from ..service import HQIService, ServiceConfig
from ..store.wal import WriteAheadLog


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000, help="database rows")
    ap.add_argument("--d", type=int, default=16, help="vector dims")
    ap.add_argument("--queries", type=int, default=200, help="stream length")
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--nprobe", type=int, default=8)
    ap.add_argument("--trace-out", default="trace.json")
    ap.add_argument("--profile", action="store_true",
                    help="run the kernel dispatch profiler alongside the "
                         "stream and print its roofline-attributed table")
    ap.add_argument("--probe-recall", action="store_true",
                    help="replay the answered-query reservoir against a "
                         "brute-force scan (exact recall@k; O(n) per sample)")
    ap.add_argument("--tune", action="store_true",
                    help="after the drift report, run one index-evolution "
                         "cycle: rebuild off to the side on the shifted "
                         "traffic and blue/green-swap the new generation in")
    args = ap.parse_args()

    kg = kg_style(n=args.n, d=args.d, queries_per_split=args.queries, seed=0)
    wl = kg.splits[0]
    hqi = HQIIndex.build(
        kg.db, wl,
        HQIConfig(min_partition_size=max(128, args.n // 16), max_leaves=32),
    )
    tmp = tempfile.mkdtemp(prefix="obsdump_")
    svc = HQIService(
        hqi,
        ServiceConfig(k=wl.k, nprobe=args.nprobe, max_batch=args.max_batch,
                      deadline_s=0.002),
        wal=WriteAheadLog(os.path.join(tmp, "wal")),
    )

    tracer = trace.enable()
    prof = None
    if args.profile:
        from ..obs.profile import disable_profiler, enable_profiler

        prof = enable_profiler()

    # first half draws low-numbered templates, second half high-numbered:
    # the share shift the drift report should flag
    tcut = max(1, len(wl.templates) // 2)
    rows_a = np.where(wl.template_of < tcut)[0]
    rows_b = np.where(wl.template_of >= tcut)[0]
    if len(rows_a) == 0 or len(rows_b) == 0:
        rows_a, rows_b = np.arange(wl.m), np.arange(wl.m)

    for i in rows_a:
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
    svc.drain()

    rng = np.random.default_rng(1)
    n_new = max(8, args.n // 100)
    svc.insert(kg.db.vectors[rng.integers(0, kg.db.n, n_new)])
    svc.delete(rng.integers(0, kg.db.n, n_new // 2))
    svc.refresh()

    for i in rows_b:
        svc.submit(wl.vectors[i], wl.templates[wl.template_of[i]])
    svc.drain()

    print("== health ==")
    print(json.dumps(svc.health().as_dict(), indent=2))

    print("== metrics ==")
    print(get_registry().to_json(indent=2))

    if prof is not None:
        print("== profile ==")
        print(prof.format_table())
        disable_profiler()

    rep = svc.drift_report(probe_recall=args.probe_recall)
    print("== drift ==")
    print(json.dumps(json.loads(rep.to_json()), indent=2))

    if args.tune:
        from ..tuner import Tuner, TunerConfig

        tuner = Tuner(
            svc, tmp,
            cfg=TunerConfig(share_shift=0.2, min_window=32, retune_nprobe=False),
        )
        rec = tuner.tune_once()
        if rec is None:  # shift below threshold at this scale: swap anyway
            rec = tuner.tune_once(force=True)
        print("== tuner ==")
        print(json.dumps({
            "reason": rec.reason,
            "generation": rec.generation,
            "covered_seq": rec.covered_seq,
            "n_rows": rec.n_rows,
            "wal_tail_replayed": rec.replayed,
            "build_s": round(rec.build_s, 4),
            "swap_s": round(rec.swap_s, 4),
            "index_swaps": svc.health().index_swaps,
            "rollback_armed": tuner.can_rollback,
        }, indent=2))

    path = tracer.export(args.trace_out)
    n_events = trace.validate_chrome_trace(tracer.to_chrome_trace())
    trace.disable()
    print(f"== trace ==\n{n_events} events ({tracer.span_count} spans) "
          f"-> {path}  (open in https://ui.perfetto.dev)")


if __name__ == "__main__":
    main()
