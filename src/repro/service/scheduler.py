"""Deadline/size-triggered micro-batch scheduler for online HVQ traffic.

Online queries arrive one at a time; the PR-1 engine is fastest when handed a
whole ``Workload`` at once (one global plan, O(#buckets) dispatches). The
scheduler bridges the two: submitted queries accumulate in a FIFO and are
flushed as one synthetic workload when either trigger fires —

  * **size**: ``max_batch`` queries are waiting (a full batch amortizes the
    plan/dispatch cost best), or
  * **deadline**: the oldest query has waited ``deadline_s`` (bounds p99
    latency under light traffic).

``build_workload`` interns each query's filter into the template list — the
filter-commonality grouping of Algorithm 3 happens here for free, since KG
traffic reuses a few templates — and optionally pads the flush up to the next
power-of-two batch slot (``pad_pow2``), the static-shape discipline of
``serve/server.py``'s slot server: on TPU fleets repeated flush shapes reuse
compiled programs instead of growing the XLA cache with one entry per batch
size. Padding rows replicate query 0 and are dropped by the service before
results are handed back.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np

from ..core.plan import _next_pow2
from ..core.types import HybridQuery, Workload
from ..obs.metrics import get_registry
from ..obs.trace import get_tracer


@dataclasses.dataclass
class PendingQuery:
    """One submitted query waiting for a flush (handle owned by service.py)."""

    handle: object  # service.QueryHandle; opaque here
    vector: np.ndarray  # f32 [d]
    filt: tuple  # canonical filter (see predicates.make_filter)
    t_submit: float
    # absolute perf_counter deadline (service deadline policy); None = none.
    # The service enforces it at flush take and at fulfill — the scheduler
    # itself stays policy-free
    t_deadline: Optional[float] = None


class MicroBatchScheduler:
    """FIFO accumulator with deadline/size flush triggers (single consumer)."""

    def __init__(
        self,
        *,
        max_batch: int = 256,
        deadline_s: float = 0.005,
        pad_pow2: bool = False,
    ) -> None:
        assert max_batch >= 1
        self.max_batch = int(max_batch)
        self.deadline_s = float(deadline_s)
        self.pad_pow2 = bool(pad_pow2)
        self._pending: Deque[PendingQuery] = deque()

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, pq: PendingQuery) -> None:
        self._pending.append(pq)

    def oldest_wait(self, now: Optional[float] = None) -> float:
        """Seconds the head-of-line query has waited; 0 when idle."""
        if not self._pending:
            return 0.0
        now = time.perf_counter() if now is None else now
        return max(0.0, now - self._pending[0].t_submit)

    def ready(self, now: Optional[float] = None) -> bool:
        if not self._pending:
            return False
        if len(self._pending) >= self.max_batch:
            return True
        return self.oldest_wait(now) >= self.deadline_s

    def take(self) -> List[PendingQuery]:
        """Pop the next flush (up to ``max_batch`` queries, FIFO order)."""
        n = min(len(self._pending), self.max_batch)
        batch = [self._pending.popleft() for _ in range(n)]
        get_registry().gauge("service.queue_depth").set(len(self._pending))
        return batch

    def build_workload(self, batch: List[PendingQuery], k: int) -> Tuple[Workload, int]:
        """(synthetic Workload, n_real): flush → engine input.

        Row i of the workload is batch[i]; rows ≥ n_real are padding slots
        (present only with ``pad_pow2``) whose results the service discards.
        """
        assert batch, "empty flush"
        m = len(batch)
        with get_tracer().span("flush.build", size=m):
            wl = Workload.from_queries(
                [HybridQuery(vector=pq.vector, filter=pq.filt) for pq in batch], k=k
            )
        if self.pad_pow2:
            slots = _next_pow2(m, 1)
            if slots > m:
                pad = slots - m
                wl = Workload(
                    vectors=np.concatenate(
                        [wl.vectors, np.repeat(wl.vectors[:1], pad, axis=0)]
                    ),
                    templates=wl.templates,
                    template_of=np.concatenate(
                        [wl.template_of, np.full(pad, wl.template_of[0], dtype=np.int32)]
                    ),
                    k=k,
                )
        return wl, m
