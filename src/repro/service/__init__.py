"""Online HVQ serving subsystem (scheduler → engine → delta merge).

Public API:
    HQIService / ServiceConfig / QueryHandle / QueueFull — the facade
    MicroBatchScheduler — deadline/size-triggered micro-batching
    DeltaStore — live inserts + tombstone deletes + refresh fold
    ServiceTelemetry — p50/p99 latency, queue depth, dispatch accounting
"""
from .delta import DeltaStore  # noqa: F401
from .scheduler import MicroBatchScheduler, PendingQuery  # noqa: F401
from .service import HQIService, QueryHandle, QueueFull, ServiceConfig  # noqa: F401
from .telemetry import FlushRecord, ServiceTelemetry  # noqa: F401
