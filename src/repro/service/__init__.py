"""Online HVQ serving subsystem (scheduler → engine → delta merge).

Public API:
    HQIService / ServiceConfig / QueryHandle / ServiceHealth — the facade
    QueueFull / ResultPending / DeadlineExceeded / QueryError /
        ServiceReadOnly — the typed error surface (errors.py)
    MicroBatchScheduler — deadline/size-triggered micro-batching
    DeltaStore — live inserts + tombstone deletes + refresh fold
    ServiceTelemetry — p50/p99 latency, queue depth, dispatch accounting
"""
from .delta import DeltaStore  # noqa: F401
from .errors import (  # noqa: F401
    DeadlineExceeded,
    QueryError,
    QueueFull,
    ResultPending,
    ServiceReadOnly,
)
from .scheduler import MicroBatchScheduler, PendingQuery  # noqa: F401
from .service import (  # noqa: F401
    HQIService,
    QueryHandle,
    ServiceConfig,
    ServiceHealth,
)
from .telemetry import FlushRecord, ServiceTelemetry  # noqa: F401
