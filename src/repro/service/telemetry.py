"""Serving telemetry: latency percentiles, queue depth, dispatch accounting.

Every flush records its size, the queue depth it left behind, how many kernel
dispatches it cost (via the thread-safe ``kernels.ops.DispatchStats``
snapshots the service takes around each flush), and the per-query
submit→answer latencies. ``summary()`` reduces that to the numbers an
operator watches: p50/p99 latency, mean flush size, dispatches per flush,
peak queue depth, sustained QPS.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Deque, Dict, List, Sequence


@dataclasses.dataclass
class FlushRecord:
    size: int  # real (non-padded) queries answered
    queue_depth: int  # queries still pending after the flush
    knn_dispatches: int
    merge_dispatches: int
    seconds: float  # wall time of the flush's answer pipeline
    # memory observability: the flush's largest candidate merge buffer and
    # the ADC LUT bytes it materialized (0 for f32 scans)
    peak_candidate_bytes: int = 0
    lut_bytes: int = 0


class ServiceTelemetry:
    """Thread-safe accumulator shared by the scheduler thread and callers.

    Percentiles are computed over a bounded window of the most recent
    ``window`` latencies / flushes (a long-lived service must not grow
    memory with uptime); totals (query/flush/dispatch counts, busy time)
    are running sums over the whole lifetime.
    """

    def __init__(self, window: int = 65_536) -> None:
        self._lock = threading.Lock()
        self._latencies: Deque[float] = deque(maxlen=window)
        self._flushes: Deque[FlushRecord] = deque(maxlen=max(1, window // 16))
        self._rejected = 0
        # lifetime totals (windows above are for percentiles/recent stats)
        self._n_queries = 0
        self._n_flushes = 0
        self._busy_s = 0.0
        self._knn = 0
        self._merge = 0
        self._size_sum = 0
        self._max_depth = 0
        self._peak_candidate_bytes = 0
        self._lut_bytes = 0
        # self-healing accounting (repro.fault): contained flush crashes,
        # queries failed by deadline expiry, overload-degraded flushes and
        # mode transitions, background-loop errors survived
        self._flush_failures = 0
        self._failed_queries = 0
        self._deadline_expired = 0
        self._degraded_flushes = 0
        self._degraded_transitions = 0
        self._loop_errors = 0
        self._index_swaps = 0

    # ------------------------------------------------------------- recording

    def record_flush(
        self,
        *,
        size: int,
        queue_depth: int,
        knn_dispatches: int,
        merge_dispatches: int,
        seconds: float,
        latencies: Sequence[float],
        peak_candidate_bytes: int = 0,
        lut_bytes: int = 0,
    ) -> None:
        with self._lock:
            self._flushes.append(
                FlushRecord(
                    size, queue_depth, knn_dispatches, merge_dispatches, seconds,
                    peak_candidate_bytes, lut_bytes,
                )
            )
            self._latencies.extend(float(x) for x in latencies)
            self._n_queries += len(latencies)
            self._n_flushes += 1
            self._busy_s += seconds
            self._knn += knn_dispatches
            self._merge += merge_dispatches
            self._size_sum += size
            self._max_depth = max(self._max_depth, queue_depth)
            self._peak_candidate_bytes = max(
                self._peak_candidate_bytes, int(peak_candidate_bytes)
            )
            self._lut_bytes += int(lut_bytes)

    def record_rejected(self) -> None:
        with self._lock:
            self._rejected += 1

    def record_flush_failure(self, n_queries: int) -> None:
        """One flush pipeline crash contained; its queries failed typed."""
        with self._lock:
            self._flush_failures += 1
            self._failed_queries += int(n_queries)

    def record_deadline_expired(self, n_queries: int = 1) -> None:
        with self._lock:
            self._deadline_expired += int(n_queries)

    def record_degraded_flush(self) -> None:
        with self._lock:
            self._degraded_flushes += 1

    def record_degraded_transition(self) -> None:
        """Overload mode flipped (either direction — count both edges)."""
        with self._lock:
            self._degraded_transitions += 1

    def record_loop_error(self) -> None:
        """Background scheduler loop survived a tick exception."""
        with self._lock:
            self._loop_errors += 1

    def record_swap(self) -> None:
        """One completed blue/green index swap (HQIService.swap_index)."""
        with self._lock:
            self._index_swaps += 1

    # --------------------------------------------------------------- reading

    def recent_flushes(self, n: int = 32) -> List[Dict[str, float]]:
        """The most recent flush records as dicts (oldest first) — the
        flight recorder snapshots these into incident bundles."""
        with self._lock:
            tail = list(self._flushes)[-int(n):]
        return [dataclasses.asdict(r) for r in tail]

    @staticmethod
    def _rank(lats, q: float) -> float:
        # nearest-rank percentile over a SORTED list: no numpy dependency
        # needed host-side, and p99 of small samples stays an observed value
        # rather than an interpolation between two
        rank = min(len(lats) - 1, max(0, int(round(q / 100.0 * (len(lats) - 1)))))
        return lats[rank]

    def latency_percentile(self, q: float) -> float:
        """Latency percentile in seconds; q in [0, 100]. 0.0 when empty."""
        with self._lock:
            lats = list(self._latencies)
        if not lats:
            return 0.0
        lats.sort()
        return self._rank(lats, q)

    def summary(self) -> Dict[str, float]:
        # one lock acquisition, one deque copy, one sort — p50 and p99 read
        # the same sorted window instead of each re-copying and re-sorting it
        with self._lock:
            n_q, n_f = self._n_queries, self._n_flushes
            lats = list(self._latencies)
            out: Dict[str, float] = {
                "queries": float(n_q),
                "flushes": float(n_f),
                "rejected": float(self._rejected),
                "mean_flush_size": (self._size_sum / n_f) if n_f else 0.0,
                "max_queue_depth": float(self._max_depth),
                "knn_dispatches_per_flush": (self._knn / n_f) if n_f else 0.0,
                "merge_dispatches_per_flush": (self._merge / n_f) if n_f else 0.0,
                "busy_qps": (n_q / self._busy_s) if self._busy_s > 0 else 0.0,
                "peak_candidate_bytes": float(self._peak_candidate_bytes),
                "lut_bytes_per_flush": (self._lut_bytes / n_f) if n_f else 0.0,
                "flush_failures": float(self._flush_failures),
                "failed_queries": float(self._failed_queries),
                "deadline_expired": float(self._deadline_expired),
                "degraded_flushes": float(self._degraded_flushes),
                "degraded_transitions": float(self._degraded_transitions),
                "loop_errors": float(self._loop_errors),
                "index_swaps": float(self._index_swaps),
            }
        lats.sort()
        out["p50_latency_s"] = self._rank(lats, 50.0) if lats else 0.0
        out["p99_latency_s"] = self._rank(lats, 99.0) if lats else 0.0
        return out
