"""DeltaStore — the freshness layer: live inserts, tombstone deletes, refresh.

The main ``HQIIndex`` is a build-time artifact; a serving system cannot
rebuild it per write. The DeltaStore makes writes visible immediately:

  * **inserts** append to a small side buffer (schema checked against the
    base DB; omitted columns become NULL). Every flush brute-force scans the
    buffer's live rows with the same fused masked-top-k kernel the engine
    uses (``kernels.ops.workunit_topk``, one dispatch per flush with one work
    unit per template) and the service folds those candidates into the final
    ``merge_topk`` — so answers always reflect the live DB.
  * **deletes** are tombstones: delta rows are dropped from the scan, indexed
    rows are excluded through the ``live_mask`` the service passes to
    ``HQIIndex.search``. Either way exact, no over-fetch heuristics.
  * **refresh()** (driven by the service) folds the buffer into the main
    index via ``HQIIndex.extend`` — qd-tree leaf routing by semantic
    description, incremental IVF append, incremental arena rebuild — and
    clears the buffer. Global ids are stable: delta row ids continue the
    index's row numbering, so a fold changes *where* a tuple lives, never its
    id. Tombstoned delta rows are folded too (as dead rows under the live
    mask) to keep ids dense; a future compaction pass can reclaim them.

Brute force over the buffer is the right trade: the buffer stays small
between refreshes (it is the write working set), so one fused scan costs less
than maintaining a second index, and the scan shares the engine's padded
power-of-two shapes so it reuses compiled kernels across flushes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.ivf import ScanStats
from ..core.plan import _next_pow2
from ..core.predicates import evaluate_filter
from ..core.types import CATEGORICAL, Column, NUMERIC, SETCAT, VectorDatabase, Workload
from ..kernels import ops as kops


class DeltaStore:
    """Append buffer + tombstones over a base schema; ids start at first_id."""

    def __init__(self, schema_db: VectorDatabase, first_id: int) -> None:
        self._schema = schema_db  # schema donor only; rows never touched
        self.first_id = int(first_id)
        self._db: Optional[VectorDatabase] = None
        self._dead = np.zeros(0, dtype=bool)

    @property
    def n(self) -> int:
        """Buffered rows, dead included (ids first_id .. first_id + n - 1)."""
        return 0 if self._db is None else self._db.n

    @property
    def n_live(self) -> int:
        return int((~self._dead).sum())

    # ---------------------------------------------------------------- writes

    def _make_columns(
        self,
        n: int,
        columns: Optional[Dict[str, np.ndarray]],
        null_masks: Optional[Dict[str, np.ndarray]],
    ) -> Dict[str, Column]:
        columns = columns or {}
        null_masks = null_masks or {}
        unknown = set(columns) - set(self._schema.columns)
        assert not unknown, f"insert references unknown columns {sorted(unknown)}"
        out: Dict[str, Column] = {}
        for name, ref in self._schema.columns.items():
            if name not in columns:
                out[name] = Column.all_null(ref, n)
                continue
            vals = columns[name]
            nm = null_masks.get(name)
            if ref.kind == NUMERIC:
                out[name] = Column.numeric(name, vals, null_mask=nm)
            elif ref.kind == CATEGORICAL:
                out[name] = Column.categorical(name, vals, null_mask=nm)
            else:
                assert ref.kind == SETCAT
                out[name] = Column.setcat(name, vals)
            assert out[name].n == n, f"column {name}: {out[name].n} rows, expected {n}"
        return out

    def insert(
        self,
        vectors: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> np.ndarray:
        """Append rows; returns their global ids (visible to the next flush)."""
        vectors = np.atleast_2d(np.asarray(vectors, dtype=np.float32))
        assert vectors.shape[1] == self._schema.d, "vector dimension mismatch"
        n = vectors.shape[0]
        ids = self.first_id + self.n + np.arange(n, dtype=np.int64)
        slab = VectorDatabase(
            vectors=vectors,
            columns=self._make_columns(n, columns, null_masks),
            metric=self._schema.metric,
            ids=ids,
        )
        self._db = slab if self._db is None else VectorDatabase.concat(self._db, slab)
        self._dead = np.concatenate([self._dead, np.zeros(n, dtype=bool)])
        return ids

    def delete(self, ext_id: int) -> bool:
        """Tombstone a buffered row; False if the id is not in the buffer."""
        local = int(ext_id) - self.first_id
        if 0 <= local < self.n and not self._dead[local]:
            self._dead[local] = True
            return True
        return False

    # ----------------------------------------------------------------- reads

    def view(self) -> "DeltaView":
        """Immutable scan snapshot (db slab, live mask, id base).

        The lock-free flush path captures this under the service lock and
        scans OUTSIDE it: the slab is replaced (never mutated) by ``insert``
        and the live mask is copied here, so a concurrent writer can't shift
        the snapshot under the scan.
        """
        return DeltaView(
            db=self._db,
            live=~self._dead.copy(),
            first_id=self.first_id,
        )

    def scan(
        self,
        workload: Workload,
        *,
        stats: Optional[ScanStats] = None,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Brute-force top-k over live buffered rows, per query.

        Returns (scores f32 [m, k], global ids i64 [m, k]) best-first with
        (-inf, -1) padding, or None when no buffered row passes any filter —
        one ``workunit_topk`` dispatch, one work unit per flush template,
        shapes padded to powers of two for compile reuse.
        """
        return self.view().scan(workload, stats=stats)
    # --------------------------------------------------------------- refresh

    def snapshot(self) -> Tuple[Optional[VectorDatabase], np.ndarray]:
        """(buffered rows incl. tombstoned, live mask) — the refresh fold input."""
        return self._db, ~self._dead.copy()

    def clear(self, first_id: int) -> None:
        """Reset after a fold; subsequent inserts continue from ``first_id``."""
        self._db = None
        self._dead = np.zeros(0, dtype=bool)
        self.first_id = int(first_id)


@dataclasses.dataclass
class DeltaView:
    """A consistent point-in-time scan view of the buffer (see ``view()``)."""

    db: Optional[VectorDatabase]
    live: np.ndarray  # bool — alive among the snapshot's buffered rows
    first_id: int

    def scan(
        self,
        workload: Workload,
        *,
        stats: Optional[ScanStats] = None,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Brute-force top-k over the snapshot's live rows, per query."""
        db = self.db
        if db is None or not self.live.any():
            return None
        live = self.live
        k, m, d = workload.k, workload.m, db.d
        groups = []  # (qidx, bitmap over buffered rows)
        for ti, filt in enumerate(workload.templates):
            qidx = workload.queries_for_template(ti)
            if len(qidx) == 0:
                continue
            bm = evaluate_filter(filt, db) & live
            if stats is not None:
                stats.tuples_scanned += db.n * len(qidx)
                stats.dists_computed += int(bm.sum()) * len(qidx)
            if bm.any():
                groups.append((qidx, bm))
        if not groups:
            return None
        W = len(groups)
        TQ = _next_pow2(max(len(q) for q, _ in groups), 1)
        TV = _next_pow2(db.n, 8)
        Q = np.zeros((W, TQ, d), dtype=np.float32)
        V = np.zeros((W, TV, d), dtype=np.float32)
        valid = np.zeros((W, TV), dtype=bool)
        V[:, : db.n] = db.vectors
        for w, (qidx, bm) in enumerate(groups):
            Q[w, : len(qidx)] = workload.vectors[qidx]
            valid[w, : db.n] = bm
        kk = min(k, TV)
        s, iloc = kops.workunit_topk(
            jnp.asarray(Q), jnp.asarray(V), jnp.asarray(valid), kk, metric=db.metric
        )
        s = np.asarray(s)
        iloc = np.asarray(iloc).astype(np.int64)
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i = np.full((m, k), -1, np.int64)
        for w, (qidx, _) in enumerate(groups):
            nq = len(qidx)
            out_i[qidx, :kk] = np.where(
                iloc[w, :nq] >= 0, self.first_id + iloc[w, :nq], -1
            )
            out_s[qidx, :kk] = s[w, :nq]
        out_s = np.where(out_i < 0, -np.inf, out_s)
        return out_s, out_i
