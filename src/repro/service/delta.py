"""DeltaStore — the freshness layer: live inserts, tombstone deletes, refresh.

The main ``HQIIndex`` is a build-time artifact; a serving system cannot
rebuild it per write. The DeltaStore makes writes visible immediately:

  * **inserts** append to a small side buffer (schema checked against the
    base DB; omitted columns become NULL). Every flush brute-force scans the
    buffer's live rows with the same fused masked-top-k kernel the engine
    uses (``kernels.ops.workunit_topk``, one dispatch per flush with one work
    unit per template) and the service folds those candidates into the final
    ``merge_topk`` — so answers always reflect the live DB.
  * **deletes** are tombstones: delta rows are dropped from the scan, indexed
    rows are excluded through the ``live_mask`` the service passes to
    ``HQIIndex.search``. Either way exact, no over-fetch heuristics.
  * **refresh()** (driven by the service) folds the buffer into the main
    index via ``HQIIndex.extend`` — qd-tree leaf routing by semantic
    description, incremental IVF append, incremental arena rebuild — and
    clears the buffer. Global ids are stable: delta row ids continue the
    index's row numbering, so a fold changes *where* a tuple lives, never its
    id. Tombstoned delta rows are folded too (as dead rows under the live
    mask) to keep ids dense; a future compaction pass can reclaim them.

Brute force over the buffer is the right trade: the buffer stays small
between refreshes (it is the write working set), so one fused scan costs less
than maintaining a second index, and the scan shares the engine's padded
power-of-two shapes so it reuses compiled kernels across flushes.

Compressed delta scans: when the store carries the index's ``PQCodebook``
(the serving layer passes ``HQIIndex.pq``) and the live buffer has outgrown
``ServiceConfig.delta_pq_threshold``, the flush scan switches to the same
two-stage path the engine uses — rows are PQ-encoded once at insert time,
the scan reads uint8 codes through ``kernels.ops.workunit_pq_topk`` (ADC),
and the ``refine_factor · k`` survivors are re-scored exactly from the f32
rows in one ``workunit_topk`` dispatch. Large write bursts between refreshes
stop paying d·4 bytes per scanned row; buffers under the threshold keep the
exact f32 scan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.ivf import ScanStats
from ..core.plan import _next_pow2
from ..core.pq import PQCodebook, adc_tables, encode_pq
from ..core.predicates import evaluate_filter
from ..core.types import CATEGORICAL, Column, NUMERIC, SETCAT, VectorDatabase, Workload
from ..fault.failpoints import failpoint
from ..kernels import ops as kops


class DeltaStore:
    """Append buffer + tombstones over a base schema; ids start at first_id.

    With ``pq`` set (the index codebook), inserted rows are additionally
    PQ-encoded on arrival — incremental, one ``encode_pq`` per insert batch —
    so a compressed flush scan never re-encodes the whole buffer.
    """

    def __init__(
        self,
        schema_db: VectorDatabase,
        first_id: int,
        pq: Optional[PQCodebook] = None,
    ) -> None:
        self._schema = schema_db  # schema donor only; rows never touched
        self.first_id = int(first_id)
        self.pq = pq
        self._db: Optional[VectorDatabase] = None
        self._dead = np.zeros(0, dtype=bool)
        self._codes: Optional[np.ndarray] = None  # uint8 [n, M], iff pq
        # rows prepared (ids handed out) but not yet committed — group-commit
        # inserts prepare under the service lock, then commit in id order
        # after the shared fsync, so id assignment must advance at prepare
        self._reserved = 0

    @property
    def n(self) -> int:
        """Buffered rows, dead included (ids first_id .. first_id + n - 1)."""
        return 0 if self._db is None else self._db.n

    @property
    def n_live(self) -> int:
        return int((~self._dead).sum())

    # ---------------------------------------------------------------- writes

    def _make_columns(
        self,
        n: int,
        columns: Optional[Dict[str, np.ndarray]],
        null_masks: Optional[Dict[str, np.ndarray]],
    ) -> Dict[str, Column]:
        columns = columns or {}
        null_masks = null_masks or {}
        unknown = set(columns) - set(self._schema.columns)
        assert not unknown, f"insert references unknown columns {sorted(unknown)}"
        out: Dict[str, Column] = {}
        for name, ref in self._schema.columns.items():
            if name not in columns:
                out[name] = Column.all_null(ref, n)
                continue
            vals = columns[name]
            nm = null_masks.get(name)
            if ref.kind == NUMERIC:
                out[name] = Column.numeric(name, vals, null_mask=nm)
            elif ref.kind == CATEGORICAL:
                out[name] = Column.categorical(name, vals, null_mask=nm)
            else:
                assert ref.kind == SETCAT
                out[name] = Column.setcat(name, vals)
            assert out[name].n == n, f"column {name}: {out[name].n} rows, expected {n}"
        return out

    def prepare_insert(
        self,
        vectors: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> Tuple[VectorDatabase, np.ndarray]:
        """Validate + stage an insert WITHOUT applying it: (slab, ids).

        Split from ``insert`` for the WAL ordering in service.py: the commit
        record must hit disk after validation (a rejected insert is never
        logged) but before the buffer mutates (a failed append leaves no
        unlogged rows behind). ``commit_insert`` is infallible.
        """
        vectors = np.atleast_2d(np.asarray(vectors, dtype=np.float32))
        assert vectors.shape[1] == self._schema.d, "vector dimension mismatch"
        n = vectors.shape[0]
        ids = self.first_id + self.n + self._reserved + np.arange(n, dtype=np.int64)
        self._reserved += n
        slab = VectorDatabase(
            vectors=vectors,
            columns=self._make_columns(n, columns, null_masks),
            metric=self._schema.metric,
            ids=ids,
        )
        return slab, ids

    def abort_insert(self, ids: np.ndarray) -> None:
        """Release a prepared-but-unlogged insert's id reservation.

        ONLY legal when the prepared slab never reached the WAL (stage
        failed) and no later prepare has happened — prepare and stage share
        one critical section in service.py, so the aborted ids are always the
        reservation's tail and handing them to the next insert is safe. A
        slab that IS in the log must never be aborted: a replay would
        re-mint its ids and diverge.
        """
        n = len(np.atleast_1d(ids))
        assert self._reserved >= n, "abort_insert without matching prepare"
        expect = self.first_id + self.n + self._reserved - n
        assert n == 0 or int(np.atleast_1d(ids)[0]) == expect, (
            "abort_insert out of order — only the newest reservation may abort"
        )
        self._reserved -= n

    def commit_insert(self, slab: VectorDatabase, ids: np.ndarray) -> np.ndarray:
        """Apply a prepared insert (no validation — see ``prepare_insert``).

        Prepared slabs MUST commit in id order (the service's group-commit
        path tickets them): rows concatenate, so first_id + position = id.
        """
        failpoint("delta.apply")
        n = slab.n
        assert n == 0 or self.first_id + self.n == int(ids[0]), (
            "commit_insert out of id order"
        )
        self._reserved = max(0, self._reserved - n)
        self._db = slab if self._db is None else VectorDatabase.concat(self._db, slab)
        self._dead = np.concatenate([self._dead, np.zeros(n, dtype=bool)])
        if self.pq is not None:
            new_codes = encode_pq(self.pq, slab.vectors)
            self._codes = (
                new_codes
                if self._codes is None
                else np.concatenate([self._codes, new_codes], axis=0)
            )
        return ids

    def insert(
        self,
        vectors: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> np.ndarray:
        """Append rows; returns their global ids (visible to the next flush)."""
        slab, ids = self.prepare_insert(vectors, columns, null_masks)
        return self.commit_insert(slab, ids)

    def delete(self, ext_id: int) -> bool:
        """Tombstone a buffered row; False if the id is not in the buffer."""
        local = int(ext_id) - self.first_id
        if 0 <= local < self.n and not self._dead[local]:
            self._dead[local] = True
            return True
        return False

    # ----------------------------------------------------------------- reads

    def view(self) -> "DeltaView":
        """Immutable scan snapshot (db slab, live mask, id base).

        The lock-free flush path captures this under the service lock and
        scans OUTSIDE it: the slab is replaced (never mutated) by ``insert``
        and the live mask is copied here, so a concurrent writer can't shift
        the snapshot under the scan.
        """
        return DeltaView(
            db=self._db,
            live=~self._dead.copy(),
            first_id=self.first_id,
            pq=self.pq,
            codes=self._codes,
        )

    def scan(
        self,
        workload: Workload,
        *,
        stats: Optional[ScanStats] = None,
        pq_threshold: Optional[int] = None,
        refine_factor: int = 4,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Brute-force top-k over live buffered rows, per query.

        Returns (scores f32 [m, k], global ids i64 [m, k]) best-first with
        (-inf, -1) padding, or None when no buffered row passes any filter —
        one ``workunit_topk`` dispatch, one work unit per flush template,
        shapes padded to powers of two for compile reuse. (See
        ``DeltaView.scan`` for the compressed path the knobs select.)
        """
        return self.view().scan(
            workload,
            stats=stats,
            pq_threshold=pq_threshold,
            refine_factor=refine_factor,
        )
    # --------------------------------------------------------------- refresh

    def snapshot(self) -> Tuple[Optional[VectorDatabase], np.ndarray]:
        """(buffered rows incl. tombstoned, live mask) — the refresh fold input."""
        return self._db, ~self._dead.copy()

    def clear(self, first_id: int) -> None:
        """Reset after a fold; subsequent inserts continue from ``first_id``."""
        self._db = None
        self._dead = np.zeros(0, dtype=bool)
        self._codes = None
        self._reserved = 0
        self.first_id = int(first_id)


@dataclasses.dataclass
class DeltaView:
    """A consistent point-in-time scan view of the buffer (see ``view()``)."""

    db: Optional[VectorDatabase]
    live: np.ndarray  # bool — alive among the snapshot's buffered rows
    first_id: int
    pq: Optional[PQCodebook] = None  # index codebook (compressed scans)
    codes: Optional[np.ndarray] = None  # uint8 [n, M], row-aligned with db

    def scan(
        self,
        workload: Workload,
        *,
        stats: Optional[ScanStats] = None,
        pq_threshold: Optional[int] = None,
        refine_factor: int = 4,
    ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Top-k over the snapshot's live rows, per query.

        Exact brute force by default. When the view carries the index
        codebook and the live buffer exceeds ``pq_threshold``, the scan runs
        compressed instead: one ADC dispatch over the uint8 codes keeping
        ``refine_factor · k`` candidates per query, then one exact f32
        re-rank dispatch of the survivors — M bytes scanned per row instead
        of d·4. Buffers at or under the threshold stay exact.
        """
        db = self.db
        if db is None or not self.live.any():
            return None
        groups = self._groups(workload, stats)
        if not groups:
            return None
        use_pq = (
            self.pq is not None
            and self.codes is not None
            and pq_threshold is not None
            and int(self.live.sum()) > int(pq_threshold)
        )
        if use_pq:
            return self._scan_pq(workload, groups, refine_factor, stats)
        return self._scan_f32(workload, groups, stats)

    def _groups(
        self, workload: Workload, stats: Optional[ScanStats]
    ) -> list:
        """Per-template (query rows, filtered live bitmap) scan groups."""
        db = self.db
        groups = []  # (qidx, bitmap over buffered rows)
        for ti, filt in enumerate(workload.templates):
            qidx = workload.queries_for_template(ti)
            if len(qidx) == 0:
                continue
            bm = evaluate_filter(filt, db) & self.live
            if stats is not None:
                stats.tuples_scanned += db.n * len(qidx)
                stats.dists_computed += int(bm.sum()) * len(qidx)
            if bm.any():
                groups.append((qidx, bm))
        return groups

    def _scan_f32(
        self,
        workload: Workload,
        groups: list,
        stats: Optional[ScanStats],
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The exact path: one fused f32 work-unit dispatch per flush."""
        db = self.db
        k, m, d = workload.k, workload.m, db.d
        W = len(groups)
        TQ = _next_pow2(max(len(q) for q, _ in groups), 1)
        TV = _next_pow2(db.n, 8)
        Q = np.zeros((W, TQ, d), dtype=np.float32)
        V = np.zeros((W, TV, d), dtype=np.float32)
        valid = np.zeros((W, TV), dtype=bool)
        V[:, : db.n] = db.vectors
        for w, (qidx, bm) in enumerate(groups):
            Q[w, : len(qidx)] = workload.vectors[qidx]
            valid[w, : db.n] = bm
        if stats is not None:
            stats.bytes_scanned += W * db.n * d * 4
        kk = min(k, TV)
        s, iloc = kops.workunit_topk(
            jnp.asarray(Q), jnp.asarray(V), jnp.asarray(valid), kk, metric=db.metric
        )
        s = np.asarray(s)
        iloc = np.asarray(iloc).astype(np.int64)
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i = np.full((m, k), -1, np.int64)
        for w, (qidx, _) in enumerate(groups):
            nq = len(qidx)
            out_i[qidx, :kk] = np.where(
                iloc[w, :nq] >= 0, self.first_id + iloc[w, :nq], -1
            )
            out_s[qidx, :kk] = s[w, :nq]
        out_s = np.where(out_i < 0, -np.inf, out_s)
        return out_s, out_i

    def _scan_pq(
        self,
        workload: Workload,
        groups: list,
        refine_factor: int,
        stats: Optional[ScanStats],
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Compressed path: ADC over uint8 codes, exact re-rank of survivors.

        Mirrors the engine's two-stage ``scan_mode="pq"`` execution
        (core/planner.py): stage A is one ``workunit_pq_topk`` dispatch over
        the buffer's code rows (one work unit per flush template, LUTs built
        once per flush), stage B gathers the surviving rows' f32 vectors and
        re-scores them exactly in one per-query ``workunit_topk`` dispatch —
        so returned scores are exact and directly mergeable with the
        engine's (exact) results.
        """
        db = self.db
        k, m, d = workload.k, workload.m, db.d
        M = self.codes.shape[1]
        W = len(groups)
        TQ = _next_pow2(max(len(q) for q, _ in groups), 1)
        TV = _next_pow2(db.n, 8)
        kprime = min(max(k, int(refine_factor) * k), TV)

        luts_all = adc_tables(self.pq, workload.vectors)  # [m, M, 256]
        luts = np.zeros((W, TQ, M, luts_all.shape[2]), dtype=np.float32)
        codes = np.zeros((W, TV, M), dtype=np.uint8)
        valid = np.zeros((W, TV), dtype=bool)
        codes[:, : db.n] = self.codes
        for w, (qidx, bm) in enumerate(groups):
            luts[w, : len(qidx)] = luts_all[qidx]
            valid[w, : db.n] = bm
        if stats is not None:
            stats.bytes_scanned += W * db.n * M
        _, iloc = kops.workunit_pq_topk(
            jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(valid), kprime
        )
        iloc = np.asarray(iloc).astype(np.int64)  # [W, TQ, kprime] buffer rows

        # per-query survivor rows (each query scans exactly one group)
        rows = np.full((m, kprime), -1, dtype=np.int64)
        for w, (qidx, _) in enumerate(groups):
            rows[qidx] = iloc[w, : len(qidx)]

        # exact re-rank: one per-query-unit dispatch over the survivors
        mp = _next_pow2(m, 1)
        Qr = np.zeros((mp, 1, d), dtype=np.float32)
        Qr[:m, 0] = workload.vectors
        Vr = np.zeros((mp, kprime, d), dtype=np.float32)
        Vr[:m] = db.vectors[np.maximum(rows, 0)]
        valid_r = np.zeros((mp, kprime), dtype=bool)
        valid_r[:m] = rows >= 0
        if stats is not None:
            stats.bytes_scanned += int((rows >= 0).sum()) * d * 4
        kk = min(k, kprime)
        s, i_loc = kops.workunit_topk(
            jnp.asarray(Qr),
            jnp.asarray(Vr),
            jnp.asarray(valid_r),
            kk,
            metric=db.metric,
        )
        s = np.asarray(s)[:m, 0]  # [m, kk] exact scores
        i_loc = np.asarray(i_loc)[:m, 0].astype(np.int64)  # idx into survivors
        picked = np.take_along_axis(rows, np.maximum(i_loc, 0), axis=1)
        out_i = np.full((m, k), -1, np.int64)
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i[:, :kk] = np.where(i_loc >= 0, self.first_id + picked, -1)
        out_s[:, :kk] = np.where(i_loc >= 0, s, -np.inf)
        out_s = np.where(out_i < 0, -np.inf, out_s)
        return out_s, out_i
