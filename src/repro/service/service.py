"""HQIService — the online serving facade over the PR-1 plan/execute engine.

Data plane, per flush (see scheduler.py for when a flush fires):

    submit() ─┐
    submit() ─┼─▶ MicroBatchScheduler ──▶ synthetic Workload
    submit() ─┘                               │
                                  HQIIndex.search(batch_vec="auto",
                                                  live_mask=tombstones)
                                               │
                    DeltaStore.scan (live inserts, one fused dispatch)
                                               │
                                  kernels.ops.merge_topk  ──▶ QueryHandle

Control plane: ``insert``/``delete`` are visible to the very next flush
(delta scan + tombstone mask); ``refresh()`` folds the delta into the main
index partitions (``HQIIndex.extend``) and invalidates the Router bitmap
cache and arena — never a full rebuild. Admission control bounds the pending
queue; ``submit`` raises ``QueueFull`` beyond ``ServiceConfig.queue_bound``.

The service can be driven synchronously (``tick``/``drain`` — what the
benchmarks and tests do) or by a background thread (``start``/``stop``) with
callers blocking on ``QueryHandle.wait()``; kernel-dispatch accounting stays
correct either way because ``DispatchStats`` is lock-protected.

Flushes are lock-free for writers: ``_flush`` snapshots (batch, live mask,
delta view) under the state lock, dispatches the kernel pipeline outside it,
and re-acquires only to fulfill handles — ``submit``/``insert``/``delete``
during a slow flush queue into the next micro-batch instead of blocking
(tests/test_service.py has the threaded regression). When the index was
built with ``HQIConfig.mesh`` set, every flush's engine work runs on the
device mesh through the sharded executor, transparently.

Durability (repro.store): with a ``WriteAheadLog`` attached (``wal=``, wired
by ``store.recovery.open_service``/``init_store``), every ``insert``/
``delete`` commits a WAL record *before* acknowledging, ``refresh()`` seals
the current WAL segment at the fold boundary, and ``store.compact.Compactor``
periodically folds + snapshots so restart cost stays O(mmap + WAL tail).
Without a WAL the service is purely in-memory, exactly as before.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core.hqi import HQIIndex
from ..core.ivf import ScanStats
from ..core.types import VectorDatabase, Workload
from ..kernels import ops as kops
from ..obs.drift import DriftConfig, DriftMonitor, DriftReport
from ..obs.metrics import get_registry
from ..obs.trace import fence, get_tracer
from .delta import DeltaStore
from .scheduler import MicroBatchScheduler, PendingQuery
from .telemetry import ServiceTelemetry


class QueueFull(RuntimeError):
    """Admission control: the pending queue is at ``queue_bound``."""


@dataclasses.dataclass
class ServiceConfig:
    k: int = 10
    nprobe: Union[int, Dict[int, int]] = 8
    batch_vec: Union[bool, str] = "auto"  # the §6.5 adaptive executor
    max_batch: int = 256  # size flush trigger
    deadline_s: float = 0.005  # latency flush trigger (oldest query's wait)
    queue_bound: int = 8192  # admission control: max pending queries
    pad_pow2: bool = False  # pad flushes to power-of-two batch slots (TPU)
    # delta-store compression: once the live delta buffer exceeds this many
    # rows (and the index has a PQ codebook), flush scans encode the delta
    # through the ADC path with exact f32 re-rank of the survivors instead
    # of brute-forcing f32 rows; None disables. Buffers at or under the
    # threshold always scan exact.
    delta_pq_threshold: Optional[int] = 4096
    # workload-drift monitor (obs.drift): sliding window of answered-query
    # templates and reservoir size for the live recall probe
    drift_window: int = 4096
    recall_reservoir: int = 64


@dataclasses.dataclass
class QueryHandle:
    """Caller-side future for one submitted query."""

    qid: int
    t_submit: float
    ids: Optional[np.ndarray] = None  # i64 [k] once done (-1 padding)
    scores: Optional[np.ndarray] = None  # f32 [k] best-first
    t_done: float = 0.0
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False
    )

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(self) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, scores); raises if the query has not been answered yet."""
        if not self.done:
            raise RuntimeError(f"query {self.qid} not answered yet")
        return self.ids, self.scores

    @property
    def latency_s(self) -> float:
        return (self.t_done - self.t_submit) if self.done else float("nan")

    def _fulfill(self, ids: np.ndarray, scores: np.ndarray, t_done: float) -> None:
        self.ids = ids
        self.scores = scores
        self.t_done = t_done
        self._event.set()


class HQIService:
    """Streaming HVQ service: micro-batched reads, immediately-visible writes."""

    def __init__(
        self,
        index: HQIIndex,
        cfg: Optional[ServiceConfig] = None,
        wal=None,  # store.wal.WriteAheadLog; None = in-memory only
    ) -> None:
        self.index = index
        self.cfg = ServiceConfig() if cfg is None else cfg
        self.wal = wal
        # last WAL record whose effects live in (index, _live) rather than
        # the delta buffer — what a snapshot of this service covers
        # (store.compact reads it; store.recovery seeds it after a replay)
        self._wal_folded_seq = 0 if wal is None else wal.last_seq
        # group commit bookkeeping: writers stage their WAL record under the
        # state lock (fixing seq order = id order), share one fsync outside
        # it, then apply in ticket order — _applied_seq is the highest seq
        # whose effects are actually in (delta, _live), which is what a fold
        # may claim as covered (wal.last_seq could include records a
        # concurrent writer has staged but not yet applied)
        self._commit_head = 0
        self._commit_tail = 0
        self._applied_seq = 0 if wal is None else wal.last_seq
        self.scheduler = MicroBatchScheduler(
            max_batch=self.cfg.max_batch,
            deadline_s=self.cfg.deadline_s,
            pad_pow2=self.cfg.pad_pow2,
        )
        # hand the delta the codebook only when compressed delta scans can
        # actually fire — otherwise inserts would pay encode_pq for codes
        # the scan path never reads
        self.delta = DeltaStore(
            index.db,
            first_id=index.db.n,
            pq=index.pq if self.cfg.delta_pq_threshold is not None else None,
        )
        self.telemetry = ServiceTelemetry()
        # workload observer feeding the future hot-swap tuner; fed by _flush,
        # read via drift_report()
        self.drift = DriftMonitor(
            DriftConfig(
                window=self.cfg.drift_window, reservoir=self.cfg.recall_reservoir
            )
        )
        # fold this service's telemetry into the process metrics registry
        # (latest service wins the "service" slot — one serving process is
        # the deployment unit)
        get_registry().attach_source("service", self.telemetry.summary)
        self._live = np.ones(index.db.n, dtype=bool)  # tombstones over indexed rows
        # state lock for scheduler + delta + live-mask: writers and the flush
        # snapshot take it BRIEFLY — kernel dispatch happens outside it, so
        # submit()/insert()/delete() never block for a flush's duration
        self._lock = threading.RLock()
        # writers park here until their commit ticket comes up (group commit)
        self._commit_cv = threading.Condition(self._lock)
        # flush lock serializes the out-of-lock pipeline sections: flushes
        # against each other (single logical consumer) and against refresh(),
        # which swaps index structures the in-flight search reads
        self._flush_lock = threading.Lock()
        self._next_qid = 0
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()

    # ------------------------------------------------------------ data plane

    def submit(self, vector: np.ndarray, filt: tuple = ()) -> QueryHandle:
        """Enqueue one hybrid query; answered at the next flush (tick/run)."""
        now = time.perf_counter()
        with self._lock:
            if len(self.scheduler) >= self.cfg.queue_bound:
                self.telemetry.record_rejected()
                raise QueueFull(f"pending queue at bound {self.cfg.queue_bound}")
            h = QueryHandle(qid=self._next_qid, t_submit=now)
            self._next_qid += 1
            self.scheduler.push(
                PendingQuery(
                    handle=h,
                    vector=np.asarray(vector, dtype=np.float32),
                    filt=filt,
                    t_submit=now,
                )
            )
        tracer = get_tracer()
        if tracer.enabled:  # hottest path: skip even the no-op kwargs build
            tracer.instant("submit", qid=h.qid)
        return h

    def insert(
        self,
        vectors: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> np.ndarray:
        """Add tuples to the live DB; visible to the next flush. Returns ids.

        With a WAL attached the insert is committed durably BEFORE the ids
        are returned — an acknowledged insert survives a crash (recovery
        replays the WAL tail into a fresh delta store, same ids). Ordering:
        validate → WAL stage → group fsync → apply, so a rejected insert is
        never logged and a failed stage never leaves unlogged rows visible.
        Concurrent writers share one fsync (WAL group commit): each stages
        its record under the state lock — fixing seq order = id order, the
        invariant recovery's replay asserts — then blocks on
        ``wal.sync_upto`` outside it, and applies in ticket (= seq) order.
        """
        with get_tracer().span("service.insert"):
            if self.wal is None:
                with self._lock:
                    slab, ids = self.delta.prepare_insert(vectors, columns, null_masks)
                    self.delta.commit_insert(slab, ids)
                return ids
            with self._lock:
                slab, ids = self.delta.prepare_insert(vectors, columns, null_masks)
                seq = self.wal.stage_insert(slab.vectors, ids, columns, null_masks)
                ticket = self._commit_tail
                self._commit_tail += 1
            try:
                self.wal.sync_upto(seq)
            finally:
                # apply even when the fsync failed: the frame is in the log (a
                # replay would re-apply it) and later tickets' id-ordered
                # commits depend on this slab's rows being in place; the
                # caller still sees the durability error because the
                # exception propagates
                self._commit_in_order(
                    ticket, seq, lambda: self.delta.commit_insert(slab, ids)
                )
            return ids

    def delete(self, ids: Iterable[int]) -> int:
        """Tombstone tuples by global id; visible to the next flush.

        With a WAL attached the delete is committed durably BEFORE it is
        acknowledged and before any tombstone is applied (same contract as
        ``insert``; replay is idempotent). Deletes join the same group-commit
        ticket queue as inserts, so tombstones apply in WAL seq order — the
        order a recovery replay reproduces.
        """
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with get_tracer().span("service.delete"):
            if self.wal is None:
                with self._lock:
                    return self._delete_locked(ids)
            with self._lock:
                seq = self.wal.stage_delete(ids)
                ticket = self._commit_tail
                self._commit_tail += 1
            try:
                self.wal.sync_upto(seq)
            finally:
                n = self._commit_in_order(
                    ticket, seq, lambda: self._delete_locked(ids)
                )
            return n

    def _commit_in_order(self, ticket: int, seq: int, apply_fn):
        """Run a staged write's apply step when its ticket comes up.

        Tickets are taken in the same critical section that staged the WAL
        record, so ticket order == seq order — applying in ticket order keeps
        the live state's mutation order identical to what a replay of the log
        would produce (and keeps ``commit_insert``'s id-order contract).
        """
        with self._commit_cv:
            while self._commit_head != ticket:
                self._commit_cv.wait()
            try:
                return apply_fn()
            finally:
                self._commit_head += 1
                self._applied_seq = max(self._applied_seq, seq)
                self._commit_cv.notify_all()

    def _delete_locked(self, ids: Iterable[int]) -> int:
        """Apply tombstones without WAL commit (shared with WAL replay)."""
        n = 0
        for ext_id in np.atleast_1d(np.asarray(ids, dtype=np.int64)):
            ext_id = int(ext_id)
            if 0 <= ext_id < len(self._live):
                if self._live[ext_id]:
                    self._live[ext_id] = False
                    n += 1
            elif self.delta.delete(ext_id):
                n += 1
        return n

    @property
    def n_live(self) -> int:
        with self._lock:
            return int(self._live.sum()) + self.delta.n_live

    def live_ids(self) -> np.ndarray:
        """Global ids of all live tuples (indexed + delta), ascending."""
        with self._lock:
            base = np.nonzero(self._live)[0].astype(np.int64)
            _, delta_live = self.delta.snapshot()
            extra = self.delta.first_id + np.nonzero(delta_live)[0].astype(np.int64)
        return np.concatenate([base, extra])

    # --------------------------------------------------------------- refresh

    def refresh(self) -> int:
        """Fold the delta buffer into the main index partitions.

        Incremental: qd-tree leaf routing for the new rows, per-partition
        IVF append, arena update reusing unchanged partitions — no
        Algorithm-1/k-means re-run. Invalidates the Router bitmap cache
        (bitmaps are [db.n] and the DB grew). Tombstoned delta rows fold in
        as dead rows so global ids stay dense. Returns #rows folded.

        Takes the flush lock first (same order as ``_flush``): the fold
        mutates index structures an in-flight flush would be reading outside
        the state lock.

        With a WAL attached, a fold also seals the current WAL segment
        (``rotate``) — folded records are covered by the next snapshot, so
        compaction can prune whole sealed segments.
        """
        with self._flush_lock, get_tracer().span("service.refresh"):
            return self._refresh_locked()

    def _refresh_locked(self) -> int:
        """The fold body; caller holds the flush lock (see ``Compactor``)."""
        with self._lock:
            delta_db, delta_live = self.delta.snapshot()
            n = 0
            if delta_db is not None:
                self.index.extend(delta_db)
                self._live = np.concatenate([self._live, delta_live])
                self.delta.clear(first_id=self.index.db.n)
                n = delta_db.n
            if self.wal is not None:
                # with the delta (now) empty, EVERY applied record's effect
                # lives in (index, _live): inserts were just folded, deletes
                # tombstoned _live at commit time — so a delete-only interval
                # also advances the folded seq and seals its segment (or the
                # WAL could never be pruned under delete-heavy traffic).
                # _applied_seq, not wal.last_seq: a concurrent group-commit
                # writer may have STAGED a record it hasn't applied yet, and
                # claiming that seq as folded would drop it from recovery
                self._wal_folded_seq = self._applied_seq
                self.wal.rotate()
            return n

    # ---------------------------------------------------------- serving loop

    def tick(self, now: Optional[float] = None) -> int:
        """Flush once if a trigger fired; returns #queries answered."""
        with self._lock:
            if not self.scheduler.ready(now):
                return 0
        return self._flush(ready_only=True, now=now)

    def flush(self) -> int:
        """Force a flush of whatever is pending (ignores triggers).

        No empty-queue fast path on purpose: ``_flush`` serializes on the
        flush lock, so even a 0 return waits out any in-flight flush —
        keeping ``drain()``'s contract that returning means every previously
        submitted query has been answered, not merely taken.
        """
        return self._flush()

    def drain(self) -> int:
        """Flush until the queue is empty; returns #queries answered."""
        total = 0
        while True:
            n = self.flush()
            if n == 0:
                return total
            total += n

    def _flush(self, ready_only: bool = False, now: Optional[float] = None) -> int:
        """One micro-batch through engine + delta + merge — lock-free pipeline.

        Three phases: (1) snapshot the batch, live mask, and delta view under
        the state lock; (2) dispatch the whole kernel pipeline OUTSIDE it, so
        concurrent ``submit``/``insert``/``delete`` queue into the next
        micro-batch instead of blocking for the flush duration; (3) re-acquire
        to fulfill handles and record telemetry. Flushes serialize among
        themselves (and against ``refresh``) on the flush lock; ``ready_only``
        (the ``tick`` path) re-checks the trigger once inside it, so a caller
        that queued behind another flush doesn't prematurely flush queries
        that arrived meanwhile and are still inside the batching window.
        """
        tracer = get_tracer()
        with self._flush_lock:
            with self._lock:
                if ready_only and not self.scheduler.ready(now):
                    return 0
                batch = self.scheduler.take()
                if not batch:
                    return 0
                depth = len(self.scheduler)
                wl, n_real = self.scheduler.build_workload(batch, self.cfg.k)
                live = self._live.copy()
                delta_view = self.delta.view()
                delta_rows = self.delta.n
            if tracer.enabled:
                # retroactive per-query queue-wait spans: t_submit and the
                # tracer share the perf_counter clock, so submit→flush waits
                # land exactly on the timeline even though they are only
                # known now
                t_start = time.perf_counter()
                for pq in batch:
                    tracer.add_span(
                        "queue.wait", pq.t_submit, t_start, qid=pq.handle.qid
                    )
                tracer.counter("queue.depth", depth)
            before = kops.dispatch_stats().snapshot()
            t0 = time.perf_counter()
            with tracer.span("flush", size=n_real, depth=depth):
                ids, scores, res = self._answer(wl, live, delta_view)
            dt = time.perf_counter() - t0
            delta_stats = kops.dispatch_stats().delta_since(before)
            t_done = time.perf_counter()
            with self._lock:
                lats = []
                with tracer.span("flush.fulfill", size=n_real):
                    for i, pq in enumerate(batch):
                        pq.handle._fulfill(ids[i], scores[i], t_done)
                        lats.append(t_done - pq.t_submit)
                self.telemetry.record_flush(
                    size=n_real,
                    queue_depth=depth,
                    knn_dispatches=delta_stats.knn_calls,
                    merge_dispatches=delta_stats.merge_calls,
                    seconds=dt,
                    latencies=lats,
                    peak_candidate_bytes=res.peak_candidate_bytes,
                    lut_bytes=res.lut_bytes,
                )
            self._observe_flush(batch, ids, lats, res, delta_rows)
        return n_real

    def _observe_flush(self, batch, ids, lats, res, delta_rows: int) -> None:
        """Feed the metrics registry and drift monitor from one flush (runs
        outside the state lock — every input is a flush-local snapshot)."""
        reg = get_registry()
        qw = reg.histogram("service.queue_wait_s")
        for w in lats:
            qw.observe(w)
        reg.histogram("service.flush_size").observe(len(batch))
        reg.histogram("engine.bytes_scanned").observe(res.bytes_scanned)
        reg.histogram("engine.peak_candidate_bytes").observe(res.peak_candidate_bytes)
        self.drift.observe_queries([pq.filt for pq in batch])
        if res.part_probes:
            self.drift.observe_probes(res.part_probes)
        self.drift.observe_delta(delta_rows)
        for i, pq in enumerate(batch):
            self.drift.maybe_sample(pq.vector, pq.filt, ids[i])

    def drift_report(
        self, *, probe_recall: bool = False, k: Optional[int] = None
    ) -> DriftReport:
        """Current workload-drift reading (see obs.drift). ``probe_recall``
        additionally replays the answered-query reservoir against a
        brute-force scan of the live DB — exact but O(n), so keep it off
        latency-sensitive paths."""
        return self.drift.report(self, probe_recall=probe_recall, k=k)

    def _answer(self, wl: Workload, live: np.ndarray, delta_view):
        """(ids i64 [m, k], scores f32 [m, k], SearchResult): engine + delta.

        Operates on the flush's snapshots (live mask copy, immutable delta
        view) so it can run outside the state lock. The engine's
        ``SearchResult`` rides along for the flush's telemetry (candidate
        buffer peak, LUT bytes).
        """
        tracer = get_tracer()
        with tracer.span("engine.search", m=wl.m):
            res = self.index.search(
                wl,
                nprobe=self.cfg.nprobe,
                batch_vec=self.cfg.batch_vec,
                live_mask=live,
            )
        with tracer.span("delta.scan", rows=len(delta_view.live)):
            delta_out = delta_view.scan(
                wl,
                stats=ScanStats(),
                pq_threshold=self.cfg.delta_pq_threshold,
                refine_factor=self.index.cfg.plan.refine_factor,
            )
        if delta_out is None:
            return res.ids, res.scores, res
        ds, di = delta_out
        cat_s = np.concatenate([res.scores, ds], axis=1)
        cat_i = np.concatenate([res.ids, di], axis=1)
        with tracer.span("delta.merge", m=wl.m):
            ms, mi = kops.merge_topk(jnp.asarray(cat_s), jnp.asarray(cat_i), wl.k)
            ms, mi = fence(ms, mi)
        return np.asarray(mi, dtype=np.int64), np.asarray(ms, dtype=np.float32), res

    # ----------------------------------------------------- background driver

    def start(self, poll_s: Optional[float] = None) -> None:
        """Run the flush loop on a background scheduler thread."""
        assert self._thread is None, "service already running"
        poll = self.cfg.deadline_s / 4 if poll_s is None else poll_s
        poll = max(1e-4, float(poll))
        self._stop_flag.clear()

        def loop() -> None:
            while not self._stop_flag.is_set():
                if self.tick() == 0:
                    time.sleep(poll)

        self._thread = threading.Thread(target=loop, name="hqi-service", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread (optionally answering remaining queries)."""
        if self._thread is None:
            return
        self._stop_flag.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()

    # ------------------------------------------------------------ inspection

    def snapshot_db(self) -> VectorDatabase:
        """The live DB as a standalone VectorDatabase (offline-parity tool):
        indexed rows + delta rows, minus tombstones, in global-id order."""
        with self._lock:
            delta_db, _ = self.delta.snapshot()
            full = (
                self.index.db
                if delta_db is None
                else VectorDatabase.concat(self.index.db, delta_db)
            )
            return full.take(self.live_ids())
