"""HQIService — the online serving facade over the PR-1 plan/execute engine.

Data plane, per flush (see scheduler.py for when a flush fires):

    submit() ─┐
    submit() ─┼─▶ MicroBatchScheduler ──▶ synthetic Workload
    submit() ─┘                               │
                                  HQIIndex.search(batch_vec="auto",
                                                  live_mask=tombstones)
                                               │
                    DeltaStore.scan (live inserts, one fused dispatch)
                                               │
                                  kernels.ops.merge_topk  ──▶ QueryHandle

Control plane: ``insert``/``delete`` are visible to the very next flush
(delta scan + tombstone mask); ``refresh()`` folds the delta into the main
index partitions (``HQIIndex.extend``) and invalidates the Router bitmap
cache and arena — never a full rebuild. Admission control bounds the pending
queue; ``submit`` raises ``QueueFull`` beyond ``ServiceConfig.queue_bound``.

The service can be driven synchronously (``tick``/``drain`` — what the
benchmarks and tests do) or by a background thread (``start``/``stop``) with
callers blocking on ``QueryHandle.wait()``; kernel-dispatch accounting stays
correct either way because ``DispatchStats`` is lock-protected.

Flushes are lock-free for writers: ``_flush`` snapshots (batch, live mask,
delta view) under the state lock, dispatches the kernel pipeline outside it,
and re-acquires only to fulfill handles — ``submit``/``insert``/``delete``
during a slow flush queue into the next micro-batch instead of blocking
(tests/test_service.py has the threaded regression). When the index was
built with ``HQIConfig.mesh`` set, every flush's engine work runs on the
device mesh through the sharded executor, transparently.

Durability (repro.store): with a ``WriteAheadLog`` attached (``wal=``, wired
by ``store.recovery.open_service``/``init_store``), every ``insert``/
``delete`` commits a WAL record *before* acknowledging, ``refresh()`` seals
the current WAL segment at the fold boundary, and ``store.compact.Compactor``
periodically folds + snapshots so restart cost stays O(mmap + WAL tail).
Without a WAL the service is purely in-memory, exactly as before.

Self-healing (repro.fault): a flush-pipeline crash is contained per flush —
that batch's handles fail with a structured ``QueryError`` and subsequent
flushes keep serving (no stranded ``QueryHandle``, no dead scheduler
thread). Per-query deadlines (``query_deadline_s`` / ``submit(deadline_s=)``)
are enforced at admission and at fulfill, failing expired queries with
``DeadlineExceeded`` instead of spending kernel time on answers nobody is
waiting for. A poisoned WAL or a diverged delta apply quarantines the WRITE
path (``ServiceReadOnly``, fail-fast) while reads keep serving. Under
overload (queue depth or flush latency past the configured thresholds) the
service sheds exactness for liveness — flushes degrade to ``scan_mode="pq"``
at ``degraded_refine_factor`` when the index carries a codebook — and
recovers automatically once pressure drops; degraded answers are flagged on
their handles and surfaced in telemetry. ``health()`` is the structured
ok/degraded/read-only status the future router tier consumes.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Iterable, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..core.hqi import HQIIndex
from ..core.ivf import ScanStats
from ..core.types import SETCAT, VectorDatabase, Workload
from ..fault.failpoints import failpoint
from ..kernels import ops as kops
from ..obs.drift import DriftConfig, DriftMonitor, DriftReport
from ..obs.metrics import get_registry
from ..obs.trace import fence, get_tracer, set_thread_name
from .delta import DeltaStore
from .errors import (  # noqa: F401 — QueueFull re-exported for compatibility
    DeadlineExceeded,
    QueryError,
    QueueFull,
    ResultPending,
    ServiceReadOnly,
)
from .scheduler import MicroBatchScheduler, PendingQuery
from .telemetry import ServiceTelemetry


@dataclasses.dataclass
class ServiceConfig:
    k: int = 10
    nprobe: Union[int, Dict[int, int]] = 8
    batch_vec: Union[bool, str] = "auto"  # the §6.5 adaptive executor
    max_batch: int = 256  # size flush trigger
    deadline_s: float = 0.005  # latency flush trigger (oldest query's wait)
    queue_bound: int = 8192  # admission control: max pending queries
    pad_pow2: bool = False  # pad flushes to power-of-two batch slots (TPU)
    # delta-store compression: once the live delta buffer exceeds this many
    # rows (and the index has a PQ codebook), flush scans encode the delta
    # through the ADC path with exact f32 re-rank of the survivors instead
    # of brute-forcing f32 rows; None disables. Buffers at or under the
    # threshold always scan exact.
    delta_pq_threshold: Optional[int] = 4096
    # workload-drift monitor (obs.drift): sliding window of answered-query
    # templates and reservoir size for the live recall probe
    drift_window: int = 4096
    recall_reservoir: int = 64
    # per-query serving deadline (seconds from submit; None = no deadline).
    # Overridable per call via submit(deadline_s=); enforced at admission
    # (an already-lapsed deadline is rejected) and at flush/fulfill (expired
    # queries fail with DeadlineExceeded instead of burning kernel time)
    query_deadline_s: Optional[float] = None
    # overload degradation: when the post-take queue depth or the flush wall
    # time crosses a threshold, flushes shed to scan_mode="pq" at
    # degraded_refine_factor (needs an index codebook — HQIIndex.attach_pq);
    # recovery is automatic once BOTH pressures drop below threshold ×
    # overload_recover_frac (hysteresis, so the mode doesn't flap)
    overload_queue_depth: Optional[int] = None
    overload_flush_s: Optional[float] = None
    degraded_refine_factor: int = 1
    overload_recover_frac: float = 0.5


@dataclasses.dataclass
class QueryHandle:
    """Caller-side future for one submitted query.

    Every handle *terminates*: fulfilled with (ids, scores), or failed with a
    typed error — ``QueryError`` (the carrying flush crashed; contained) or
    ``DeadlineExceeded`` (the per-query deadline lapsed). ``degraded`` marks
    answers produced by an overload-shed (PQ-approximate) flush, so callers
    comparing against exact references know to exclude them.
    """

    qid: int
    t_submit: float
    ids: Optional[np.ndarray] = None  # i64 [k] once done (-1 padding)
    scores: Optional[np.ndarray] = None  # f32 [k] best-first
    t_done: float = 0.0
    error: Optional[BaseException] = None
    degraded: bool = False
    _event: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False
    )

    @property
    def done(self) -> bool:
        """Terminated — fulfilled OR failed. Check ``ok`` to distinguish."""
        return self._event.is_set()

    @property
    def ok(self) -> bool:
        return self._event.is_set() and self.error is None

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self._event.wait(timeout)

    def result(
        self, timeout: Optional[float] = None
    ) -> Tuple[np.ndarray, np.ndarray]:
        """(ids, scores) of a fulfilled query.

        ``timeout=None`` is the non-blocking accessor: raises ``ResultPending``
        if the query has not terminated yet. With a ``timeout``, blocks up to
        that many seconds and raises ``DeadlineExceeded`` on expiry. A handle
        that terminated in failure re-raises its stored typed error
        (``QueryError`` / ``DeadlineExceeded``).
        """
        if not self._event.is_set():
            if timeout is None:
                raise ResultPending(f"query {self.qid} not answered yet")
            if not self._event.wait(timeout):
                raise DeadlineExceeded(
                    f"result() timed out after {timeout}s for query {self.qid}",
                    qid=self.qid,
                )
        if self.error is not None:
            raise self.error
        return self.ids, self.scores

    @property
    def latency_s(self) -> float:
        return (self.t_done - self.t_submit) if self.done else float("nan")

    def _fulfill(
        self,
        ids: np.ndarray,
        scores: np.ndarray,
        t_done: float,
        degraded: bool = False,
    ) -> None:
        self.ids = ids
        self.scores = scores
        self.t_done = t_done
        self.degraded = degraded
        self._event.set()

    def _fail(self, error: BaseException, t_done: float) -> None:
        self.error = error
        self.t_done = t_done
        self._event.set()


@dataclasses.dataclass
class ServiceHealth:
    """Structured serving status — what ``HQIService.health()`` returns and
    what the metrics registry's ``health`` source publishes.

    ``status`` is the one-word rollup a router shards traffic on:
    ``"ok"`` (full exact serving), ``"degraded"`` (answering, but overload-shed
    to approximate scans), ``"read-only"`` (write path quarantined — poisoned
    WAL or diverged apply — reads still serving).
    """

    status: str
    queue_depth: int
    degraded: bool
    read_only: bool
    write_error: Optional[str]
    wal_synced_seq: Optional[int]
    applied_seq: int
    last_flush_age_s: Optional[float]
    last_flush_s: float
    flush_failures: int
    deadline_expired: int
    compactor_failures: int
    compactor_error: Optional[str]
    armed_failpoints: Tuple[str, ...] = ()
    # index-evolution (tuner) status — defaulted so older callers that build
    # ServiceHealth positionally keep working
    index_swaps: int = 0
    tuner_failures: int = 0
    tuner_error: Optional[str] = None

    def as_dict(self) -> Dict[str, object]:
        d = dataclasses.asdict(self)
        d["armed_failpoints"] = list(self.armed_failpoints)
        return d


class HQIService:
    """Streaming HVQ service: micro-batched reads, immediately-visible writes."""

    def __init__(
        self,
        index: HQIIndex,
        cfg: Optional[ServiceConfig] = None,
        wal=None,  # store.wal.WriteAheadLog; None = in-memory only
    ) -> None:
        self.index = index
        self.cfg = ServiceConfig() if cfg is None else cfg
        self.wal = wal
        # last WAL record whose effects live in (index, _live) rather than
        # the delta buffer — what a snapshot of this service covers
        # (store.compact reads it; store.recovery seeds it after a replay)
        self._wal_folded_seq = 0 if wal is None else wal.last_seq
        # group commit bookkeeping: writers stage their WAL record under the
        # state lock (fixing seq order = id order), share one fsync outside
        # it, then apply in ticket order — _applied_seq is the highest seq
        # whose effects are actually in (delta, _live), which is what a fold
        # may claim as covered (wal.last_seq could include records a
        # concurrent writer has staged but not yet applied)
        self._commit_head = 0
        self._commit_tail = 0
        self._applied_seq = 0 if wal is None else wal.last_seq
        self.scheduler = MicroBatchScheduler(
            max_batch=self.cfg.max_batch,
            deadline_s=self.cfg.deadline_s,
            pad_pow2=self.cfg.pad_pow2,
        )
        # hand the delta the codebook only when compressed delta scans can
        # actually fire — otherwise inserts would pay encode_pq for codes
        # the scan path never reads
        self.delta = DeltaStore(
            index.db,
            first_id=index.db.n,
            pq=index.pq if self.cfg.delta_pq_threshold is not None else None,
        )
        self.telemetry = ServiceTelemetry()
        # workload observer feeding the future hot-swap tuner; fed by _flush,
        # read via drift_report()
        self.drift = DriftMonitor(
            DriftConfig(
                window=self.cfg.drift_window, reservoir=self.cfg.recall_reservoir
            )
        )
        # fold this service's telemetry into the process metrics registry
        # (latest service wins the "service" slot — one serving process is
        # the deployment unit)
        get_registry().attach_source("service", self.telemetry.summary)
        get_registry().attach_source("health", lambda: self.health().as_dict())
        self._live = np.ones(index.db.n, dtype=bool)  # tombstones over indexed rows
        # self-healing state (repro.fault). _write_poisoned: a delta apply
        # diverged from what the WAL logged — permanent in-process write
        # quarantine (restart + replay heals it). _degraded: overload shed to
        # approximate scans. _last_flush_* feed the overload detector + health
        self._write_poisoned: Optional[BaseException] = None
        self._degraded = False
        self._last_flush_s = 0.0
        self._last_flush_done: Optional[float] = None
        self._compactor = None  # back-ref set by store.compact.Compactor
        self._tuner = None  # back-ref set by tuner.Tuner (health/metrics)
        self._swaps = 0  # completed blue/green index swaps (swap_index)
        # per-FILTER nprobe overrides installed by the tuner; translated to
        # per-template dicts flush-locally in _answer (template indices are
        # interned per batch, so an index-keyed dict can't persist)
        self._nprobe_by_filter: Optional[Dict[tuple, int]] = None
        # state lock for scheduler + delta + live-mask: writers and the flush
        # snapshot take it BRIEFLY — kernel dispatch happens outside it, so
        # submit()/insert()/delete() never block for a flush's duration
        self._lock = threading.RLock()
        # writers park here until their commit ticket comes up (group commit)
        self._commit_cv = threading.Condition(self._lock)
        # flush lock serializes the out-of-lock pipeline sections: flushes
        # against each other (single logical consumer) and against refresh(),
        # which swaps index structures the in-flight search reads
        self._flush_lock = threading.Lock()
        self._next_qid = 0
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()

    # ------------------------------------------------------------ data plane

    def submit(
        self,
        vector: np.ndarray,
        filt: tuple = (),
        *,
        deadline_s: Optional[float] = None,
    ) -> QueryHandle:
        """Enqueue one hybrid query; answered at the next flush (tick/run).

        ``deadline_s`` (or ``ServiceConfig.query_deadline_s`` when omitted)
        bounds submit→answer: an already-lapsed deadline is rejected here
        (``DeadlineExceeded`` — admission control, nothing queued), and a
        query whose deadline expires before its flush fulfills it is failed
        with ``DeadlineExceeded`` on its handle instead of consuming kernel
        time.
        """
        now = time.perf_counter()
        dl = self.cfg.query_deadline_s if deadline_s is None else deadline_s
        if dl is not None and dl <= 0:
            self.telemetry.record_deadline_expired()
            raise DeadlineExceeded(f"deadline {dl}s lapsed at admission", qid=-1)
        with self._lock:
            if len(self.scheduler) >= self.cfg.queue_bound:
                self.telemetry.record_rejected()
                raise QueueFull(f"pending queue at bound {self.cfg.queue_bound}")
            h = QueryHandle(qid=self._next_qid, t_submit=now)
            self._next_qid += 1
            self.scheduler.push(
                PendingQuery(
                    handle=h,
                    vector=np.asarray(vector, dtype=np.float32),
                    filt=filt,
                    t_submit=now,
                    t_deadline=None if dl is None else now + dl,
                )
            )
        tracer = get_tracer()
        if tracer.enabled:  # hottest path: skip even the no-op kwargs build
            tracer.instant("submit", qid=h.qid)
        return h

    def insert(
        self,
        vectors: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> np.ndarray:
        """Add tuples to the live DB; visible to the next flush. Returns ids.

        With a WAL attached the insert is committed durably BEFORE the ids
        are returned — an acknowledged insert survives a crash (recovery
        replays the WAL tail into a fresh delta store, same ids). Ordering:
        validate → WAL stage → group fsync → apply, so a rejected insert is
        never logged and a failed stage never leaves unlogged rows visible.
        Concurrent writers share one fsync (WAL group commit): each stages
        its record under the state lock — fixing seq order = id order, the
        invariant recovery's replay asserts — then blocks on
        ``wal.sync_upto`` outside it, and applies in ticket (= seq) order.
        """
        with get_tracer().span("service.insert"):
            self._check_writable()
            if self.wal is None:
                with self._lock:
                    slab, ids = self.delta.prepare_insert(vectors, columns, null_masks)
                    try:
                        self.delta.commit_insert(slab, ids)
                    except BaseException:
                        # nothing logged, nothing applied — release the id
                        # reservation so the next insert gets these ids
                        self.delta.abort_insert(ids)
                        raise
                return ids
            with self._lock:
                slab, ids = self.delta.prepare_insert(vectors, columns, null_masks)
                try:
                    seq = self.wal.stage_insert(slab.vectors, ids, columns, null_masks)
                except BaseException:
                    # the frame never reached the log; releasing the
                    # reservation is safe because prepare+stage share this
                    # critical section — no later writer saw these ids
                    self.delta.abort_insert(ids)
                    raise
                ticket = self._commit_tail
                self._commit_tail += 1
            try:
                self.wal.sync_upto(seq)
            finally:
                # apply even when the fsync failed: the frame is in the log (a
                # replay would re-apply it) and later tickets' id-ordered
                # commits depend on this slab's rows being in place; the
                # caller still sees the durability error because the
                # exception propagates
                self._commit_in_order(
                    ticket, seq, lambda: self.delta.commit_insert(slab, ids)
                )
            return ids

    def delete(self, ids: Iterable[int]) -> int:
        """Tombstone tuples by global id; visible to the next flush.

        With a WAL attached the delete is committed durably BEFORE it is
        acknowledged and before any tombstone is applied (same contract as
        ``insert``; replay is idempotent). Deletes join the same group-commit
        ticket queue as inserts, so tombstones apply in WAL seq order — the
        order a recovery replay reproduces.
        """
        ids = np.atleast_1d(np.asarray(ids, dtype=np.int64))
        with get_tracer().span("service.delete"):
            self._check_writable()
            if self.wal is None:
                with self._lock:
                    return self._delete_locked(ids)
            with self._lock:
                seq = self.wal.stage_delete(ids)
                ticket = self._commit_tail
                self._commit_tail += 1
            try:
                self.wal.sync_upto(seq)
            finally:
                n = self._commit_in_order(
                    ticket, seq, lambda: self._delete_locked(ids)
                )
            return n

    def _commit_in_order(self, ticket: int, seq: int, apply_fn):
        """Run a staged write's apply step when its ticket comes up.

        Tickets are taken in the same critical section that staged the WAL
        record, so ticket order == seq order — applying in ticket order keeps
        the live state's mutation order identical to what a replay of the log
        would produce (and keeps ``commit_insert``'s id-order contract).
        """
        with self._commit_cv:
            while self._commit_head != ticket:
                self._commit_cv.wait()
            try:
                out = apply_fn()
            except BaseException as e:
                # the record IS in the log but its effect is NOT in the live
                # state — and the ids it reserved cannot be released (a replay
                # would reproduce them). In-memory writes can never be
                # reconciled with the log again: quarantine the write path
                # (reads keep serving; restart + WAL replay heals). Crucially
                # _applied_seq must NOT advance past this record — a fold
                # claiming it as covered would drop it from recovery
                self._write_poisoned = e
                raise
            else:
                self._applied_seq = max(self._applied_seq, seq)
                return out
            finally:
                self._commit_head += 1
                self._commit_cv.notify_all()

    def _delete_locked(self, ids: Iterable[int]) -> int:
        """Apply tombstones without WAL commit (shared with WAL replay)."""
        n = 0
        for ext_id in np.atleast_1d(np.asarray(ids, dtype=np.int64)):
            ext_id = int(ext_id)
            if 0 <= ext_id < len(self._live):
                if self._live[ext_id]:
                    self._live[ext_id] = False
                    n += 1
            elif self.delta.delete(ext_id):
                n += 1
        return n

    def _check_writable(self) -> None:
        """Fail-fast gate on the write path (reads never come through here).

        Two quarantine flavors: a poisoned WAL (durability I/O failed past
        its retry budget — ``clear_poison()`` after fixing the disk heals it)
        and a diverged delta apply (in-process state can no longer be
        reconciled with the log — only restart + replay heals).
        """
        if self._write_poisoned is not None:
            raise ServiceReadOnly(
                "write path quarantined: delta apply diverged from WAL",
                cause=self._write_poisoned,
            )
        if self.wal is not None and getattr(self.wal, "poisoned", None) is not None:
            raise ServiceReadOnly(
                "write path quarantined: WAL poisoned", cause=self.wal.poisoned
            )

    def health(self) -> ServiceHealth:
        """Structured ok/degraded/read-only serving status (see ServiceHealth)."""
        from ..fault import failpoints as _fp

        with self._lock:
            depth = len(self.scheduler)
            degraded = self._degraded
            apply_poison = self._write_poisoned
            applied_seq = self._applied_seq
            last_done = self._last_flush_done
            last_s = self._last_flush_s
            swaps = self._swaps
        wal_poison = (
            getattr(self.wal, "poisoned", None) if self.wal is not None else None
        )
        write_error = apply_poison if apply_poison is not None else wal_poison
        read_only = write_error is not None
        comp = self._compactor
        tun = self._tuner
        tsum = self.telemetry.summary()
        return ServiceHealth(
            status=("read-only" if read_only else "degraded" if degraded else "ok"),
            queue_depth=depth,
            degraded=degraded,
            read_only=read_only,
            write_error=None if write_error is None else repr(write_error),
            wal_synced_seq=None if self.wal is None else self.wal.synced_seq,
            applied_seq=applied_seq,
            last_flush_age_s=(
                None if last_done is None else time.perf_counter() - last_done
            ),
            last_flush_s=last_s,
            flush_failures=int(tsum["flush_failures"]),
            deadline_expired=int(tsum["deadline_expired"]),
            compactor_failures=(
                0 if comp is None else int(comp.consecutive_failures)
            ),
            compactor_error=(
                None
                if comp is None or comp.last_error is None
                else repr(comp.last_error)
            ),
            armed_failpoints=tuple(sorted(_fp.list_armed())),
            index_swaps=swaps,
            tuner_failures=(0 if tun is None else int(tun.consecutive_failures)),
            tuner_error=(
                None
                if tun is None or tun.last_error is None
                else repr(tun.last_error)
            ),
        )

    @property
    def n_live(self) -> int:
        with self._lock:
            return int(self._live.sum()) + self.delta.n_live

    def live_ids(self) -> np.ndarray:
        """Global ids of all live tuples (indexed + delta), ascending."""
        with self._lock:
            base = np.nonzero(self._live)[0].astype(np.int64)
            _, delta_live = self.delta.snapshot()
            extra = self.delta.first_id + np.nonzero(delta_live)[0].astype(np.int64)
        return np.concatenate([base, extra])

    # --------------------------------------------------------------- refresh

    def refresh(self) -> int:
        """Fold the delta buffer into the main index partitions.

        Incremental: qd-tree leaf routing for the new rows, per-partition
        IVF append, arena update reusing unchanged partitions — no
        Algorithm-1/k-means re-run. Invalidates the Router bitmap cache
        (bitmaps are [db.n] and the DB grew). Tombstoned delta rows fold in
        as dead rows so global ids stay dense. Returns #rows folded.

        Takes the flush lock first (same order as ``_flush``): the fold
        mutates index structures an in-flight flush would be reading outside
        the state lock.

        With a WAL attached, a fold also seals the current WAL segment
        (``rotate``) — folded records are covered by the next snapshot, so
        compaction can prune whole sealed segments.
        """
        with self._flush_lock, get_tracer().span("service.refresh"):
            return self._refresh_locked()

    def _refresh_locked(self) -> int:
        """The fold body; caller holds the flush lock (see ``Compactor``)."""
        with self._lock:
            delta_db, delta_live = self.delta.snapshot()
            n = 0
            if delta_db is not None:
                self.index.extend(delta_db)
                self._live = np.concatenate([self._live, delta_live])
                self.delta.clear(first_id=self.index.db.n)
                n = delta_db.n
            if self.wal is not None:
                # with the delta (now) empty, EVERY applied record's effect
                # lives in (index, _live): inserts were just folded, deletes
                # tombstoned _live at commit time — so a delete-only interval
                # also advances the folded seq and seals its segment (or the
                # WAL could never be pruned under delete-heavy traffic).
                # _applied_seq, not wal.last_seq: a concurrent group-commit
                # writer may have STAGED a record it hasn't applied yet, and
                # claiming that seq as folded would drop it from recovery
                self._wal_folded_seq = self._applied_seq
                self.wal.rotate()
            return n

    # ------------------------------------------------------------- hot swap

    def set_nprobe_by_filter(self, mapping: Optional[Dict[tuple, int]]) -> None:
        """Install (or clear, with None) per-FILTER nprobe overrides.

        ``ServiceConfig.nprobe`` dicts are keyed by template *index*, which
        is flush-local (the scheduler interns templates per micro-batch), so
        a tuner's per-template tuning can't persist in that form. The tuner
        hands over a dict keyed by the filter tuples themselves; ``_answer``
        translates it per flush. Filters the tuning never saw fall back to
        the config default.
        """
        with self._lock:
            self._nprobe_by_filter = None if mapping is None else dict(mapping)

    def swap_index(
        self, index: HQIIndex, live: np.ndarray, covered_seq: int
    ) -> Tuple[HQIIndex, np.ndarray, int, int]:
        """Blue/green swap: replace the serving index with one built off to
        the side, losing no acknowledged write and dropping no query.

        ``index``/``live`` must cover the SAME global-id prefix the serving
        state had at capture time — ids are row positions, so the builder
        rebuilds over the full captured DB, dead rows included, and nothing
        renumbers — and ``covered_seq`` is the highest WAL seq whose effect
        the build includes. The tail (writes acknowledged after capture) is
        re-established on the new index before it serves: replayed from the
        WAL past ``covered_seq`` when one is attached, else adopted from the
        displaced in-memory view (id-ordered, so the rows past the new
        index's count are exactly the post-capture inserts).

        Fault containment: the ``tuner.swap`` failpoint, the group-commit
        drain, and the tail replay all happen BEFORE any serving state is
        touched — a swap that faults anywhere leaves the old index serving
        untouched. In-flight flushes finished under the flush lock we hold;
        queued queries simply answer on the new index at their next flush.

        Returns ``(old_index, old_live, old_covered_seq, n_tail_replayed)``
        — the first three are exactly the arguments a later ``swap_index``
        call needs for instant rollback.
        """
        with self._flush_lock, get_tracer().span("service.swap"):
            failpoint("tuner.swap")
            with self._commit_cv:
                # Drain the group-commit pipeline: a writer that staged its
                # WAL record but hasn't applied yet would otherwise apply
                # into the delta we're about to retire — and the replay
                # below reads the WAL file, which already holds its frame,
                # so the write would land twice.
                while self._commit_head != self._commit_tail:
                    self._commit_cv.wait()
                new_live = np.array(live, dtype=bool, copy=True)
                delta = DeltaStore(
                    index.db,
                    first_id=index.db.n,
                    pq=(
                        index.pq
                        if self.cfg.delta_pq_threshold is not None
                        else None
                    ),
                )
                if self.wal is not None:
                    replayed = self._replay_tail(delta, new_live, covered_seq)
                else:
                    replayed = self._adopt_tail(delta, new_live)
                # ---- point of no return: mutate serving state atomically
                old_index, old_live = self.index, self._live
                old_seq = self._wal_folded_seq
                self.index = index
                self._live = new_live
                self.delta = delta
                if self.wal is not None:
                    self._wal_folded_seq = covered_seq
                # stale router bitmaps / arena views from a previous serving
                # stint (rollback) must not survive the swap; a fresh build
                # just rebuilds lazily on first flush
                self.index.invalidate_caches()
                self._swaps += 1
            self.telemetry.record_swap()
            get_registry().counter("service.index_swaps").inc(1)
            # retained drift traffic describes the displaced layout — a
            # share-shift computed across the swap boundary would immediately
            # re-trigger the tuner on its own rebuild
            self.drift.reset()
        return old_index, old_live, old_seq, replayed

    def _replay_tail(
        self, delta: DeltaStore, live: np.ndarray, after_seq: int
    ) -> int:
        """Replay acked WAL records past ``after_seq`` into a swap-candidate
        (delta, live) pair; returns #records. Caller holds both locks with
        the commit pipeline drained, so the log holds no staged-but-unapplied
        frame. Same transitions as recovery's ``replay_into``, including the
        id-continuity check: the first replayed insert must land exactly at
        the new index's row count, or the build captured a different id
        space than the log describes."""
        # lazy: store.recovery imports this module at its own import time
        from ..store.recovery import RecoveryError
        from ..store.wal import KIND_DELETE, KIND_INSERT, split_insert_arrays

        n = 0
        for rec in self.wal.replay(after_seq):
            if rec.kind == KIND_INSERT:
                vectors, ids, columns, null_masks = split_insert_arrays(
                    rec.arrays
                )
                got = delta.insert(vectors, columns or None, null_masks or None)
                if not np.array_equal(got, ids):
                    raise RecoveryError(
                        f"swap replay diverged at WAL record {rec.seq}: "
                        f"ids {got.tolist()} != committed {ids.tolist()}"
                    )
            elif rec.kind == KIND_DELETE:
                for ext_id in np.atleast_1d(
                    np.asarray(rec.arrays["ids"], dtype=np.int64)
                ):
                    ext_id = int(ext_id)
                    if 0 <= ext_id < len(live):
                        live[ext_id] = False
                    else:
                        delta.delete(ext_id)
            else:
                raise RecoveryError(
                    f"swap replay: WAL record {rec.seq} has unknown kind "
                    f"{rec.kind}"
                )
            n += 1
        return n

    def _adopt_tail(self, delta: DeltaStore, live: np.ndarray) -> int:
        """No-WAL swap tail: carry post-capture writes from the serving
        in-memory view into a swap candidate; returns #rows adopted.

        The full view (indexed rows + delta rows) is id-ordered, so rows at
        positions >= the new index's row count are exactly the inserts the
        build didn't capture; post-capture deletes are wherever the serving
        masks went dead."""
        cut = delta.first_id  # == the new index's db.n
        cur_db, cur_live = self.delta.snapshot()
        full_db = (
            self.index.db
            if cur_db is None
            else VectorDatabase.concat(self.index.db, cur_db)
        )
        full_live = np.concatenate([self._live, cur_live])
        # deletes over rows the new index holds fold into its live mask
        m = min(len(live), len(full_live))
        np.logical_and(live[:m], full_live[:m], out=live[:m])
        if full_db.n <= cut:
            return 0
        tail = full_db.take(np.arange(cut, full_db.n))
        cols: Dict[str, np.ndarray] = {}
        nms: Dict[str, np.ndarray] = {}
        for name, c in tail.columns.items():
            cols[name] = c.values
            if c.kind != SETCAT and c.null_mask is not None:
                nms[name] = c.null_mask
        got = delta.insert(tail.vectors, cols or None, nms or None)
        assert int(got[0]) == cut, "adopted tail broke id continuity"
        for gid in cut + np.nonzero(~full_live[cut:])[0]:
            delta.delete(int(gid))
        return int(full_db.n - cut)

    # ---------------------------------------------------------- serving loop

    def tick(self, now: Optional[float] = None) -> int:
        """Flush once if a trigger fired; returns #queries terminated."""
        failpoint("scheduler.tick")
        with self._lock:
            if not self.scheduler.ready(now):
                return 0
        return self._flush(ready_only=True, now=now)

    def flush(self) -> int:
        """Force a flush of whatever is pending (ignores triggers).

        No empty-queue fast path on purpose: ``_flush`` serializes on the
        flush lock, so even a 0 return waits out any in-flight flush —
        keeping ``drain()``'s contract that returning means every previously
        submitted query has been answered, not merely taken.
        """
        return self._flush()

    def drain(self) -> int:
        """Flush until the queue is empty; returns #queries answered."""
        total = 0
        while True:
            n = self.flush()
            if n == 0:
                return total
            total += n

    def _flush(self, ready_only: bool = False, now: Optional[float] = None) -> int:
        """One micro-batch through engine + delta + merge — lock-free pipeline.

        Three phases: (1) snapshot the batch, live mask, and delta view under
        the state lock; (2) dispatch the whole kernel pipeline OUTSIDE it, so
        concurrent ``submit``/``insert``/``delete`` queue into the next
        micro-batch instead of blocking for the flush duration; (3) re-acquire
        to fulfill handles and record telemetry. Flushes serialize among
        themselves (and against ``refresh``) on the flush lock; ``ready_only``
        (the ``tick`` path) re-checks the trigger once inside it, so a caller
        that queued behind another flush doesn't prematurely flush queries
        that arrived meanwhile and are still inside the batching window.
        """
        tracer = get_tracer()
        with self._flush_lock:
            with self._lock:
                if ready_only and not self.scheduler.ready(now):
                    return 0
                batch = self.scheduler.take()
                if not batch:
                    return 0
                depth = len(self.scheduler)
                # deadline gate #1 (take): fail already-expired queries before
                # spending any kernel time on them
                t_take = time.perf_counter()
                alive, expired = [], []
                for pq in batch:
                    dead = pq.t_deadline is not None and t_take >= pq.t_deadline
                    (expired if dead else alive).append(pq)
                for pq in expired:
                    pq.handle._fail(
                        DeadlineExceeded(
                            f"deadline lapsed before flush (query {pq.handle.qid})",
                            qid=pq.handle.qid,
                        ),
                        t_take,
                    )
                if expired:
                    self.telemetry.record_deadline_expired(len(expired))
                batch = alive
                if not batch:
                    return len(expired)
                degraded = self._update_overload(depth)
                wl, n_real = self.scheduler.build_workload(batch, self.cfg.k)
                live = self._live.copy()
                delta_view = self.delta.view()
                delta_rows = self.delta.n
            if tracer.enabled:
                # retroactive per-query queue-wait spans: t_submit and the
                # tracer share the perf_counter clock, so submit→flush waits
                # land exactly on the timeline even though they are only
                # known now
                t_start = time.perf_counter()
                for pq in batch:
                    tracer.add_span(
                        "queue.wait", pq.t_submit, t_start, qid=pq.handle.qid
                    )
                tracer.counter("queue.depth", depth)
            before = kops.dispatch_stats().snapshot()
            t0 = time.perf_counter()
            try:
                with tracer.span("flush", size=n_real, depth=depth):
                    failpoint("service.flush")
                    ids, scores, res = self._answer(
                        wl, live, delta_view, degraded=degraded
                    )
            except Exception as e:
                # crash containment: this flush's queries fail typed, the
                # service keeps serving — no stranded handle, no dead loop
                t_done = time.perf_counter()
                with self._lock:
                    for pq in batch:
                        pq.handle._fail(
                            QueryError(
                                f"flush pipeline failed (query {pq.handle.qid})",
                                qid=pq.handle.qid,
                                cause=e,
                            ),
                            t_done,
                        )
                    self._last_flush_s = t_done - t0
                    self._last_flush_done = t_done
                self.telemetry.record_flush_failure(len(batch))
                get_registry().counter("service.flush_failures").inc(1)
                return n_real + len(expired)
            dt = time.perf_counter() - t0
            delta_stats = kops.dispatch_stats().delta_since(before)
            t_done = time.perf_counter()
            with self._lock:
                lats = []
                n_late = 0
                with tracer.span("flush.fulfill", size=n_real):
                    # deadline gate #2 (fulfill): the answer exists but came
                    # too late — the caller's contract says fail, not a
                    # surprise stale success
                    for i, pq in enumerate(batch):
                        if pq.t_deadline is not None and t_done >= pq.t_deadline:
                            pq.handle._fail(
                                DeadlineExceeded(
                                    f"deadline lapsed during flush "
                                    f"(query {pq.handle.qid})",
                                    qid=pq.handle.qid,
                                ),
                                t_done,
                            )
                            n_late += 1
                        else:
                            pq.handle._fulfill(
                                ids[i], scores[i], t_done, degraded=degraded
                            )
                            lats.append(t_done - pq.t_submit)
                if n_late:
                    self.telemetry.record_deadline_expired(n_late)
                if degraded:
                    self.telemetry.record_degraded_flush()
                self._last_flush_s = dt
                self._last_flush_done = t_done
                self.telemetry.record_flush(
                    size=n_real,
                    queue_depth=depth,
                    knn_dispatches=delta_stats.knn_calls,
                    merge_dispatches=delta_stats.merge_calls,
                    seconds=dt,
                    latencies=lats,
                    peak_candidate_bytes=res.peak_candidate_bytes,
                    lut_bytes=res.lut_bytes,
                )
            self._observe_flush(batch, ids, lats, res, delta_rows)
        return n_real + len(expired)

    def _update_overload(self, depth: int) -> bool:
        """Overload detector (caller holds the state lock): returns whether
        THIS flush should run degraded. Enter on either pressure signal
        (post-take queue depth, last flush wall time) crossing its threshold;
        exit only when both drop below threshold × ``overload_recover_frac``
        (hysteresis). Degrading needs a codebook — an index without ``pq``
        never sheds, whatever the pressure."""
        cfg = self.cfg
        qd, fl = cfg.overload_queue_depth, cfg.overload_flush_s
        if (qd is None and fl is None) or self.index.pq is None:
            return False
        over_q = qd is not None and depth >= qd
        over_f = fl is not None and self._last_flush_s >= fl
        if not self._degraded:
            if over_q or over_f:
                self._degraded = True
                self.telemetry.record_degraded_transition()
        else:
            frac = cfg.overload_recover_frac
            calm_q = qd is None or depth <= qd * frac
            calm_f = fl is None or self._last_flush_s <= fl * frac
            if calm_q and calm_f:
                self._degraded = False
                self.telemetry.record_degraded_transition()
        get_registry().gauge("service.degraded").set(1 if self._degraded else 0)
        return self._degraded

    def _observe_flush(self, batch, ids, lats, res, delta_rows: int) -> None:
        """Feed the metrics registry and drift monitor from one flush (runs
        outside the state lock — every input is a flush-local snapshot)."""
        reg = get_registry()
        qw = reg.histogram("service.queue_wait_s")
        for w in lats:
            qw.observe(w)
        reg.histogram("service.flush_size").observe(len(batch))
        reg.histogram("engine.bytes_scanned").observe(res.bytes_scanned)
        reg.histogram("engine.peak_candidate_bytes").observe(res.peak_candidate_bytes)
        self.drift.observe_queries([pq.filt for pq in batch])
        if res.part_probes:
            self.drift.observe_probes(res.part_probes)
        self.drift.observe_delta(delta_rows)
        for i, pq in enumerate(batch):
            self.drift.maybe_sample(pq.vector, pq.filt, ids[i])

    def drift_report(
        self, *, probe_recall: bool = False, k: Optional[int] = None
    ) -> DriftReport:
        """Current workload-drift reading (see obs.drift). ``probe_recall``
        additionally replays the answered-query reservoir against a
        brute-force scan of the live DB — exact but O(n), so keep it off
        latency-sensitive paths."""
        return self.drift.report(self, probe_recall=probe_recall, k=k)

    def _answer(self, wl: Workload, live: np.ndarray, delta_view, degraded=False):
        """(ids i64 [m, k], scores f32 [m, k], SearchResult): engine + delta.

        Operates on the flush's snapshots (live mask copy, immutable delta
        view) so it can run outside the state lock. The engine's
        ``SearchResult`` rides along for the flush's telemetry (candidate
        buffer peak, LUT bytes). A ``degraded`` flush sheds the main-index
        scan to the ADC path (``scan_mode="pq"`` at ``degraded_refine_factor``)
        — the delta scan stays as configured, since the delta buffer is small
        by construction and never the overload source.
        """
        tracer = get_tracer()
        scan_kw = (
            {"scan_mode": "pq", "refine_factor": self.cfg.degraded_refine_factor}
            if degraded
            else {}
        )
        nprobe: Union[int, Dict[int, int]] = self.cfg.nprobe
        by_filter = self._nprobe_by_filter
        if by_filter is not None:
            # tuner overrides are keyed by filter tuple; template indices are
            # interned per batch, so translate for THIS flush's workload
            default = nprobe if isinstance(nprobe, int) else 8
            nprobe = {
                ti: by_filter.get(filt, default)
                for ti, filt in enumerate(wl.templates)
            }
        with tracer.span("engine.search", m=wl.m):
            res = self.index.search(
                wl,
                nprobe=nprobe,
                batch_vec=self.cfg.batch_vec,
                live_mask=live,
                **scan_kw,
            )
        with tracer.span("delta.scan", rows=len(delta_view.live)):
            delta_out = delta_view.scan(
                wl,
                stats=ScanStats(),
                pq_threshold=self.cfg.delta_pq_threshold,
                refine_factor=self.index.cfg.plan.refine_factor,
            )
        if delta_out is None:
            return res.ids, res.scores, res
        ds, di = delta_out
        cat_s = np.concatenate([res.scores, ds], axis=1)
        cat_i = np.concatenate([res.ids, di], axis=1)
        with tracer.span("delta.merge", m=wl.m):
            ms, mi = kops.merge_topk(jnp.asarray(cat_s), jnp.asarray(cat_i), wl.k)
            ms, mi = fence(ms, mi)
        return np.asarray(mi, dtype=np.int64), np.asarray(ms, dtype=np.float32), res

    # ----------------------------------------------------- background driver

    def start(self, poll_s: Optional[float] = None) -> None:
        """Run the flush loop on a background scheduler thread."""
        assert self._thread is None, "service already running"
        poll = self.cfg.deadline_s / 4 if poll_s is None else poll_s
        poll = max(1e-4, float(poll))
        self._stop_flag.clear()

        def loop() -> None:
            set_thread_name("service")  # root spans tagged for trace triage
            while not self._stop_flag.is_set():
                try:
                    n = self.tick()
                except Exception:
                    # a tick that dies must not kill the scheduler thread —
                    # _flush already contained per-batch failures; anything
                    # reaching here (e.g. an armed scheduler.tick failpoint)
                    # is counted and survived
                    self.telemetry.record_loop_error()
                    n = 0
                if n == 0:
                    time.sleep(poll)

        self._thread = threading.Thread(target=loop, name="hqi-service", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread (optionally answering remaining queries)."""
        if self._thread is None:
            return
        self._stop_flag.set()
        self._thread.join()
        self._thread = None
        if drain:
            self.drain()

    # ------------------------------------------------------------ inspection

    def snapshot_db(self) -> VectorDatabase:
        """The live DB as a standalone VectorDatabase (offline-parity tool):
        indexed rows + delta rows, minus tombstones, in global-id order."""
        with self._lock:
            delta_db, _ = self.delta.snapshot()
            full = (
                self.index.db
                if delta_db is None
                else VectorDatabase.concat(self.index.db, delta_db)
            )
            return full.take(self.live_ids())
