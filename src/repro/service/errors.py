"""Typed serving errors — the structured failure surface of ``HQIService``.

The self-healing contract (repro.fault) is that every submitted query
*terminates*: answered, or failed with one of these errors carrying enough
structure for a caller (or the future router tier) to act on — retry, shed,
or surface. Bare ``RuntimeError``s are exactly what a router cannot route.
"""
from __future__ import annotations

from typing import Optional

__all__ = [
    "DeadlineExceeded",
    "QueryError",
    "QueueFull",
    "ResultPending",
    "ServiceReadOnly",
]


class QueueFull(RuntimeError):
    """Admission control: the pending queue is at ``queue_bound``."""


class ResultPending(RuntimeError):
    """``QueryHandle.result()`` called before the query was answered
    (non-blocking form; pass ``timeout=`` for the blocking accessor)."""


class DeadlineExceeded(TimeoutError):
    """A deadline lapsed: a per-query serving deadline expired before the
    answer was produced, or ``QueryHandle.result(timeout=)`` timed out."""

    def __init__(self, message: str, *, qid: Optional[int] = None) -> None:
        super().__init__(message)
        self.qid = qid


class QueryError(RuntimeError):
    """A query's flush pipeline failed; ``cause`` is the underlying error.

    Raised by ``QueryHandle.result()`` when the handle was *failed* rather
    than fulfilled — the flush that carried it crashed (and was contained:
    the service keeps serving subsequent flushes).
    """

    def __init__(self, message: str, *, qid: int, cause: BaseException) -> None:
        super().__init__(message)
        self.qid = qid
        self.cause = cause
        self.__cause__ = cause


class ServiceReadOnly(RuntimeError):
    """Writes are quarantined (poisoned WAL or a diverged delta apply);
    reads keep serving. ``cause`` is the fault that tripped the quarantine."""

    def __init__(self, message: str, *, cause: Optional[BaseException] = None) -> None:
        super().__init__(message)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause
