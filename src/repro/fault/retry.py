"""Bounded retry with exponential backoff + jitter for transient I/O faults.

The store layer's durability calls — the WAL group-commit fsync, snapshot
blob streaming — can fail transiently (NFS hiccup, overloaded disk, the
chaos harness's armed ``count=N`` failpoints) without the data being wrong.
Crashing a serving process on the first ``OSError`` turns a 2 ms hiccup into
a full restart + recovery; retrying forever turns a dead disk into a hung
commit. ``with_retries`` is the bounded middle: a few attempts, exponential
backoff so a struggling device is not hammered, jitter so concurrent
retriers decorrelate, and the LAST error propagated when attempts run out —
at which point the caller escalates (the WAL poisons itself into read-only
quarantine, the compactor backs off and reports through the registry).
"""
from __future__ import annotations

import random
import time
from typing import Callable, Optional, Tuple, Type, TypeVar

T = TypeVar("T")

__all__ = ["with_retries"]


def backoff_delays(
    attempts: int,
    *,
    base_s: float = 0.002,
    max_s: float = 0.25,
    jitter: float = 0.5,
    rng: Optional[random.Random] = None,
):
    """The sleep schedule between attempts: ``base · 2^i`` capped at
    ``max_s``, each scaled by ``1 + U(0, jitter)``. ``attempts - 1`` values
    (no sleep after the final failure)."""
    rng = rng or random.Random()
    for i in range(max(0, attempts - 1)):
        d = min(max_s, base_s * (2.0 ** i))
        yield d * (1.0 + jitter * rng.random())


def with_retries(
    fn: Callable[[], T],
    *,
    attempts: int = 3,
    base_s: float = 0.002,
    max_s: float = 0.25,
    jitter: float = 0.5,
    retry_on: Tuple[Type[BaseException], ...] = (OSError,),
    rng: Optional[random.Random] = None,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> T:
    """Call ``fn`` up to ``attempts`` times; return its first success.

    Only ``retry_on`` errors are retried — anything else (assertion,
    corruption, KeyboardInterrupt) propagates immediately, because retrying
    a *logic* error just repeats it with extra latency. ``on_retry(attempt,
    error)`` is the observability hook (the WAL counts fsync retries through
    it). The final failure re-raises the last error unchanged so callers
    keep their existing except clauses.
    """
    assert attempts >= 1
    delays = backoff_delays(
        attempts, base_s=base_s, max_s=max_s, jitter=jitter, rng=rng
    )
    last: Optional[BaseException] = None
    for attempt in range(attempts):
        try:
            return fn()
        except retry_on as e:  # noqa: PERF203 — the whole point
            last = e
            if on_retry is not None:
                on_retry(attempt + 1, e)
            try:
                sleep(next(delays))
            except StopIteration:
                break
    assert last is not None
    raise last
