"""Chaos harness: drive a live ``HQIService`` while failpoints fire.

The self-healing contract this harness verifies, round after round, with
random subsets of the standard failpoint sites armed (``repro.fault.
failpoints.SITES``) and — optionally — a writer subprocess SIGKILL'd
mid-commit:

  1. **No lost acked write.** Every insert whose ids were returned is in
     ``live_ids()`` after every subsequent crash + ``open_service`` recovery;
     every acknowledged delete stays dead. Writes that *failed* are
     indeterminate (the fault may have landed before or after durability) and
     are tracked as neither.
  2. **No hung query.** Every submitted query terminates within the harness
     timeout: fulfilled, or failed with a typed error (``QueryError``,
     ``DeadlineExceeded``) — never a handle nobody will ever set.
  3. **Exact parity.** Every successfully answered, non-degraded query
     matches ``exhaustive_search`` over the service's own state snapshot
     (captured quiescently between the round's write and query phases):
     same id set, same scores. Faults may fail queries; they may never
     silently corrupt answers.

Determinism: every choice — which sites arm, with what error/probability/
count, the write/delete/query streams — derives from one seed. Wall-clock
still influences micro-batch *composition* (which queries share a flush),
but all three invariants are composition-independent, so the asserted
outcome is deterministic even though scheduling is not.

CLI:  python -m repro.fault.chaos [--smoke] [--seed N] [--rounds N] ...
      (exit code 1 when any invariant is violated; JSON report on stdout)
"""
from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from ..core import HQIConfig, HQIIndex
from ..core.baselines import exhaustive_search
from ..core.predicates import Between, In, make_filter
from ..core.types import Column, VectorDatabase, Workload
from ..service.errors import DeadlineExceeded, QueryError
from ..service.service import HQIService, ServiceConfig
from ..store import Compactor, open_service
from ..store.recovery import init_store
from . import failpoints

EXACT = 10_000  # nprobe past every list count: the engine scans exhaustively

# (site, error kind) pool the harness draws from. wal.fsync gets a transient
# OSError (exercises the retry budget AND — with enough firings — poisoning);
# the pipeline sites get the default FailpointError (exercises containment).
_SITE_ERRORS: Tuple[Tuple[str, str], ...] = (
    ("wal.stage", "oserror"),
    ("wal.fsync", "oserror"),
    ("delta.apply", "runtimeerror"),
    ("service.flush", "failpoint"),
    ("scheduler.tick", "runtimeerror"),
    ("snapshot.write", "oserror"),
    ("compact.cycle", "failpoint"),
)


@dataclasses.dataclass
class ChaosConfig:
    seed: int = 0
    rounds: int = 4
    writes_per_round: int = 8
    insert_batch: int = 6
    deletes_per_round: int = 6
    queries_per_round: int = 50
    k: int = 5
    n0: int = 1200  # seed DB rows
    d: int = 16
    metric: str = "ip"
    sites_per_round: int = 3  # distinct failpoints armed per phase
    fault_count: int = 2  # firings per armed site (transient faults)
    poison_rounds: Tuple[int, ...] = (2,)  # rounds arming wal.fsync past its
    # retry budget — exercises WAL poisoning + clear_poison healing
    deadline_queries: int = 3  # per round, submitted with a ~0 deadline
    kill_writer: bool = True  # SIGKILL a writer subprocess, then recover
    compact_every: int = 2  # compact_once every N rounds (faults armed)
    result_timeout_s: float = 60.0  # per-query hang detector
    sync_wal: bool = True


@dataclasses.dataclass
class ChaosReport:
    rounds: int = 0
    queries_submitted: int = 0
    answered_ok: int = 0
    failed_typed: int = 0  # QueryError / DeadlineExceeded — terminated
    hung: int = 0  # invariant 2: MUST stay 0
    degraded_answers: int = 0
    writes_acked: int = 0
    writes_failed: int = 0
    deletes_acked: int = 0
    parity_mismatches: int = 0  # invariant 3: MUST stay 0
    recovery_checks: int = 0
    recovery_violations: int = 0  # invariant 1: MUST stay 0
    restarts: int = 0
    poisons_healed: int = 0
    compactions: int = 0
    compaction_failures: int = 0
    killed_writers: int = 0
    killed_writer_acks: int = 0
    sites_fired: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return (
            self.hung == 0
            and self.parity_mismatches == 0
            and self.recovery_violations == 0
        )

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


# ---------------------------------------------------------------------------
# Synthetic store (self-contained — the harness must run from a stock binary)
# ---------------------------------------------------------------------------


def _synth_db(n: int, d: int, seed: int, metric: str) -> VectorDatabase:
    rng = np.random.default_rng(seed)
    return VectorDatabase(
        vectors=rng.normal(size=(n, d)).astype(np.float32),
        columns={
            "A": Column.numeric("A", rng.random(n).astype(np.float32)),
            "cat": Column.categorical(
                "cat", rng.integers(0, 8, n).astype(np.int32)
            ),
        },
        metric=metric,
    )


def _templates() -> List[tuple]:
    return [
        make_filter(),  # pure vector search
        make_filter(Between("A", 0.0, 0.5)),
        make_filter(In("cat", frozenset({0, 1, 2}))),
        make_filter(Between("A", 0.2, 0.9), In("cat", frozenset({1, 3, 5}))),
    ]


def _insert_payload(
    rng: np.random.Generator, n: int, d: int
) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    return (
        rng.normal(size=(n, d)).astype(np.float32),
        {
            "A": rng.random(n).astype(np.float32),
            "cat": rng.integers(0, 8, n).astype(np.int32),
        },
    )


def _service_cfg(k: int) -> ServiceConfig:
    # EXACT nprobe: the engine answers exhaustively, so invariant 3 can demand
    # parity with brute force instead of a recall bound
    return ServiceConfig(k=k, nprobe=EXACT, max_batch=16, deadline_s=1e-3)


def _build_service(root: str, cfg: ChaosConfig) -> HQIService:
    db = _synth_db(cfg.n0, cfg.d, cfg.seed, cfg.metric)
    rng = np.random.default_rng(cfg.seed + 1)
    templates = _templates()
    wl = Workload(
        vectors=rng.normal(size=(32, cfg.d)).astype(np.float32),
        templates=templates,
        template_of=rng.integers(0, len(templates), 32).astype(np.int32),
        k=cfg.k,
    )
    index = HQIIndex.build(
        db, wl, HQIConfig(min_partition_size=128, max_leaves=8)
    )
    return init_store(root, index, cfg=_service_cfg(cfg.k), sync=cfg.sync_wal)


# ---------------------------------------------------------------------------
# Round phases
# ---------------------------------------------------------------------------


def _arm_phase(
    rng: np.random.Generator,
    pool: Tuple[Tuple[str, str], ...],
    n_sites: int,
    count: int,
) -> List[str]:
    """Arm ``n_sites`` distinct sites drawn from ``pool``; returns names."""
    picks = rng.choice(len(pool), size=min(n_sites, len(pool)), replace=False)
    armed = []
    for p in picks:
        site, kind = pool[int(p)]
        failpoints.arm(
            site,
            kind,
            prob=float(rng.uniform(0.4, 1.0)),
            count=count,
            seed=int(rng.integers(0, 2**31)),
        )
        armed.append(site)
    return armed


def _write_phase(
    svc: HQIService,
    cfg: ChaosConfig,
    rng: np.random.Generator,
    must_live: Set[int],
    must_dead: Set[int],
    rep: ChaosReport,
) -> None:
    for _ in range(cfg.writes_per_round):
        vecs, cols = _insert_payload(rng, cfg.insert_batch, cfg.d)
        try:
            ids = svc.insert(vecs, cols)
        except Exception:
            # indeterminate: the fault may have hit before OR after the
            # record reached the log — the id set is unknown to the caller,
            # so it joins neither invariant set
            rep.writes_failed += 1
        else:
            rep.writes_acked += 1
            must_live.update(int(i) for i in ids)
    candidates = sorted(must_live)
    if candidates:
        picks = rng.choice(
            len(candidates),
            size=min(cfg.deletes_per_round, len(candidates)),
            replace=False,
        )
        for p in picks:
            gid = candidates[int(p)]
            # a delete ATTEMPT makes the id indeterminate even on failure
            # (the tombstone may be logged despite the raised fault)
            must_live.discard(gid)
            try:
                svc.delete([gid])
            except Exception:
                rep.writes_failed += 1
            else:
                rep.deletes_acked += 1
                must_dead.add(gid)


def _query_phase(
    svc: HQIService,
    cfg: ChaosConfig,
    rng: np.random.Generator,
    rep: ChaosReport,
) -> None:
    """Submit a query stream against the background loop; verify termination
    + parity. The parity reference is the service's own quiescent snapshot
    (no writes are in flight during this phase)."""
    db_snap = svc.snapshot_db()
    templates = _templates()
    t_of = rng.integers(0, len(templates), cfg.queries_per_round).astype(np.int32)
    qv = rng.normal(size=(cfg.queries_per_round, cfg.d)).astype(np.float32)
    deadline_picks = set(
        int(i)
        for i in rng.choice(
            cfg.queries_per_round,
            size=min(cfg.deadline_queries, cfg.queries_per_round),
            replace=False,
        )
    )
    svc.start(poll_s=1e-4)
    handles = []
    for i in range(cfg.queries_per_round):
        dl = 1e-9 if i in deadline_picks else None  # ~always expires
        try:
            h = svc.submit(qv[i], templates[int(t_of[i])], deadline_s=dl)
        except DeadlineExceeded:
            rep.failed_typed += 1  # rejected at admission: terminated
            handles.append(None)
        else:
            handles.append(h)
        rep.queries_submitted += 1
        if (i + 1) % 8 == 0:
            # trickle the stream across several micro-batches: a single
            # giant flush would give one fault the whole round's queries
            time.sleep(0.003)
    deadline_t = time.perf_counter() + cfg.result_timeout_s
    for h in handles:
        if h is None:
            continue
        if not h.wait(max(0.0, deadline_t - time.perf_counter())):
            rep.hung += 1  # invariant 2 violated
    svc.stop(drain=True)

    wl = Workload(vectors=qv, templates=templates, template_of=t_of, k=cfg.k)
    ref = exhaustive_search(db_snap, wl)
    for i, h in enumerate(handles):
        if h is None or not h.done:
            continue
        if h.error is not None:
            assert isinstance(
                h.error, (QueryError, DeadlineExceeded)
            ), f"untyped query failure: {h.error!r}"
            rep.failed_typed += 1
            continue
        rep.answered_ok += 1
        if h.degraded:
            rep.degraded_answers += 1
            continue  # approximate by design: excluded from exact parity
        got_i, got_s = h.ids, h.scores
        ref_pos = ref.ids[i]
        ref_gids = set(
            int(g) for g in np.asarray(db_snap.ids)[ref_pos[ref_pos >= 0]]
        )
        got_gids = set(int(g) for g in got_i[got_i >= 0])
        scores_match = np.allclose(
            np.where(np.isfinite(got_s), got_s, -1e30),
            np.where(np.isfinite(ref.scores[i]), ref.scores[i], -1e30),
            rtol=1e-4,
            atol=1e-4,
        )
        if got_gids != ref_gids or not scores_match:
            rep.parity_mismatches += 1


def _recovery_check(
    root: str,
    cfg: ChaosConfig,
    svc: HQIService,
    must_live: Set[int],
    must_dead: Set[int],
    rep: ChaosReport,
) -> HQIService:
    """Crash the process state (close the WAL, drop the service) and verify
    ``open_service`` restores every acked write; returns the new service."""
    svc.wal.close()
    svc2 = open_service(root, cfg=_service_cfg(cfg.k), sync=cfg.sync_wal)
    alive = set(int(i) for i in svc2.live_ids())
    rep.recovery_checks += 1
    if not must_live.issubset(alive) or (must_dead & alive):
        rep.recovery_violations += 1
    rep.restarts += 1
    return svc2


def _kill_writer_phase(
    root: str, cfg: ChaosConfig, seed: int, rep: ChaosReport
) -> Set[int]:
    """SIGKILL a subprocess mid-write-stream; every id it printed (= acked)
    must survive the parent's subsequent recovery."""
    proc = subprocess.Popen(
        [
            sys.executable,
            "-m",
            "repro.fault.chaos",
            "--child",
            root,
            "--seed",
            str(seed),
            "--k",
            str(cfg.k),
        ],
        stdout=subprocess.PIPE,
        stderr=subprocess.DEVNULL,
        text=True,
        env=os.environ.copy(),
    )
    # let it commit a few batches, then kill without warning (SIGKILL —
    # no atexit, no flush, the genuine crash signature)
    time.sleep(2.0)
    proc.kill()
    out, _ = proc.communicate()
    acked: Set[int] = set()
    for line in out.splitlines():
        if line.startswith("ACK "):
            acked.update(int(t) for t in line[4:].split(",") if t)
    rep.killed_writers += 1
    rep.killed_writer_acks += len(acked)
    return acked


def _child_writer(root: str, seed: int, k: int) -> None:
    """``--child`` mode: open the store and stream insert batches until
    killed, printing each ACKED batch's ids (print AFTER the ack, so every
    printed id is covered by the durability contract)."""
    svc = open_service(root, cfg=_service_cfg(k))
    rng = np.random.default_rng(seed)
    d = svc.index.db.d
    while True:
        vecs, cols = _insert_payload(rng, 4, d)
        ids = svc.insert(vecs, cols)
        print("ACK " + ",".join(str(int(i)) for i in ids), flush=True)
        # pace the stream: the parent's recovery replays every acked record,
        # so an unthrottled 2 s burst would turn the invariant check into a
        # replay benchmark
        time.sleep(0.005)


# ---------------------------------------------------------------------------
# The harness
# ---------------------------------------------------------------------------


def run_chaos(root: str, cfg: Optional[ChaosConfig] = None) -> ChaosReport:
    cfg = cfg or ChaosConfig()
    rng = np.random.default_rng(cfg.seed)
    rep = ChaosReport()
    failpoints.disarm_all()
    svc = _build_service(root, cfg)
    must_live: Set[int] = set()
    must_dead: Set[int] = set()
    write_pool = tuple(
        (s, k) for s, k in _SITE_ERRORS if s.startswith(("wal.", "delta."))
    )
    compact_pool = tuple(
        (s, k) for s, k in _SITE_ERRORS if s in ("snapshot.write", "compact.cycle")
    )
    try:
        for rnd in range(cfg.rounds):
            rep.rounds += 1
            # -- write phase: store-layer faults armed
            count = cfg.fault_count
            if rnd in cfg.poison_rounds:
                # enough consecutive fsync failures to blow the retry budget
                failpoints.arm(
                    "wal.fsync",
                    "oserror",
                    count=svc.wal.fsync_retries + 2,
                    seed=int(rng.integers(0, 2**31)),
                )
                _arm_phase(rng, write_pool[:1] + write_pool[2:], 2, count)
            else:
                _arm_phase(rng, write_pool, cfg.sites_per_round, count)
            _write_phase(svc, cfg, rng, must_live, must_dead, rep)
            _note_fired(rep)
            failpoints.disarm_all()
            # heal quarantines the faults may have tripped: a poisoned WAL
            # clears in place (operator path); a diverged apply needs the
            # restart+replay path — which is itself a recovery check
            if svc.wal.poisoned is not None:
                svc.wal.clear_poison()
                rep.poisons_healed += 1
            if svc._write_poisoned is not None:
                svc = _recovery_check(root, cfg, svc, must_live, must_dead, rep)

            # -- query phase: serving faults armed, parity asserted.
            # Bounded counts + sub-1.0 probability so SOME flushes crash
            # (containment exercised) while others answer (parity exercised)
            failpoints.arm(
                "service.flush",
                "failpoint",
                prob=0.5,
                count=2,
                seed=int(rng.integers(0, 2**31)),
            )
            failpoints.arm(
                "scheduler.tick",
                "runtimeerror",
                prob=0.5,
                count=2,
                seed=int(rng.integers(0, 2**31)),
            )
            _query_phase(svc, cfg, rng, rep)
            _note_fired(rep)
            failpoints.disarm_all()

            # -- compaction under fire (every compact_every rounds)
            if cfg.compact_every and (rnd + 1) % cfg.compact_every == 0:
                _arm_phase(rng, compact_pool, 1, count)
                try:
                    Compactor(svc, root).compact_once(force=True)
                    rep.compactions += 1
                except Exception:
                    rep.compaction_failures += 1  # old generation must serve
                _note_fired(rep)
                failpoints.disarm_all()

            # -- crash + recover, verify the durability invariant
            svc = _recovery_check(root, cfg, svc, must_live, must_dead, rep)

        # -- writer-kill phase: a subprocess dies mid-commit, parent recovers
        if cfg.kill_writer:
            svc.wal.close()
            acked = _kill_writer_phase(root, cfg, cfg.seed + 999, rep)
            svc = open_service(root, cfg=_service_cfg(cfg.k), sync=cfg.sync_wal)
            alive = set(int(i) for i in svc.live_ids())
            must_live.update(acked)
            rep.recovery_checks += 1
            if not acked.issubset(alive) or (must_dead & alive):
                rep.recovery_violations += 1
    finally:
        failpoints.disarm_all()
        if svc._thread is not None:
            svc.stop(drain=False)
    return rep


def _note_fired(rep: ChaosReport) -> None:
    for site in failpoints.SITES:
        n = failpoints.fired(site)
        if n:
            rep.sites_fired[site] = rep.sites_fired.get(site, 0) + n


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(description="HQIService chaos harness")
    ap.add_argument("--root", default=None, help="store dir (default: tmp)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--no-kill", action="store_true", help="skip SIGKILL phase")
    ap.add_argument(
        "--smoke", action="store_true", help="small fast config (CI)"
    )
    ap.add_argument("--child", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.child is not None:
        _child_writer(args.child, args.seed, args.k)
        return 0  # unreachable: the parent kills us

    cfg = ChaosConfig(seed=args.seed, k=args.k)
    if args.smoke:
        cfg = dataclasses.replace(
            cfg, rounds=2, queries_per_round=25, writes_per_round=4, n0=800,
            poison_rounds=(1,),
        )
    if args.rounds is not None:
        cfg = dataclasses.replace(cfg, rounds=args.rounds)
    if args.queries is not None:
        cfg = dataclasses.replace(cfg, queries_per_round=args.queries)
    if args.no_kill:
        cfg = dataclasses.replace(cfg, kill_writer=False)

    if args.root is None:
        with tempfile.TemporaryDirectory(prefix="hqi-chaos-") as root:
            rep = run_chaos(root, cfg)
    else:
        rep = run_chaos(args.root, cfg)
    print(json.dumps(rep.as_dict(), indent=1, sort_keys=True))
    return 0 if rep.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
