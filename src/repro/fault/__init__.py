"""Fault injection & self-healing verification (failpoints, retry, chaos).

Public API:
    failpoint / arm / armed / disarm / disarm_all / fired / evaluated /
        list_armed / SITES / FailpointError — the process-wide failpoint
        registry (failpoints.py); zero-cost when disarmed
    with_retries — bounded exponential-backoff retry for transient I/O
    chaos (submodule, import lazily) — the live-service chaos harness:
        ``python -m repro.fault.chaos --smoke``

``repro.fault.chaos`` is deliberately NOT imported here: it pulls in the
whole serving + store stack, while ``failpoints`` must stay importable from
inside those very layers (service.py, wal.py, …) without a cycle.
"""
from .failpoints import (  # noqa: F401
    SITES,
    FailpointError,
    arm,
    armed,
    disarm,
    disarm_all,
    evaluated,
    failpoint,
    fired,
    list_armed,
)
from .retry import with_retries  # noqa: F401
