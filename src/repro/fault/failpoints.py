"""Process-wide failpoint registry: named fault-injection sites.

A *failpoint* is a named hook compiled into a production code path —
``failpoints.failpoint("wal.fsync")`` — that normally does nothing and can be
*armed* to raise a chosen error with a chosen probability for a bounded
number of firings. The store and service layers thread sites through every
I/O and pipeline stage whose failure a serving deployment must survive, and
the chaos harness (``repro.fault.chaos``) drives a live service with random
subsets armed, asserting the standing invariants (no hung query, no lost
acked write, exact parity on non-degraded answers).

Cost discipline (same pattern as ``obs.trace``'s ``NullTracer``): the hot
path of a *disarmed* process is one module-global load and a falsy branch —
no dict lookup, no lock, nothing allocated — so instrumentation left in the
WAL commit path or the flush loop is free in production.
``benchmarks/check_fault.py`` gates that claim in CI (< 2% of the service
bench's serving pass, with every evaluation priced at the microbenched
per-call cost).

Arming:

  * programmatic — ``arm("wal.fsync", error=OSError, count=2)`` (first two
    evaluations raise, then the site heals: exactly a transient fault), or
    the ``armed(...)`` context manager tests use;
  * by environment — ``REPRO_FAILPOINTS="wal.fsync=oserror:p0.5:n3,
    service.flush=runtimeerror"`` arms sites at import time, so a stock
    binary can be chaos-tested with no code changes. Grammar per site:
    ``name=kind[:pP][:nN][:sS][:seedX]`` — error kind (oserror | ioerror |
    runtimeerror | timeout | failpoint), firing probability ``p`` (default
    1.0), max firings ``n`` (default unbounded), initial evaluations to skip
    ``s`` (default 0), RNG seed for the probability draw (default 0 —
    deterministic by default, as every chaos artifact must be).

Site names are dotted ``layer.stage`` strings; the standard sites are listed
in ``SITES`` (and in the README's failpoint table). Unknown names are legal —
``failpoint`` is self-registering — but ``arm`` warns loudly via
``KeyError`` when ``strict=True`` and the name is not a known site.
"""
from __future__ import annotations

import dataclasses
import os
import random
import threading
from contextlib import contextmanager
from typing import Callable, Dict, Optional, Union

__all__ = [
    "FailpointError",
    "SITES",
    "arm",
    "armed",
    "disarm",
    "disarm_all",
    "evaluated",
    "failpoint",
    "fired",
    "list_armed",
]


class FailpointError(RuntimeError):
    """Default error an armed failpoint raises (kind "failpoint")."""


# The standard sites threaded through the store and service layers. Keeping
# the list here (not just in the README) lets the chaos harness arm "all the
# real sites" without string drift and lets tests assert coverage.
SITES = (
    "wal.stage",        # WriteAheadLog.stage — frame write into the OS
    "wal.fsync",        # WriteAheadLog.sync_upto — the group-commit fsync
    "snapshot.write",   # snapshot._write_generation — per-blob stream to disk
    "snapshot.load",    # snapshot._load_snapshot — generation open
    "compact.cycle",    # Compactor.compact_once — top of a fold→snapshot cycle
    "service.flush",    # HQIService._flush — the answer pipeline
    "delta.apply",      # DeltaStore.commit_insert — post-WAL state apply
    "scheduler.tick",   # HQIService.tick — the background loop's poll step
    "tuner.build",      # Tuner._build — off-to-the-side index rebuild
    "tuner.swap",       # HQIService.swap_index — pre-mutation swap gate
)

_ERROR_KINDS: Dict[str, Callable[[str], BaseException]] = {
    "oserror": lambda site: OSError(f"injected fault at {site}"),
    "ioerror": lambda site: IOError(f"injected fault at {site}"),
    "runtimeerror": lambda site: RuntimeError(f"injected fault at {site}"),
    "timeout": lambda site: TimeoutError(f"injected fault at {site}"),
    "failpoint": lambda site: FailpointError(f"injected fault at {site}"),
}


@dataclasses.dataclass
class _Armed:
    """One armed site's firing policy (mutated under the registry lock)."""

    make_error: Callable[[str], BaseException]
    prob: float = 1.0
    remaining: Optional[int] = None  # firings left; None = unbounded
    skip: int = 0  # evaluations to pass through before becoming eligible
    rng: random.Random = dataclasses.field(default_factory=lambda: random.Random(0))


# Hot-path contract: ``_ACTIVE`` is True iff at least one site is armed. The
# disarmed fast path in ``failpoint`` reads it WITHOUT the lock — arming is
# rare and racing a concurrent arm only delays the first injection by one
# evaluation, while taking a lock per call would tax every production commit.
_ACTIVE = False
_LOCK = threading.Lock()
_ARMED: Dict[str, _Armed] = {}
_EVALS: Dict[str, int] = {}  # evaluations of armed sites (diagnostics)
_FIRED: Dict[str, int] = {}  # errors actually raised, per site


def failpoint(name: str) -> None:
    """Evaluate the failpoint ``name``; raises iff the site is armed and its
    policy fires. The disarmed cost is one global load + branch."""
    if not _ACTIVE:
        return
    _evaluate(name)


def _evaluate(name: str) -> None:
    with _LOCK:
        fp = _ARMED.get(name)
        if fp is None:
            return
        _EVALS[name] = _EVALS.get(name, 0) + 1
        if fp.skip > 0:
            fp.skip -= 1
            return
        if fp.remaining is not None and fp.remaining <= 0:
            return
        if fp.prob < 1.0 and fp.rng.random() >= fp.prob:
            return
        if fp.remaining is not None:
            fp.remaining -= 1
        _FIRED[name] = _FIRED.get(name, 0) + 1
        err = fp.make_error(name)
    raise err


def arm(
    name: str,
    error: Union[str, BaseException, type, Callable[[str], BaseException]] = "failpoint",
    *,
    prob: float = 1.0,
    count: Optional[int] = None,
    skip: int = 0,
    seed: int = 0,
    strict: bool = True,
) -> None:
    """Arm site ``name``: subsequent ``failpoint(name)`` calls may raise.

    ``error`` is an error-kind string (see ``_ERROR_KINDS``), an exception
    class, a ready exception instance (raised as-is every firing), or a
    factory ``site -> exception``. ``prob`` is the per-evaluation firing
    probability (seeded — deterministic across runs), ``count`` bounds total
    firings (transient faults: fail N times, then heal), ``skip`` passes the
    first N evaluations through untouched (fault the *middle* of a stream).
    """
    if strict and name not in SITES:
        raise KeyError(
            f"unknown failpoint {name!r}; known sites: {', '.join(SITES)} "
            f"(arm(strict=False) to target an ad-hoc site)"
        )
    if isinstance(error, str):
        kind = error.lower()
        if kind not in _ERROR_KINDS:
            raise ValueError(
                f"unknown error kind {error!r}; one of {sorted(_ERROR_KINDS)}"
            )
        make = _ERROR_KINDS[kind]
    elif isinstance(error, BaseException):
        make = lambda _site, _e=error: _e  # noqa: E731
    elif isinstance(error, type) and issubclass(error, BaseException):
        make = lambda site, _cls=error: _cls(f"injected fault at {site}")  # noqa: E731
    else:
        make = error  # factory
    global _ACTIVE
    with _LOCK:
        _ARMED[name] = _Armed(
            make_error=make,
            prob=float(prob),
            remaining=None if count is None else int(count),
            skip=int(skip),
            rng=random.Random(seed),
        )
        _ACTIVE = True


def disarm(name: str) -> None:
    global _ACTIVE
    with _LOCK:
        _ARMED.pop(name, None)
        _ACTIVE = bool(_ARMED)


def disarm_all() -> None:
    global _ACTIVE
    with _LOCK:
        _ARMED.clear()
        _EVALS.clear()
        _FIRED.clear()
        _ACTIVE = False


@contextmanager
def armed(name: str, error="failpoint", **kw):
    """Scoped arm/disarm for tests: ``with armed("wal.fsync", OSError): ...``"""
    arm(name, error, **kw)
    try:
        yield
    finally:
        disarm(name)


def fired(name: str) -> int:
    """How many times site ``name`` actually raised since the last reset."""
    with _LOCK:
        return _FIRED.get(name, 0)


def evaluated(name: str) -> int:
    """How many times site ``name`` was evaluated while armed."""
    with _LOCK:
        return _EVALS.get(name, 0)


def list_armed() -> Dict[str, Dict[str, Union[float, int, None]]]:
    """Armed sites and their policies (for health dumps / diagnostics)."""
    with _LOCK:
        return {
            n: {"prob": fp.prob, "remaining": fp.remaining, "skip": fp.skip}
            for n, fp in _ARMED.items()
        }


# ---------------------------------------------------------------------------
# Environment activation: REPRO_FAILPOINTS="site=kind[:pP][:nN][:sS][:seedX],…"
# ---------------------------------------------------------------------------


def _arm_from_env(spec: str) -> None:
    for entry in spec.replace(";", ",").split(","):
        entry = entry.strip()
        if not entry:
            continue
        name, _, policy = entry.partition("=")
        parts = (policy or "failpoint").split(":")
        kind = parts[0] or "failpoint"
        kw: Dict[str, float] = {}
        for p in parts[1:]:
            if p.startswith("seed"):
                kw["seed"] = int(p[4:])
            elif p.startswith("p"):
                kw["prob"] = float(p[1:])
            elif p.startswith("n"):
                kw["count"] = int(p[1:])
            elif p.startswith("s"):
                kw["skip"] = int(p[1:])
            else:
                raise ValueError(f"bad REPRO_FAILPOINTS policy token {p!r} in {entry!r}")
        arm(name.strip(), kind, strict=False, **kw)


_env = os.environ.get("REPRO_FAILPOINTS", "")
if _env:
    _arm_from_env(_env)
