"""Versioned on-disk snapshots of an ``HQIIndex`` (+ serving state).

The index is exactly the state that is expensive to recompute — qd-tree
partitions mined from the historical workload, per-partition IVF quantizers,
the packed arena, trained PQ codebooks — yet before this module the repo
could only rebuild it from raw tuples on every process start. A snapshot
makes restart O(mmap) instead of O(k-means).

Format (one *generation* per save, self-describing and versioned):

    <root>/
      CURRENT                  # text file: name of the newest valid generation
      gen-000001/
        manifest.json          # JSON tree mirroring HQIIndex.to_state(); every
                               # array leaf replaced by an {"__npy__": ...}
                               # record (file, dtype, shape, nbytes)
        arrays/<dotted.path>.npy
      wal/                     # owned by store/wal.py

Array blobs are plain ``.npy`` files written with ``np.save`` and loaded with
``np.load(mmap_mode="r")`` — zero-copy: the loaded index's packed rows, PQ
codes, posting-list tables, and bitmap cache are memory-mapped pages shared
with the OS cache, so load cost is metadata-only and independent of DB size.

Crash safety: a generation is staged as ``gen-XXXXXX.tmp`` (arrays first,
manifest LAST, both fsync'd), atomically renamed into place, and only then is
``CURRENT`` swapped (tmp-write + rename). A crash at any point leaves either
the old generation current or a ``.tmp`` directory the loader ignores and the
next save sweeps. ``load_snapshot`` validates the manifest and every referenced
blob (existence + byte size) and falls back to older generations when the
newest is torn.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Set

import numpy as np

from ..core.hqi import HQIIndex
from ..fault.failpoints import failpoint
from ..fault.retry import with_retries
from ..obs.metrics import get_registry
from ..obs.trace import get_tracer
from .wal import _fsync_dir

FORMAT = "hqi-snapshot"
VERSION = 1

_GEN_PREFIX = "gen-"
_ARRAY_KEY = "__npy__"
_PINNED_FILE = "PINNED"

# One process may run several generation writers (the background compactor
# AND the index-evolution tuner): serialize name allocation + the final
# rename so two concurrent saves can't both claim gen-N. Blob streaming
# happens inside too — both writers are background work, and serial writes
# beat interleaved disk traffic.
_WRITE_LOCK = threading.Lock()


# ---------------------------------------------------------------------------
# State-tree <-> (manifest JSON, array blobs)
# ---------------------------------------------------------------------------


def _externalize(node: Any, arrays: Dict[str, np.ndarray], prefix: str) -> Any:
    """Replace every ndarray leaf with a blob record; collect arrays by file.

    Blob filenames are percent-quoted (user-supplied column names flow into
    the key path — a ``/`` or other separator must not escape ``arrays/``).
    """
    if isinstance(node, np.ndarray):
        from urllib.parse import quote

        key = prefix.strip(".") or "root"
        fname = quote(key, safe="") + ".npy"
        assert fname not in arrays, f"duplicate array key {key}"
        arrays[fname] = node
        return {
            _ARRAY_KEY: fname,
            "dtype": str(node.dtype),
            "shape": list(node.shape),
        }
    if isinstance(node, dict):
        return {
            str(k): _externalize(v, arrays, f"{prefix}.{k}") for k, v in node.items()
        }
    if isinstance(node, (list, tuple)):
        return [_externalize(v, arrays, f"{prefix}.{i}") for i, v in enumerate(node)]
    if isinstance(node, (np.integer,)):
        return int(node)
    if isinstance(node, (np.floating,)):
        return float(node)
    if isinstance(node, (np.bool_,)):
        return bool(node)
    assert node is None or isinstance(node, (bool, int, float, str)), (
        f"unserializable snapshot leaf at {prefix!r}: {type(node).__name__}"
    )
    return node


def _internalize(node: Any, arrays_dir: str, *, mmap: bool = True) -> Any:
    """Inverse of ``_externalize``: blob records become (mmap'd) ndarrays."""
    if isinstance(node, dict):
        if _ARRAY_KEY in node:
            fname = node[_ARRAY_KEY]
            if os.path.basename(fname) != fname or fname.startswith(".."):
                raise SnapshotError(f"unsafe blob path {fname!r} in manifest")
            path = os.path.join(arrays_dir, fname)
            arr = np.load(path, mmap_mode="r" if mmap else None)
            if tuple(arr.shape) != tuple(node["shape"]) or str(arr.dtype) != node["dtype"]:
                raise SnapshotError(
                    f"blob {node[_ARRAY_KEY]} does not match its manifest record: "
                    f"{arr.dtype}{list(arr.shape)} vs {node['dtype']}{node['shape']}"
                )
            return arr
        return {k: _internalize(v, arrays_dir, mmap=mmap) for k, v in node.items()}
    if isinstance(node, list):
        return [_internalize(v, arrays_dir, mmap=mmap) for v in node]
    return node


class SnapshotError(RuntimeError):
    """No loadable generation (missing, torn, or version-incompatible)."""


# ---------------------------------------------------------------------------
# Generations
# ---------------------------------------------------------------------------


def _gen_name(gen: int) -> str:
    return f"{_GEN_PREFIX}{gen:06d}"


def _gen_number(name: str) -> int:
    return int(name[len(_GEN_PREFIX):])


def list_generations(root: str) -> List[str]:
    """Completed generation names under ``root``, oldest first."""
    if not os.path.isdir(root):
        return []
    out = [
        e
        for e in os.listdir(root)
        if e.startswith(_GEN_PREFIX)
        and not e.endswith(".tmp")
        and os.path.isdir(os.path.join(root, e))
    ]
    return sorted(out, key=_gen_number)


def _atomic_write(path: str, data: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path))


@dataclasses.dataclass
class Snapshot:
    """A loaded generation: the index plus its serving-layer sidecar state."""

    index: HQIIndex
    live: Optional[np.ndarray]  # bool [db.n] tombstone mask (None = all live)
    wal_seq: int  # last WAL record folded into this snapshot
    generation: int
    path: str  # the generation directory


def build_state(index: HQIIndex, live: Optional[np.ndarray] = None) -> Dict[str, Any]:
    """Capture the snapshot state tree — array *references*, no blob I/O.

    Cheap enough to run under the serving layer's flush lock (the compactor
    does): index mutations are replacements, so captured references stay
    immutable while ``write_generation`` streams them to disk outside any
    lock.
    """
    state: Dict[str, Any] = {"index": index.to_state()}
    if live is not None:
        state["live"] = np.asarray(live, dtype=bool)
    return state


def save_snapshot(
    root: str,
    index: HQIIndex,
    *,
    live: Optional[np.ndarray] = None,
    wal_seq: int = 0,
) -> str:
    """Write one new generation; returns its name (e.g. ``gen-000002``).

    ``live`` is the serving layer's tombstone mask over ``index.db`` rows and
    ``wal_seq`` the last WAL record this snapshot covers — recovery replays
    only records after it. Both default to the bare-index case.
    """
    return write_generation(root, build_state(index, live), wal_seq=wal_seq)


def write_generation(
    root: str,
    state: Dict[str, Any],
    *,
    wal_seq: int = 0,
    meta: Optional[Dict[str, Any]] = None,
    set_current: bool = True,
) -> str:
    """Persist a captured state tree as the next generation (crash-safe).

    ``meta`` stamps free-form provenance into the manifest (the tuner records
    its trigger reason there). ``set_current=False`` writes the generation
    WITHOUT flipping ``CURRENT`` — the blue/green pattern: the tuner persists
    the candidate layout first and promotes it (``set_current()``) only after
    the in-memory swap succeeded, so a failed swap leaves restarts loading
    the generation that matches what is actually serving.
    """
    with get_tracer().span("snapshot.write", wal_seq=int(wal_seq)):
        with _WRITE_LOCK:
            return _write_generation(
                root, state, wal_seq=wal_seq, meta=meta, set_current=set_current
            )


def _write_generation(
    root: str,
    state: Dict[str, Any],
    *,
    wal_seq: int = 0,
    meta: Optional[Dict[str, Any]] = None,
    set_current: bool = True,
) -> str:
    os.makedirs(root, exist_ok=True)
    gens = list_generations(root)
    gen = (_gen_number(gens[-1]) + 1) if gens else 1
    name = _gen_name(gen)
    final_dir = os.path.join(root, name)
    tmp_dir = final_dir + ".tmp"
    # sweep a stale stage from a previous crashed save
    if os.path.isdir(tmp_dir):
        import shutil

        shutil.rmtree(tmp_dir)

    arrays: Dict[str, np.ndarray] = {}
    tree = _externalize(state, arrays, "")
    manifest = {
        "format": FORMAT,
        "version": VERSION,
        "generation": gen,
        "created_unix": time.time(),
        "wal_seq": int(wal_seq),
        "state": tree,
    }
    if meta is not None:
        manifest["meta"] = meta

    arrays_dir = os.path.join(tmp_dir, "arrays")
    os.makedirs(arrays_dir)
    for fname, arr in arrays.items():
        path = os.path.join(arrays_dir, fname)

        def _write_blob(path: str = path, arr: np.ndarray = arr) -> None:
            failpoint("snapshot.write")
            # "wb" truncates, so a retry after a partial write starts clean
            with open(path, "wb") as f:
                np.save(f, np.ascontiguousarray(arr))
                f.flush()
                os.fsync(f.fileno())

        # transient blob-I/O faults retry with bounded backoff; a failure
        # that outlives the budget aborts THIS generation only — the tmp dir
        # never renamed into place, CURRENT untouched, old generations intact
        with_retries(
            _write_blob,
            retry_on=(OSError,),
            on_retry=lambda _a, _e: get_registry()
            .counter("snapshot.write_retries")
            .inc(1),
        )
    # manifest LAST: its presence marks the generation complete
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp_dir)
    os.replace(tmp_dir, final_dir)
    _fsync_dir(root)
    if set_current:
        _atomic_write(os.path.join(root, "CURRENT"), name + "\n")
    return name


def current_generation(root: str) -> Optional[str]:
    """The generation name ``CURRENT`` points at, or None."""
    cpath = os.path.join(root, "CURRENT")
    if not os.path.isfile(cpath):
        return None
    with open(cpath) as f:
        name = f.read().strip()
    return name or None


def set_current(root: str, name: str) -> None:
    """Atomically repoint ``CURRENT`` at an existing, complete generation.

    The promotion half of a blue/green save (``write_generation(...,
    set_current=False)``) — and the demotion half of a rollback.
    """
    if _validate_generation(root, name) is None:
        raise SnapshotError(f"cannot promote {name!r}: not a loadable generation")
    _atomic_write(os.path.join(root, "CURRENT"), name + "\n")


# ---------------------------------------------------------------------------
# Pinning — generations retention must not collect (rollback targets)
# ---------------------------------------------------------------------------


def pinned_generations(root: str) -> Set[str]:
    """Generation names listed in ``<root>/PINNED`` (one per line)."""
    path = os.path.join(root, _PINNED_FILE)
    if not os.path.isfile(path):
        return set()
    with open(path) as f:
        return {line.strip() for line in f if line.strip()}


def _write_pinned(root: str, names: Set[str]) -> None:
    path = os.path.join(root, _PINNED_FILE)
    if not names:
        if os.path.isfile(path):
            os.remove(path)
            _fsync_dir(root)
        return
    _atomic_write(path, "".join(n + "\n" for n in sorted(names)))


def pin_generation(root: str, name: str) -> None:
    """Shield ``name`` from ``prune_generations`` until unpinned.

    Durable (a ``PINNED`` file beside ``CURRENT``), so every pruner in every
    process respects it — the tuner pins the displaced generation after a
    swap so instant rollback survives however many compaction cycles run
    in between.
    """
    _write_pinned(root, pinned_generations(root) | {name})


def unpin_generation(root: str, name: str) -> None:
    """Release a pin; a no-op when ``name`` was not pinned."""
    _write_pinned(root, pinned_generations(root) - {name})


def _validate_generation(root: str, name: str) -> Optional[dict]:
    """Parsed manifest if the generation is complete and loadable, else None."""
    gen_dir = os.path.join(root, name)
    mpath = os.path.join(gen_dir, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if manifest.get("format") != FORMAT or manifest.get("version") != VERSION:
        return None
    arrays_dir = os.path.join(gen_dir, "arrays")

    def blobs_ok(node: Any) -> bool:
        if isinstance(node, dict):
            if _ARRAY_KEY in node:
                fname = node[_ARRAY_KEY]
                if os.path.basename(fname) != fname or fname.startswith(".."):
                    return False
                path = os.path.join(arrays_dir, fname)
                if not os.path.isfile(path):
                    return False
                # npy header (~128 B) + payload; a short file is a torn write
                expect = int(np.prod(node["shape"])) * np.dtype(node["dtype"]).itemsize
                return os.path.getsize(path) >= expect
            return all(blobs_ok(v) for v in node.values())
        if isinstance(node, list):
            return all(blobs_ok(v) for v in node)
        return True

    return manifest if blobs_ok(manifest.get("state", {})) else None


def load_snapshot(root: str, *, mmap: bool = True) -> Snapshot:
    """Load the newest valid generation (``CURRENT`` first, then fallback).

    Raises ``SnapshotError`` when no generation is loadable. ``mmap=False``
    forces full in-memory loads (tests / copying a snapshot elsewhere).
    """
    with get_tracer().span("snapshot.load"):
        return _load_snapshot(root, mmap=mmap)


def _load_snapshot(root: str, *, mmap: bool = True) -> Snapshot:
    failpoint("snapshot.load")
    candidates: List[str] = []
    current = os.path.join(root, "CURRENT")
    if os.path.isfile(current):
        with open(current) as f:
            candidates.append(f.read().strip())
    for name in reversed(list_generations(root)):
        if name not in candidates:
            candidates.append(name)
    errors = []
    for name in candidates:
        manifest = _validate_generation(root, name)
        if manifest is None:
            continue
        gen_dir = os.path.join(root, name)
        try:
            state = _internalize(
                manifest["state"], os.path.join(gen_dir, "arrays"), mmap=mmap
            )
            live = state.get("live")
            return Snapshot(
                index=HQIIndex.from_state(state["index"]),
                live=None if live is None else np.asarray(live),
                wal_seq=int(manifest["wal_seq"]),
                generation=int(manifest["generation"]),
                path=gen_dir,
            )
        except Exception as e:
            # a blob torn inside the validator's size margin (npy header) or
            # any other decode failure: this generation is damaged goods —
            # fall back to the next-newest candidate instead of failing a
            # restart that an older, fully-valid generation could serve
            errors.append(f"{name}: {e!r}")
    raise SnapshotError(
        f"no loadable snapshot generation under {root!r}"
        + (f" (damaged candidates: {'; '.join(errors)})" if errors else "")
    )


def prune_generations(
    root: str, keep: int = 2, *, pinned: Iterable[str] = ()
) -> List[str]:
    """Delete all but the newest ``keep`` generations; returns deleted names.

    ``keep=0`` prunes *everything* except the survivors below (it used to be
    a silent no-op, which let "prune all history" calls leak disk forever).
    Negative ``keep`` raises ``ValueError``.

    Never deletes: the generation ``CURRENT`` points at (what a concurrent
    loader follows), names passed via ``pinned``, or names recorded in the
    on-disk ``PINNED`` file (the tuner's rollback targets — see
    ``pin_generation``).
    """
    import shutil

    if keep < 0:
        raise ValueError(f"keep must be >= 0, got {keep}")
    gens = list_generations(root)
    current = current_generation(root)
    pins = set(pinned) | pinned_generations(root)
    cut = gens if keep == 0 else gens[:-keep]
    doomed = [g for g in cut if g != current and g not in pins]
    for name in doomed:
        shutil.rmtree(os.path.join(root, name), ignore_errors=True)
    # sweep stale stages too
    for e in os.listdir(root) if os.path.isdir(root) else []:
        if e.startswith(_GEN_PREFIX) and e.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, e), ignore_errors=True)
    return doomed
