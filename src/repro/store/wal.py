"""Append-only write-ahead log for the serving layer's inserts and deletes.

``HQIService`` keeps live writes in a host-side ``DeltaStore`` between
``refresh()`` folds — exactly the state a crash loses. With a WAL attached,
``insert``/``delete`` append a durable record *before* acknowledging, so any
write the caller ever saw survives a crash: recovery loads the newest
snapshot and replays the WAL tail into a fresh delta store
(store/recovery.py), reproducing the same external ids bit-for-bit.

Record framing (binary, little-endian), one record per committed write:

    u32 magic   "WAL1"
    u64 seq     monotonically increasing across segments
    u8  kind    1 = insert, 2 = delete
    u32 len     payload byte length
    u32 crc32   of the payload bytes
    len bytes   payload: np.savez archive of named arrays (vectors, ids,
                per-column values/null-masks for inserts; ids for deletes)

A torn tail — the process died mid-append — fails the length or CRC check.
In the FINAL segment that is the expected crash signature: replay stops
there, acknowledged records are intact (they were flushed before the ack)
and the unacknowledged fragment is cleanly dropped. A bad frame in a SEALED
(non-final) segment is bit rot, not a torn append — replay raises
``WalCorruptionError`` rather than silently skipping the acknowledged
records behind it.

Segments: records append to ``wal-<first_seq>.log``; ``rotate()`` (called by
``refresh()``) seals the current segment and starts the next, so compaction
can ``prune(upto_seq)`` whole sealed segments once a snapshot covers them.
"""
from __future__ import annotations

import dataclasses
import io
import os
import struct
import threading
import time
import zlib
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..fault.failpoints import failpoint
from ..fault.retry import with_retries
from ..obs.metrics import get_registry
from ..obs.trace import get_tracer

_MAGIC = 0x57414C31  # "WAL1"
_HEADER = struct.Struct("<IQBII")  # magic, seq, kind, len, crc32

KIND_SEAL = 0  # segment terminator written by rotate(); empty payload
KIND_INSERT = 1
KIND_DELETE = 2

_SEG_PREFIX = "wal-"


class WalCorruptionError(RuntimeError):
    """A sealed segment holds a bad frame: records behind it are unreachable."""


class WalPoisonedError(RuntimeError):
    """The WAL quarantined itself: a group-commit fsync failed past its retry
    budget, so durability can no longer be promised. Writes fail fast with
    this error (``cause`` is the original I/O failure); reads — ``replay``,
    ``segments`` — keep working, and ``clear_poison()`` re-opens the write
    path once the operator has fixed the device."""

    def __init__(self, message: str, *, cause: Optional[BaseException] = None) -> None:
        super().__init__(message)
        self.cause = cause
        if cause is not None:
            self.__cause__ = cause


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _ends_with_seal(data: bytes) -> bool:
    """Does the segment end with an intact seal frame (written by rotate)?

    The durable marker distinguishing 'sealed segment with interior bit rot'
    (replay must raise — acknowledged records sit behind the damage) from
    'open segment with a crash-torn tail' (repairable by truncation).
    """
    if len(data) < _HEADER.size:
        return False
    magic, _seq, kind, plen, crc = _HEADER.unpack_from(data, len(data) - _HEADER.size)
    return magic == _MAGIC and kind == KIND_SEAL and plen == 0 and crc == 0


@dataclasses.dataclass
class WalRecord:
    seq: int
    kind: int  # KIND_INSERT | KIND_DELETE
    arrays: Dict[str, np.ndarray]


def _seg_name(first_seq: int) -> str:
    return f"{_SEG_PREFIX}{first_seq:020d}.log"


def _seg_first_seq(name: str) -> int:
    return int(name[len(_SEG_PREFIX):-len(".log")])


def _encode_payload(arrays: Dict[str, np.ndarray]) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.ascontiguousarray(v) for k, v in arrays.items()})
    return buf.getvalue()


def _decode_payload(payload: bytes) -> Dict[str, np.ndarray]:
    with np.load(io.BytesIO(payload)) as z:
        return {k: z[k] for k in z.files}


def _scan_intact(data: bytes) -> Tuple[int, int]:
    """(byte offset after the last intact record, its seq; 0s when none)."""
    off, last_seq = 0, 0
    while off + _HEADER.size <= len(data):
        magic, seq, _, plen, crc = _HEADER.unpack_from(data, off)
        if magic != _MAGIC:
            break
        payload = data[off + _HEADER.size : off + _HEADER.size + plen]
        if len(payload) < plen or zlib.crc32(payload) != crc:
            break
        off += _HEADER.size + plen
        last_seq = seq
    return off, last_seq


class WriteAheadLog:
    """Single-writer append log over a directory of sealed + one open segment.

    ``sync=True`` (default) fsyncs before any record is acknowledged — the
    durability contract the service's ack depends on. Benchmarks may relax
    it; the frame CRC still bounds the damage to the unsynced tail.

    Group commit: ``stage()`` writes a frame (assigning its seq) without
    fsyncing; ``sync_upto(seq)`` makes it durable, and concurrent callers
    share ONE fsync — the first waiter becomes the leader, fsyncs everything
    staged so far, and wakes the rest, so N threads committing concurrently
    pay ~1 fsync instead of N (the classic WAL group commit). ``append`` is
    stage + sync_upto, preserving the single-caller contract unchanged.
    """

    def __init__(
        self, path: str, *, sync: bool = True, fsync_retries: int = 3
    ) -> None:
        self.path = path
        self.sync = bool(sync)
        # transient-fault budget for the group-commit fsync (repro.fault):
        # an fsync that keeps failing past this many attempts (exponential
        # backoff + jitter between them) poisons the log — see ``poisoned``
        self.fsync_retries = max(1, int(fsync_retries))
        self.poisoned: Optional[BaseException] = None
        # retention floor: while set, prune() keeps every record with
        # seq > pin_seq on disk. The index-evolution tuner pins the seq its
        # off-to-the-side build covers so the compactor can't collect the
        # tail the blue/green swap still has to replay.
        self.pin_seq: Optional[int] = None
        os.makedirs(path, exist_ok=True)
        self._fh: Optional[io.BufferedWriter] = None
        self._seg: Optional[str] = None
        self.last_seq = 0
        # group-commit state: _mu orders frame writes (seq assignment must
        # match file order — replay equates the two); _cv hands off the
        # fsync leadership; _synced_seq is the durable high-water mark
        self._mu = threading.RLock()
        self._cv = threading.Condition(self._mu)
        self._sync_leader = False
        segs = self.segments()
        open_last = False
        for name in segs:
            full = os.path.join(path, name)
            with open(full, "rb") as f:
                data = f.read()
            end, last = _scan_intact(data)
            if last:
                self.last_seq = last
            is_final = name == segs[-1]
            sealed = _ends_with_seal(data)
            if end < len(data) and is_final and not sealed:
                # torn tail from a crash mid-append in the OPEN segment: drop
                # the unacknowledged fragment so the segment stays appendable.
                # Sealed segments (terminated by rotate()'s seal frame) are
                # never repaired — a bad frame there is bit rot over
                # acknowledged records and replay() raises instead.
                with open(full, "r+b") as f:
                    f.truncate(end)
                data = data[:end]
                sealed = _ends_with_seal(data)
            if is_final:
                # resume appending only into an UNSEALED final segment; after
                # a seal the next append starts a fresh segment
                open_last = not sealed
        if open_last:
            self._open_segment(segs[-1])
        self._synced_seq = self.last_seq  # everything on disk is durable

    # ------------------------------------------------------------------ write

    def _open_segment(self, name: str) -> None:
        if self._fh is not None:
            self._fh.close()
        self._seg = name
        self._fh = open(os.path.join(self.path, name), "ab")
        if self.sync:
            # make the directory entry itself durable: fsyncing the FILE
            # does not persist its existence in wal/ — without this, a
            # power loss after the ack could lose the whole new segment
            _fsync_dir(self.path)

    def stage(self, kind: int, arrays: Dict[str, np.ndarray]) -> int:
        """Write one record to the OS (ordered, CRC-framed) without fsync.

        Returns its sequence number; the record is NOT durable until a
        ``sync_upto`` covering that seq returns. The payload is encoded
        outside the lock, so concurrent stagers only serialize on the
        actual frame write (which fixes seq order = file order = replay
        order, the invariant recovery's id-stability assert depends on).
        """
        if self.poisoned is not None:
            raise WalPoisonedError(
                "WAL quarantined after unrecoverable fsync failure",
                cause=self.poisoned,
            )
        failpoint("wal.stage")
        payload = _encode_payload(arrays)
        with self._mu:
            if self._fh is None:
                self._open_segment(_seg_name(self.last_seq + 1))
            seq = self.last_seq + 1
            frame = _HEADER.pack(_MAGIC, seq, kind, len(payload), zlib.crc32(payload))
            self._fh.write(frame + payload)
            self._fh.flush()
            self.last_seq = seq
        return seq

    def sync_upto(self, seq: int) -> int:
        """Block until record ``seq`` is durable; batches concurrent callers.

        The first caller to find ``seq`` unsynced becomes the leader: it
        fsyncs ONCE, covering every record staged up to that moment, then
        wakes all waiters — whoever's seq the batch covered returns without
        issuing its own fsync. Returns the durable high-water mark.
        """
        if not self.sync:
            return self.last_seq
        with self._cv:
            while True:
                if self._synced_seq >= seq:
                    return self._synced_seq
                if not self._sync_leader:
                    break  # take leadership for the next fsync batch
                self._cv.wait()
            self._sync_leader = True
            fh = self._fh
            upto = self.last_seq
        ok = False
        err: Optional[BaseException] = None
        try:
            if fh is not None:
                with get_tracer().span("wal.fsync", upto=upto):
                    t0 = time.perf_counter()

                    def _sync() -> None:
                        failpoint("wal.fsync")
                        os.fsync(fh.fileno())

                    try:
                        # transient I/O faults are retried with bounded
                        # exponential backoff; a failure that outlives the
                        # budget poisons the log (durability can no longer be
                        # promised) and propagates to every caller whose
                        # record this batch covered
                        with_retries(
                            _sync,
                            attempts=self.fsync_retries,
                            retry_on=(OSError,),
                            on_retry=lambda _a, _e: get_registry()
                            .counter("wal.fsync_retries")
                            .inc(1),
                        )
                    except BaseException as e:
                        err = e
                        raise
                    get_registry().histogram("wal.fsync_s").observe(
                        time.perf_counter() - t0
                    )
            ok = True
        finally:
            with self._cv:
                self._sync_leader = False
                if ok and fh is not None:
                    self._synced_seq = max(self._synced_seq, upto)
                elif err is not None and not isinstance(err, KeyboardInterrupt):
                    self.poisoned = err
                self._cv.notify_all()
        return self._synced_seq

    @property
    def synced_seq(self) -> int:
        """Durable high-water mark: the largest seq an ack may cover."""
        return self._synced_seq

    def clear_poison(self) -> None:
        """Operator hook: re-open the write path after fixing the device.

        Safe because a poisoned fsync never advanced ``_synced_seq`` — any
        record the failure left non-durable was never acknowledged, and the
        next successful group fsync covers it or its torn remains truncate
        on restart.
        """
        self.poisoned = None

    def append(self, kind: int, arrays: Dict[str, np.ndarray]) -> int:
        """Commit one record durably; returns its sequence number."""
        seq = self.stage(kind, arrays)
        self.sync_upto(seq)
        return seq

    def rotate(self) -> None:
        """Seal the open segment; the next append starts a fresh one.

        Called at ``refresh()`` so sealed segments map onto fold boundaries
        and compaction can drop them wholesale once a snapshot covers them.
        Writes a durable seal frame — the marker that tells a later reopen
        this segment's content is complete (a bad frame inside it is bit
        rot to surface, not a torn tail to truncate).
        """
        with self._cv, get_tracer().span("wal.rotate"):
            while self._sync_leader:
                # an in-flight group fsync holds the segment's fd; closing
                # it under the leader would fsync a dead descriptor
                self._cv.wait()
            if self._fh is not None:
                self._fh.write(_HEADER.pack(_MAGIC, self.last_seq, KIND_SEAL, 0, 0))
                self._fh.flush()
                if self.sync:
                    os.fsync(self._fh.fileno())
                self._fh.close()
                self._fh = None
                self._seg = None
            self._synced_seq = max(self._synced_seq, self.last_seq)
            self._cv.notify_all()

    def close(self) -> None:
        self.rotate()

    # ------------------------------------------- service-facing commit helpers

    def log_insert(
        self,
        vectors: np.ndarray,
        ids: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> int:
        """Commit one acknowledged insert batch (ids as the service assigned)."""
        return self.append(KIND_INSERT, insert_arrays(vectors, ids, columns, null_masks))

    def log_delete(self, ids) -> int:
        """Commit one acknowledged delete request (replay is idempotent)."""
        return self.append(
            KIND_DELETE, {"ids": np.atleast_1d(np.asarray(ids, dtype=np.int64))}
        )

    def stage_insert(
        self,
        vectors: np.ndarray,
        ids: np.ndarray,
        columns: Optional[Dict[str, np.ndarray]] = None,
        null_masks: Optional[Dict[str, np.ndarray]] = None,
    ) -> int:
        """Stage an insert for group commit; durable after ``sync_upto``."""
        return self.stage(KIND_INSERT, insert_arrays(vectors, ids, columns, null_masks))

    def stage_delete(self, ids) -> int:
        """Stage a delete for group commit; durable after ``sync_upto``."""
        return self.stage(
            KIND_DELETE, {"ids": np.atleast_1d(np.asarray(ids, dtype=np.int64))}
        )

    # ------------------------------------------------------------------- read

    def segments(self) -> List[str]:
        out = [
            e
            for e in os.listdir(self.path)
            if e.startswith(_SEG_PREFIX) and e.endswith(".log")
        ]
        return sorted(out, key=_seg_first_seq)

    def replay(self, after_seq: int = 0) -> Iterator[WalRecord]:
        """Yield intact records with seq > ``after_seq``, in commit order.

        A bad frame in an UNSEALED final segment is the crash-torn tail:
        replay stops cleanly there (everything before it was acknowledged
        and survives; the fragment never was). A bad frame anywhere else —
        a segment rotate() terminated with its seal frame, or a non-final
        segment — raises ``WalCorruptionError``: acknowledged records sit
        behind the damage and must not be silently skipped.
        """
        segs = self.segments()
        for i, name in enumerate(segs):
            is_final = name == segs[-1]
            if not is_final and _seg_first_seq(segs[i + 1]) <= after_seq + 1:
                # every record here has seq < the successor's first, all of
                # them <= after_seq: fully covered by the caller's snapshot,
                # retained only for older generations — skip without reading
                # (so bit rot in a covered segment can't block a restart the
                # newest snapshot + tail could fully serve)
                continue
            with open(os.path.join(self.path, name), "rb") as f:
                data = f.read()
            torn_ok = is_final and not _ends_with_seal(data)
            off = 0
            while off + _HEADER.size <= len(data):
                magic, seq, kind, plen, crc = _HEADER.unpack_from(data, off)
                payload = data[off + _HEADER.size : off + _HEADER.size + plen]
                bad = (
                    magic != _MAGIC
                    or len(payload) < plen
                    or zlib.crc32(payload) != crc
                )
                if bad:
                    if torn_ok:
                        return  # torn tail: drop the unacknowledged fragment
                    raise WalCorruptionError(
                        f"bad frame at byte {off} of sealed segment {name}; "
                        f"acknowledged records behind it would be lost"
                    )
                off += _HEADER.size + plen
                if kind != KIND_SEAL and seq > after_seq:
                    yield WalRecord(seq=seq, kind=kind, arrays=_decode_payload(payload))
            if off != len(data):  # trailing partial header
                if torn_ok:
                    return
                raise WalCorruptionError(
                    f"partial frame header at byte {off} of sealed segment {name}"
                )

    # ------------------------------------------------------------------ prune

    def prune(self, upto_seq: int) -> List[str]:
        """Delete sealed segments fully covered by a snapshot; returns names.

        A segment is deletable when every record it holds has
        seq <= ``upto_seq`` — i.e. the NEXT segment starts at or below
        ``upto_seq + 1`` — and it is not the open segment. ``pin_seq``
        (when set) clamps the horizon so records a pending swap must replay
        survive any concurrent pruner.
        """
        if self.pin_seq is not None:
            upto_seq = min(int(upto_seq), int(self.pin_seq))
        segs = self.segments()
        doomed: List[str] = []
        for i, name in enumerate(segs):
            nxt = _seg_first_seq(segs[i + 1]) if i + 1 < len(segs) else self.last_seq + 1
            if name != self._seg and nxt <= upto_seq + 1:
                doomed.append(name)
        for name in doomed:
            os.remove(os.path.join(self.path, name))
        return doomed


# ---------------------------------------------------------------------------
# Record payload helpers (shared by service.py's commit and recovery's replay)
# ---------------------------------------------------------------------------


def insert_arrays(
    vectors: np.ndarray,
    ids: np.ndarray,
    columns: Optional[Dict[str, np.ndarray]],
    null_masks: Optional[Dict[str, np.ndarray]],
) -> Dict[str, np.ndarray]:
    out = {
        "vectors": np.atleast_2d(np.asarray(vectors, dtype=np.float32)),
        "ids": np.asarray(ids, dtype=np.int64),
    }
    for name, vals in (columns or {}).items():
        out[f"col.{name}"] = np.asarray(vals)
    for name, nm in (null_masks or {}).items():
        out[f"nm.{name}"] = np.asarray(nm)
    return out


def split_insert_arrays(
    arrays: Dict[str, np.ndarray]
) -> Tuple[np.ndarray, np.ndarray, Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    """(vectors, ids, columns, null_masks) back out of an insert record."""
    columns = {
        k[len("col."):]: v for k, v in arrays.items() if k.startswith("col.")
    }
    null_masks = {k[len("nm."):]: v for k, v in arrays.items() if k.startswith("nm.")}
    return arrays["vectors"], arrays["ids"], columns, null_masks
