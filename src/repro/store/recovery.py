"""Crash recovery: snapshot load + WAL tail replay → a serving ``HQIService``.

The contract ``open_service`` restores after a crash (or a clean restart):

  * every **acknowledged** write is present — an insert whose ids were
    returned, a delete that returned — because the service committed it to
    the WAL before acknowledging;
  * every **unacknowledged** fragment (a record torn mid-append by the
    crash) is cleanly dropped (frame CRC, see wal.py);
  * external ids are **bit-identical** to the uncrashed process: replayed
    inserts re-enter the delta store in commit order, so id assignment
    (``first_id + position``) reproduces exactly — recovery *verifies* this
    against the ids each record logged at commit time;
  * query results match the uncrashed process: the snapshot restores the
    index (and its arena / router cache) byte-for-byte via mmap, and the
    replayed delta scans through the same flush path.

Store layout under one root directory (see snapshot.py for generations):

    root/
      CURRENT, gen-*/          # snapshot generations
      wal/wal-*.log            # the write-ahead log

``init_store`` bootstraps that layout around a freshly built index;
``open_service`` is the restart path. Compaction (compact.py) keeps the WAL
tail short by folding + re-snapshotting in the background.
"""
from __future__ import annotations

import os
from typing import Optional

import numpy as np

from ..service.service import HQIService, ServiceConfig
from .snapshot import Snapshot, load_snapshot, save_snapshot
from .wal import KIND_DELETE, KIND_INSERT, WriteAheadLog, split_insert_arrays


class RecoveryError(RuntimeError):
    """Replay diverged from the committed log (id mismatch / unknown record)."""


def wal_dir(root: str) -> str:
    return os.path.join(root, "wal")


def init_store(
    root: str,
    index,
    *,
    cfg: Optional[ServiceConfig] = None,
    sync: bool = True,
) -> HQIService:
    """Bootstrap a persistent store around a freshly built index.

    Writes a new snapshot generation, opens the WAL, and returns an
    ``HQIService`` committing every write through it. The WAL is opened
    FIRST and the snapshot stamped with its current seq: re-initializing
    over a previously used root must not leave the old incarnation's
    records replayable into the new index (they describe rows it never
    held) — they are marked covered instead, and new commits continue
    above them.
    """
    wal = WriteAheadLog(wal_dir(root), sync=sync)
    save_snapshot(
        root, index, live=np.ones(index.db.n, dtype=bool), wal_seq=wal.last_seq
    )
    return HQIService(index, cfg, wal=wal)


def replay_into(svc: HQIService, wal: WriteAheadLog, *, after_seq: int = 0) -> int:
    """Apply the WAL tail to a freshly loaded service; returns #records.

    Records enter through the same state transitions the live service used
    (delta append / tombstone), but WITHOUT re-logging. Insert replay asserts
    that the ids the delta store assigns now equal the ids the service
    acknowledged then — the external-id stability guarantee.
    """
    n = 0
    with svc._lock:
        for rec in wal.replay(after_seq):
            if rec.kind == KIND_INSERT:
                vectors, ids, columns, null_masks = split_insert_arrays(rec.arrays)
                got = svc.delta.insert(vectors, columns or None, null_masks or None)
                if not np.array_equal(got, ids):
                    raise RecoveryError(
                        f"WAL record {rec.seq}: replayed insert ids "
                        f"{got.tolist()} != committed ids {ids.tolist()}"
                    )
            elif rec.kind == KIND_DELETE:
                svc._delete_locked(rec.arrays["ids"])
            else:
                raise RecoveryError(f"WAL record {rec.seq}: unknown kind {rec.kind}")
            n += 1
    return n


def open_service(
    root: str,
    *,
    cfg: Optional[ServiceConfig] = None,
    sync: bool = True,
    mmap: bool = True,
) -> HQIService:
    """Load the newest valid snapshot, replay the WAL tail, resume serving.

    The returned service answers queries bit-identically to an uncrashed
    process: snapshot state is mmap'd (O(metadata) load), acknowledged
    writes after the snapshot re-enter the delta store in commit order, and
    the WAL stays attached so new writes keep committing durably.
    """
    snap: Snapshot = load_snapshot(root, mmap=mmap)
    svc = HQIService(snap.index, cfg)
    if snap.live is not None:
        # writable copy: tombstones mutate the mask in place, mmap is read-only
        svc._live = np.array(snap.live, dtype=bool)
    wal = WriteAheadLog(wal_dir(root), sync=sync)
    # compaction may have pruned EVERY segment (snapshot covers them all);
    # new commits must continue above the snapshot's seq, never restart at 1,
    # or the next recovery would skip them as already-covered
    wal.last_seq = max(wal.last_seq, snap.wal_seq)
    replay_into(svc, wal, after_seq=snap.wal_seq)
    svc.wal = wal
    svc._wal_folded_seq = snap.wal_seq
    # every record on disk is now applied (replayed or snapshot-covered); a
    # fold may claim up to here — the group-commit apply path advances it
    svc._applied_seq = wal.last_seq
    return svc
