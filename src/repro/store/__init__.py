"""Index persistence & recovery: versioned snapshots, serving WAL, compaction.

Public API:
    save_snapshot / load_snapshot / Snapshot — versioned manifest + .npy
        generations of a full ``HQIIndex`` (+ live mask), mmap'd zero-copy
        on load; build_state / write_generation split capture from blob I/O
    list_generations / prune_generations — generation lifecycle
    current_generation / set_current / pin_generation / unpin_generation /
        pinned_generations — blue/green promotion + rollback-target pinning
    WriteAheadLog / WalRecord — append-only commit log for serving writes
    init_store / open_service / replay_into — bootstrap + crash recovery
    Compactor — background fold → snapshot → prune loop
"""
from .compact import Compactor  # noqa: F401
from .recovery import (  # noqa: F401
    RecoveryError,
    init_store,
    open_service,
    replay_into,
    wal_dir,
)
from .snapshot import (  # noqa: F401
    Snapshot,
    SnapshotError,
    build_state,
    current_generation,
    list_generations,
    load_snapshot,
    pin_generation,
    pinned_generations,
    prune_generations,
    save_snapshot,
    set_current,
    unpin_generation,
    write_generation,
)
from .wal import (  # noqa: F401
    KIND_DELETE,
    KIND_INSERT,
    WalCorruptionError,
    WalPoisonedError,
    WalRecord,
    WriteAheadLog,
)
