"""Background compaction: fold the delta, snapshot a fresh generation, prune.

Between refreshes the write working set lives in the DeltaStore and the WAL
tail grows per commit; restart cost is snapshot-load + WAL-replay. The
compactor bounds that tail: periodically (or when the delta outgrows
``min_delta_rows``) it

  1. folds the delta into the index partitions via the service's existing
     incremental ``refresh()`` path (qd-tree leaf routing + IVF append +
     ``PackedArena.updated`` — never a rebuild), which also seals the WAL
     segment at the fold boundary;
  2. captures (index state, live mask, folded WAL seq) under the flush lock
     — a consistent point: refresh is excluded, and concurrent writes land
     in the delta + WAL *after* the captured seq, so recovery replays them;
  3. writes a new snapshot generation OUTSIDE the service locks (tmp-dir +
     atomic rename + CURRENT swap, see snapshot.py) — flushes and writes
     proceed while the blobs stream to disk;
  4. prunes old generations (keeping ``keep_generations``) and deletes WAL
     segments every remaining generation already covers.

Snapshotting at fold points is also what keeps recovery *bit-identical*
under approximate search: every row is either in the snapshot's partitions
or in the replayed delta, exactly as in the uncrashed process.
"""
from __future__ import annotations

import threading
from typing import List, Optional

from ..fault.failpoints import failpoint
from ..obs.metrics import get_registry
from ..obs.trace import get_tracer, set_thread_name
from ..service.service import HQIService
from .snapshot import (
    build_state,
    list_generations,
    prune_generations,
    write_generation,
)
from .wal import WriteAheadLog


class Compactor:
    """Folds + snapshots an ``HQIService`` store in the background.

    Drive it synchronously (``compact_once``) or as a daemon thread
    (``start``/``stop``). One compactor per store root; compaction never
    blocks readers for longer than the in-memory fold (step 2 holds the
    flush lock only to capture array *references* — blob writing happens
    outside).
    """

    def __init__(
        self,
        service: HQIService,
        root: str,
        *,
        interval_s: float = 30.0,
        min_delta_rows: int = 1,
        keep_generations: int = 2,
        max_backoff_s: float = 300.0,
    ) -> None:
        assert service.wal is not None, "compaction needs a WAL-backed service"
        self.service = service
        self.root = root
        self.interval_s = float(interval_s)
        self.min_delta_rows = int(min_delta_rows)
        self.keep_generations = int(keep_generations)
        # failure backoff (repro.fault): after N consecutive failed cycles the
        # background loop waits interval_s · 2^N (capped) before retrying — a
        # persistently failing snapshot disk must not be hammered every tick
        self.max_backoff_s = float(max_backoff_s)
        self.consecutive_failures = 0
        self.generations_written = 0
        self.last_error: Optional[BaseException] = None  # background health
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()
        # surface compactor health in the process registry (obsdump shows it)
        get_registry().attach_source("compactor", self._metrics)
        # back-ref for HQIService.health()'s compactor fields
        service._compactor = self

    def _metrics(self) -> dict:
        return {
            "generations_written": self.generations_written,
            "consecutive_failures": self.consecutive_failures,
            "last_error": None if self.last_error is None else repr(self.last_error),
            "backoff_s": self._backoff_s(),
        }

    def _backoff_s(self) -> float:
        """Current inter-cycle delay: interval, exponentially inflated by
        consecutive failures, capped at ``max_backoff_s``."""
        if self.consecutive_failures == 0:
            return self.interval_s
        return min(
            self.max_backoff_s,
            self.interval_s * (2.0 ** self.consecutive_failures),
        )

    # ------------------------------------------------------------------ once

    def compact_once(self, force: bool = False) -> Optional[str]:
        """One fold → snapshot → prune cycle; returns the new generation name.

        Returns None when the delta is below ``min_delta_rows`` (nothing
        worth folding) and ``force`` is False. Failure accounting lives here
        (not only in the background loop) so synchronously driven compactors
        report the same ``consecutive_failures``/``last_error`` health.
        """
        try:
            name = self._compact_once(force)
        except Exception as e:
            self.consecutive_failures += 1
            self.last_error = e
            raise
        else:
            self.consecutive_failures = 0
            self.last_error = None
            return name

    def _compact_once(self, force: bool = False) -> Optional[str]:
        svc = self.service
        with get_tracer().span("compact"):
            failpoint("compact.cycle")
            with svc._flush_lock:
                with svc._lock:
                    pending = svc.delta.n
                if pending < self.min_delta_rows and not force:
                    return None
                svc._refresh_locked()  # folds + seals the WAL segment
                with svc._lock:
                    # capture the state tree — array REFERENCES, no blob I/O.
                    # Index mutations are replacements (extend swaps arrays),
                    # so the captured refs stay immutable after the locks drop
                    # and the blobs stream to disk without blocking the
                    # service.
                    state = build_state(svc.index, live=svc._live.copy())
                    wal_seq = svc._wal_folded_seq
            name = write_generation(self.root, state, wal_seq=wal_seq)
            self.generations_written += 1
            self._prune(wal_seq)
            return name

    def _prune(self, newest_covered_seq: int) -> None:
        prune_generations(self.root, keep=self.keep_generations)
        # WAL segments are deletable once the OLDEST remaining generation
        # covers them — an operator rolling back to it must still replay
        # everything after its wal_seq. With keep_generations snapshots at
        # monotone wal_seqs, that is the (keep-1)-back snapshot's seq; being
        # conservative, prune only below the oldest remaining generation.
        import json
        import os

        kept = list_generations(self.root)
        seqs: List[int] = []
        for g in kept:
            try:
                with open(os.path.join(self.root, g, "manifest.json")) as f:
                    seqs.append(int(json.load(f)["wal_seq"]))
            except (OSError, ValueError, KeyError):
                seqs.append(0)
        covered = min(seqs) if seqs else newest_covered_seq
        wal: WriteAheadLog = self.service.wal
        wal.prune(covered)

    # ------------------------------------------------------------ background

    def start(self) -> None:
        """Run ``compact_once`` on a daemon thread every ``interval_s``."""
        assert self._thread is None, "compactor already running"
        self._stop_flag.clear()

        def loop() -> None:
            set_thread_name("compactor")  # root spans tagged for trace triage
            while not self._stop_flag.wait(self._backoff_s()):
                try:
                    self.compact_once()
                except Exception:  # keep compacting through transients
                    # (disk full, etc.): the service must outlive its
                    # compactor. compact_once already recorded last_error and
                    # bumped consecutive_failures — the next wait backs off
                    # exponentially instead of hammering a failing disk
                    pass

        self._thread = threading.Thread(target=loop, name="hqi-compactor", daemon=True)
        self._thread.start()

    def stop(self, final_compact: bool = True) -> None:
        """Stop the thread; optionally snapshot whatever is pending first."""
        if self._thread is not None:
            self._stop_flag.set()
            self._thread.join()
            self._thread = None
        if final_compact:
            self.compact_once()
