"""Pallas TPU kernel: fused ADC scan (PQ lookup-table distances) + top-k.

The per-query LUT ([M, 256] f32 ≤ 64 KB) stays resident in VMEM while uint8
code tiles stream from HBM; scores accumulate as M gathers and fold into the
same running-top-k scratch as fused_knn. HBM traffic per query tile is the
CODE bytes (d·4/M× less than raw vectors) — this is the paper-family
(FAISS IVF-PQ) scan, TPU-shaped.

Gather note: Mosaic supports small-table gathers via one-hot matmul when
dynamic gather is unavailable; we express the lookup as
one_hot(codes) @ lutᵀ per subspace — an MXU-friendly [TV,256]×[256,1]
contraction batched over M (interpret mode validates numerics either way).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_knn import NEG_INF, _merge_topk


def _pq_scan_kernel(
    lut_ref,  # [M, 256] f32 — ONE query's tables
    codes_ref,  # [TV, M] int32
    valid_ref,  # [1, TV] int32
    out_s_ref,  # [1, K]
    out_i_ref,  # [1, K]
    acc_s_ref,  # scratch [1, K]
    acc_i_ref,  # scratch [1, K]
    *,
    k: int,
    tv: int,
    m: int,
    nv_tiles: int,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_s_ref[...] = jnp.full(acc_s_ref.shape, NEG_INF, jnp.float32)
        acc_i_ref[...] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    codes = codes_ref[...]  # [TV, M]
    lut = lut_ref[...]  # [M, 256]
    # LUT gather as one-hot matmul per subspace (MXU-friendly, Mosaic-safe)
    scores = jnp.zeros((codes.shape[0],), jnp.float32)
    for sub in range(m):
        onehot = (
            codes[:, sub][:, None] == jax.lax.broadcasted_iota(jnp.int32, (tv, 256), 1)
        ).astype(jnp.float32)
        scores = scores + jax.lax.dot_general(
            onehot, lut[sub], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
    valid = valid_ref[0, :] != 0
    scores = jnp.where(valid, scores, NEG_INF)[None, :]  # [1, TV]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = jnp.where(valid[None, :], col + j * tv, -1)

    new_s, new_i = _merge_topk(acc_s_ref[...], acc_i_ref[...], scores, gidx, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = new_s
        out_i_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "tv", "interpret"))
def pq_scan(
    lut: jax.Array,  # f32 [M, 256] — one query
    codes: jax.Array,  # uint8/int32 [NV, M]
    valid: jax.Array,  # bool [NV]
    *,
    k: int,
    tv: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    nv, m = codes.shape
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    codes_p = jnp.zeros((nv_p, m), jnp.int32).at[:nv].set(codes.astype(jnp.int32))
    valid_p = jnp.zeros((1, nv_p), jnp.int32).at[0, :nv].set(valid.astype(jnp.int32))
    nv_tiles = nv_p // tv
    kernel = functools.partial(_pq_scan_kernel, k=k, tv=tv, m=m, nv_tiles=nv_tiles)
    call = pl.pallas_call(
        kernel,
        grid=(nv_tiles,),
        in_specs=[
            pl.BlockSpec((m, 256), lambda j: (0, 0)),  # LUT resident
            pl.BlockSpec((tv, m), lambda j: (j, 0)),
            pl.BlockSpec((1, tv), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((1, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
        interpret=interpret,
    )
    s, i = call(lut, codes_p, valid_p)
    return s[0], i[0]
