"""Pallas TPU kernels: fused ADC scan (PQ lookup-table distances) + top-k.

Two grids over compressed (PQ) code storage:

  * ``pq_scan`` — the original one-query grid: ONE query's [M, 256] LUT stays
    resident in VMEM while uint8 code tiles stream from HBM.
  * ``workunit_pq_scan`` — the engine's batched work-unit grid ``[W, TQ, TV]``:
    each unit carries TQ per-query LUTs ([TQ, M, 256] f32 ≤ 64 KB·TQ/256,
    VMEM-resident across that unit's whole code sweep) and scans uint8 code
    tiles with a SINGLE one-hot MXU contraction — the per-subspace Python loop
    of ``pq_scan`` is flattened into one ``[TQ, M·256] @ [M·256, TV]`` matmul.
    Results fold into the same running-top-k VMEM scratch as fused_knn.

HBM traffic per scanned row is the CODE bytes (d·4/M× less than raw vectors —
the FAISS IVF-PQ family scan, TPU-shaped). Codes ship as uint8 end to end and
widen to int32 in-register; padding them to int32 host-side would quadruple
the code-tile traffic and defeat the point.

Gather note: Mosaic supports small-table gathers via one-hot matmul when
dynamic gather is unavailable; we express the lookup as
``one_hot(codes) @ lutᵀ`` — an MXU-friendly contraction (interpret mode
validates numerics either way). On real hardware the [TV, M] uint8 tile wants
M padded toward the lane width; at HQI's M ∈ {4, 8, 16} the tile is narrow,
which interpret mode tolerates and Mosaic handles via relayout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_knn import NEG_INF, _merge_topk


def _pq_scan_kernel(
    lut_ref,  # [M, 256] f32 — ONE query's tables
    codes_ref,  # [TV, M] uint8
    valid_ref,  # [1, TV] int32
    out_s_ref,  # [1, K]
    out_i_ref,  # [1, K]
    acc_s_ref,  # scratch [1, K]
    acc_i_ref,  # scratch [1, K]
    *,
    k: int,
    tv: int,
    m: int,
    nv_tiles: int,
):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_s_ref[...] = jnp.full(acc_s_ref.shape, NEG_INF, jnp.float32)
        acc_i_ref[...] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    codes = codes_ref[...].astype(jnp.int32)  # [TV, M] — widen in-register
    lut = lut_ref[...]  # [M, 256]
    # LUT gather as one-hot matmul per subspace (MXU-friendly, Mosaic-safe)
    scores = jnp.zeros((codes.shape[0],), jnp.float32)
    for sub in range(m):
        onehot = (
            codes[:, sub][:, None] == jax.lax.broadcasted_iota(jnp.int32, (tv, 256), 1)
        ).astype(jnp.float32)
        scores = scores + jax.lax.dot_general(
            onehot, lut[sub], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
    valid = valid_ref[0, :] != 0
    scores = jnp.where(valid, scores, NEG_INF)[None, :]  # [1, TV]
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = jnp.where(valid[None, :], col + j * tv, -1)

    new_s, new_i = _merge_topk(acc_s_ref[...], acc_i_ref[...], scores, gidx, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = new_s
        out_i_ref[...] = new_i


@functools.partial(jax.jit, static_argnames=("k", "tv", "interpret"))
def pq_scan(
    lut: jax.Array,  # f32 [M, 256] — one query
    codes: jax.Array,  # uint8 [NV, M]
    valid: jax.Array,  # bool [NV]
    *,
    k: int,
    tv: int = 1024,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    nv, m = codes.shape
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    # keep the code tiles uint8 across the dispatch boundary — int32 padding
    # would 4× the HBM traffic the compressed scan exists to avoid
    codes_p = jnp.zeros((nv_p, m), jnp.uint8).at[:nv].set(codes.astype(jnp.uint8))
    valid_p = jnp.zeros((1, nv_p), jnp.int32).at[0, :nv].set(valid.astype(jnp.int32))
    nv_tiles = nv_p // tv
    kernel = functools.partial(_pq_scan_kernel, k=k, tv=tv, m=m, nv_tiles=nv_tiles)
    call = pl.pallas_call(
        kernel,
        grid=(nv_tiles,),
        in_specs=[
            pl.BlockSpec((m, 256), lambda j: (0, 0)),  # LUT resident
            pl.BlockSpec((tv, m), lambda j: (j, 0)),
            pl.BlockSpec((1, tv), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda j: (0, 0)),
            pl.BlockSpec((1, k), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
        interpret=interpret,
    )
    s, i = call(lut, codes_p, valid_p)
    return s[0], i[0]


# ---------------------------------------------------------------------------
# Batched work-unit ADC scan (the engine's compressed execution kernel)
# ---------------------------------------------------------------------------


def _workunit_pq_kernel(
    lut_ref,  # [1, TQ, M*256] f32 — this unit's per-query tables, flattened
    codes_ref,  # [1, TV, M] uint8
    valid_ref,  # [1, TV] int32
    out_s_ref,  # [1, TQ, K]
    out_i_ref,  # [1, TQ, K]
    acc_s_ref,  # scratch f32 [TQ, K]
    acc_i_ref,  # scratch i32 [TQ, K]
    *,
    k: int,
    tv: int,
    m: int,
    nv_tiles: int,
):
    j = pl.program_id(1)  # code tile (inner) — w outer, so scratch is per-unit

    @pl.when(j == 0)
    def _init():
        acc_s_ref[...] = jnp.full(acc_s_ref.shape, NEG_INF, jnp.float32)
        acc_i_ref[...] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    codes = codes_ref[0].astype(jnp.int32)  # [TV, M] — uint8 widened in-register
    # one-hot over ALL subspaces at once: [TV, M, 256] -> [TV, M*256]; the
    # whole ADC gather is then ONE MXU contraction instead of an M-long loop
    iota = jax.lax.broadcasted_iota(jnp.int32, (tv, m, 256), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32).reshape(tv, m * 256)
    lut = lut_ref[0]  # [TQ, M*256]
    scores = jax.lax.dot_general(
        lut, onehot, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [TQ, TV]
    valid = valid_ref[0, :] != 0
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = jnp.where(valid[None, :], col + j * tv, -1)

    new_s, new_i = _merge_topk(acc_s_ref[...], acc_i_ref[...], scores, gidx, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = new_s[None]
        out_i_ref[...] = new_i[None]


@functools.partial(jax.jit, static_argnames=("k", "tv", "interpret"))
def workunit_pq_scan(
    luts: jax.Array,  # f32 [W, TQ, M, 256] — per-query ADC tables per unit
    codes: jax.Array,  # uint8 [W, NV, M] — gathered code rows per unit
    valid: jax.Array,  # bool [W, NV]
    *,
    k: int,
    tv: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Work-unit grid [W, TQ, TV] over compressed codes.

    Returns (scores f32 [W, TQ, k] best-first, idx i32 [W, TQ, k]; -1 = none).
    The LUT block of a unit stays VMEM-resident across its code sweep; code
    tiles ship as uint8 and widen in-register.
    """
    w, tq, m, nbook = luts.shape
    assert nbook == 256, "PQ codebooks are 8-bit (256 entries)"
    nv = codes.shape[1]
    k = int(k)
    # shrink the tile to the (pow2-padded) list length so short posting lists
    # don't pay a full 512-row sweep
    tv = min(tv, max(8, 1 << max(0, nv - 1).bit_length()))
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    codes_p = jnp.zeros((w, nv_p, m), jnp.uint8).at[:, :nv].set(codes.astype(jnp.uint8))
    valid_p = jnp.zeros((w, nv_p), jnp.int32).at[:, :nv].set(valid.astype(jnp.int32))
    luts_f = luts.reshape(w, tq, m * nbook)
    nv_tiles = nv_p // tv

    kernel = functools.partial(
        _workunit_pq_kernel, k=k, tv=tv, m=m, nv_tiles=nv_tiles
    )
    call = pl.pallas_call(
        kernel,
        grid=(w, nv_tiles),  # unit outer, code tile inner
        in_specs=[
            pl.BlockSpec((1, tq, m * nbook), lambda w_, j: (w_, 0, 0)),
            pl.BlockSpec((1, tv, m), lambda w_, j: (w_, j, 0)),
            pl.BlockSpec((1, tv), lambda w_, j: (w_, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, k), lambda w_, j: (w_, 0, 0)),
            pl.BlockSpec((1, tq, k), lambda w_, j: (w_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, tq, k), jnp.float32),
            jax.ShapeDtypeStruct((w, tq, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )
    return call(luts_f, codes_p, valid_p)


# ---------------------------------------------------------------------------
# Streamed-LUT work-unit ADC scan: the resident table never expands
# ---------------------------------------------------------------------------


def _workunit_pq_streamed_kernel(
    idx_ref,  # SMEM i32 [W, TQ] — scalar-prefetched LUT row per unit slot
    table_ref,  # HBM f32 [U, M*256] — the resident ADC table, NEVER expanded
    codes_ref,  # [1, TV, M] uint8
    valid_ref,  # [1, TV] int32
    out_s_ref,  # [1, TQ, K]
    out_i_ref,  # [1, TQ, K]
    lut_vmem,  # scratch f32 [TQ, M*256] — this unit's streamed LUT rows
    acc_s_ref,  # scratch f32 [TQ, K]
    acc_i_ref,  # scratch i32 [TQ, K]
    sem,  # DMA completion semaphore
    *,
    k: int,
    tv: int,
    tq: int,
    m: int,
    nv_tiles: int,
):
    w = pl.program_id(0)
    j = pl.program_id(1)  # code tile (inner) — w outer, so scratch is per-unit

    @pl.when(j == 0)
    def _init():
        acc_s_ref[...] = jnp.full(acc_s_ref.shape, NEG_INF, jnp.float32)
        acc_i_ref[...] = jnp.full(acc_i_ref.shape, -1, jnp.int32)
        # gather this unit's TQ LUT rows HBM -> VMEM, addressed through the
        # prefetched index vector: the per-unit [TQ, M*256] block is BUILT in
        # VMEM by DMA, so no [W, TQ, M, 256] operand is ever materialized
        for t in range(tq):
            dma = pltpu.make_async_copy(
                table_ref.at[idx_ref[w, t]], lut_vmem.at[t], sem
            )
            dma.start()
            dma.wait()

    codes = codes_ref[0].astype(jnp.int32)  # [TV, M] — uint8 widened in-register
    iota = jax.lax.broadcasted_iota(jnp.int32, (tv, m, 256), 2)
    onehot = (codes[:, :, None] == iota).astype(jnp.float32).reshape(tv, m * 256)
    scores = jax.lax.dot_general(
        lut_vmem[...], onehot, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [TQ, TV] — same contraction as _workunit_pq_kernel
    valid = valid_ref[0, :] != 0
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = jnp.where(valid[None, :], col + j * tv, -1)

    new_s, new_i = _merge_topk(acc_s_ref[...], acc_i_ref[...], scores, gidx, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = new_s[None]
        out_i_ref[...] = new_i[None]


@functools.partial(jax.jit, static_argnames=("k", "tv", "interpret"))
def workunit_pq_scan_streamed(
    table: jax.Array,  # f32 [U, M, 256] — resident per-query ADC tables
    lut_idx: jax.Array,  # i32 [W, TQ] — LUT row per unit slot (0 for padding)
    codes: jax.Array,  # uint8 [W, NV, M] — gathered code rows per unit
    valid: jax.Array,  # bool [W, NV]
    *,
    k: int,
    tv: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Work-unit ADC grid that streams LUT rows straight out of the resident
    table.

    Same [W, TV] sweep and one-hot MXU contraction as ``workunit_pq_scan``,
    but the per-unit LUT block is assembled in VMEM by per-row DMA from the
    [U, M·256] HBM table, addressed through a scalar-prefetched index vector
    (``PrefetchScalarGridSpec``). The [W, TQ, M, 256] expansion — W·TQ/U×
    redundant HBM traffic plus its allocation — is gone; each unit reads
    exactly the TQ rows it scans with.

    Returns (scores f32 [W, TQ, k] best-first, idx i32 [W, TQ, k]; -1 = none).
    """
    u, m, nbook = table.shape
    assert nbook == 256, "PQ codebooks are 8-bit (256 entries)"
    w, tq = lut_idx.shape
    nv = codes.shape[1]
    k = int(k)
    # shrink the tile to the (pow2-padded) list length so short posting lists
    # don't pay a full 512-row sweep (same rule as workunit_pq_scan)
    tv = min(tv, max(8, 1 << max(0, nv - 1).bit_length()))
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    codes_p = jnp.zeros((w, nv_p, m), jnp.uint8).at[:, :nv].set(codes.astype(jnp.uint8))
    valid_p = jnp.zeros((w, nv_p), jnp.int32).at[:, :nv].set(valid.astype(jnp.int32))
    table_f = table.reshape(u, m * nbook)
    nv_tiles = nv_p // tv

    kernel = functools.partial(
        _workunit_pq_streamed_kernel, k=k, tv=tv, tq=tq, m=m, nv_tiles=nv_tiles
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # lut_idx rides ahead of the grid in SMEM
        grid=(w, nv_tiles),  # unit outer, code tile inner
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # table stays in HBM
            pl.BlockSpec((1, tv, m), lambda w_, j, idx: (w_, j, 0)),
            pl.BlockSpec((1, tv), lambda w_, j, idx: (w_, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, tq, k), lambda w_, j, idx: (w_, 0, 0)),
            pl.BlockSpec((1, tq, k), lambda w_, j, idx: (w_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, m * nbook), jnp.float32),
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    call = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((w, tq, k), jnp.float32),
            jax.ShapeDtypeStruct((w, tq, k), jnp.int32),
        ],
        interpret=interpret,
    )
    return call(lut_idx.astype(jnp.int32), table_f, codes_p, valid_p)
