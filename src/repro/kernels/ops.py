"""jit'd dispatch wrappers for the kernels package.

Every op has two implementations: the pure-jnp reference (``ref.py``) used on
CPU / in the dry-run, and a Pallas TPU kernel. Selection is per-call
(``use_pallas``) with a process-wide default settable via
``set_default_backend``. On this CPU container the Pallas path runs in
interpret mode (tests); on a real TPU fleet ``interpret=False``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_DEFAULT_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

# NV/NQ ratio above which the db-stationary grid wins (each DB tile read once
# from HBM while every query tile's top-k stays resident in VMEM scratch)
_DB_STATIONARY_RATIO = 4


def set_default_backend(use_pallas: bool, interpret: bool = True) -> None:
    global _DEFAULT_PALLAS, _DEFAULT_INTERPRET
    _DEFAULT_PALLAS = use_pallas
    _DEFAULT_INTERPRET = interpret


@dataclasses.dataclass
class DispatchStats:
    """Process-wide kernel-dispatch accounting (see core/planner.py).

    ``knn_calls`` counts similarity-scan dispatches (work-unit megabatches and
    the legacy batched path); ``merge_calls`` counts segmented top-k merges.
    ``shapes`` holds the distinct (W, TQ, TV, k) problem shapes seen — a proxy
    for XLA compile-cache pressure that the engine's shape budget bounds.

    ``peak_candidate_bytes`` is the largest candidate merge buffer any single
    execution materialized (scores + ids) — the memory the segmented layout
    exists to shrink on skewed routing. ``lut_expand_bytes`` accumulates the
    bytes of every expanded per-unit [W, TQ, M, 256] ADC LUT operand; the
    resident-table dispatch path never records here, so a zero delta across a
    compressed search is the "no LUT expansion" assertion the tests make.

    Thread-safe: the serving layer's scheduler thread (repro.service) and
    foreground callers both dispatch kernels, so all mutation goes through a
    lock; read a consistent copy with ``snapshot()``.
    """

    knn_calls: int = 0
    merge_calls: int = 0
    shapes: set = dataclasses.field(default_factory=set)
    peak_candidate_bytes: int = 0
    lut_expand_bytes: int = 0
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record_knn(self, shape: tuple) -> None:
        with self._lock:
            self.knn_calls += 1
            self.shapes.add(shape)
        hook = _PROFILE_HOOK
        if hook is not None:
            hook("knn", shape)

    def record_merge(self) -> None:
        with self._lock:
            self.merge_calls += 1
        hook = _PROFILE_HOOK
        if hook is not None:
            hook("merge", None)

    def record_candidate_bytes(self, nbytes: int) -> None:
        with self._lock:
            self.peak_candidate_bytes = max(self.peak_candidate_bytes, int(nbytes))

    def record_lut_expand(self, nbytes: int) -> None:
        with self._lock:
            self.lut_expand_bytes += int(nbytes)

    def reset(self) -> None:
        with self._lock:
            self.knn_calls = 0
            self.merge_calls = 0
            self.shapes = set()
            self.peak_candidate_bytes = 0
            self.lut_expand_bytes = 0

    def snapshot(self) -> "DispatchStats":
        """Consistent point-in-time copy (counters + shape set)."""
        with self._lock:
            return DispatchStats(
                knn_calls=self.knn_calls,
                merge_calls=self.merge_calls,
                shapes=set(self.shapes),
                peak_candidate_bytes=self.peak_candidate_bytes,
                lut_expand_bytes=self.lut_expand_bytes,
            )

    def delta_since(self, prev: "DispatchStats") -> "DispatchStats":
        """What happened between two snapshots: ``after.delta_since(before)``.

        Running counters subtract; ``shapes`` is the set of shapes first seen
        in the interval; ``peak_candidate_bytes`` is a lifetime high-water
        mark, not a rate, so the delta carries the current value unchanged.
        """
        a, b = self.snapshot(), prev
        return DispatchStats(
            knn_calls=a.knn_calls - b.knn_calls,
            merge_calls=a.merge_calls - b.merge_calls,
            shapes=a.shapes - b.shapes,
            peak_candidate_bytes=a.peak_candidate_bytes,
            lut_expand_bytes=a.lut_expand_bytes - b.lut_expand_bytes,
        )


_DISPATCH = DispatchStats()


def dispatch_stats() -> DispatchStats:
    return _DISPATCH


def reset_dispatch_stats() -> None:
    _DISPATCH.reset()


# Issue-level profiler hook (obs.profile): called as hook(kind, shape) on
# every kernel dispatch — "knn" with the problem shape, "merge" with None —
# so the profiler can report attribution *coverage* (every dispatch its
# plan-level sites did not attribute shows up as issued-but-unattributed).
# One global load when disarmed; obs imports stay lazy from this side.
_PROFILE_HOOK = None


def set_profile_hook(cb) -> None:
    global _PROFILE_HOOK
    _PROFILE_HOOK = cb


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_scores(q: jax.Array, v: jax.Array, metric: str = "ip") -> jax.Array:
    """Dense score matrix (no masking/top-k) — plain GEMM, XLA-optimal."""
    return _ref.pairwise_scores_ref(q, v, metric)


def masked_topk(
    q: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked similarity top-k. See fused_knn.py for the TPU kernel."""
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .fused_knn import fused_knn

        return fused_knn(q, v, valid, k=k, metric=metric, interpret=interpret)
    return _masked_topk_jnp(q, v, valid, k, metric)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _masked_topk_jnp(q, v, valid, k, metric):
    return _ref.masked_topk_ref(q, v, valid, k, metric)


def batched_masked_topk(
    q: jax.Array,  # [W, TQ, D]  padded work units (see core/planner.py)
    v: jax.Array,  # [W, TV, D]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """vmapped work-unit execution: the device side of Algorithm 3.

    Each work unit is a (query-group tile × posting-list tile) pair assembled
    by the planner; one call evaluates all units in parallel. Alias of
    ``workunit_topk`` (the engine's entry point), kept for its callers.
    """
    return workunit_topk(
        q, v, valid, k, metric=metric, use_pallas=use_pallas, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _batched_masked_topk_jnp(q, v, valid, k, metric):
    return jax.vmap(lambda a, b, c: _ref.masked_topk_ref(a, b, c, k, metric))(q, v, valid)


def _unit_scan_fn(k: int, metric: str, use_pallas: bool, interpret: bool):
    """Per-rank/per-bucket work-unit scan body: the ONE place the kernel
    choice lives (db-stationary grid when the vector tile dominates the
    query tile), shared by ``workunit_topk`` and the sharded wrapper so the
    single-device and sharded paths can never diverge on dispatch
    heuristics."""

    def scan(q, v, valid):  # [W, TQ, D], [W, TV, D], [W, TV]
        if use_pallas:
            from .fused_knn import fused_knn, fused_knn_db_stationary

            if v.shape[1] >= _DB_STATIONARY_RATIO * max(int(q.shape[1]), 1):
                fn = functools.partial(
                    fused_knn_db_stationary, k=k, metric=metric, interpret=interpret
                )
            else:
                fn = functools.partial(fused_knn, k=k, metric=metric, interpret=interpret)
            return jax.vmap(fn)(q, v, valid)
        return jax.vmap(lambda a, b, c: _ref.masked_topk_ref(a, b, c, k, metric))(q, v, valid)

    return scan


def workunit_topk(
    q: jax.Array,  # [W, TQ, D]  one bucket's work units (see core/plan.py)
    v: jax.Array,  # [W, TV, D]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Work-unit entry point of the execution engine: one bucket, one dispatch.

    The engine hands every work unit of a shape bucket — across all partitions
    and templates — to a single call. On the Pallas path this picks the
    db-stationary grid of ``fused_knn`` when the vector tile dominates the
    query tile (NV ≫ NQ, the batch-serving shape), and the query-stationary
    grid otherwise (``_unit_scan_fn``).
    """
    _DISPATCH.record_knn((q.shape[0], q.shape[1], v.shape[1], int(k)))
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        return _unit_scan_fn(int(k), metric, True, interpret)(q, v, valid)
    return _batched_masked_topk_jnp(q, v, valid, k, metric)


def workunit_pq_topk(
    luts: jax.Array,  # f32 [W, TQ, M, 256]  per-query ADC tables per work unit
    codes: jax.Array,  # uint8 [W, TV, M]     gathered PQ code rows per unit
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Compressed (ADC) work-unit entry point — ``workunit_topk`` over codes.

    One bucket of the engine's compressed scan stage, one dispatch: each work
    unit's TQ lookup tables scan its uint8 code tile via a batched one-hot
    MXU contraction (kernels/pq_scan.py). Codes stay uint8 across the
    dispatch boundary and widen in-register — HBM traffic per scanned row is
    M bytes instead of d·4.
    """
    _DISPATCH.record_knn(
        ("pq", luts.shape[0], luts.shape[1], codes.shape[1], int(k))
    )
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .pq_scan import workunit_pq_scan

        return workunit_pq_scan(luts, codes, valid, k=k, interpret=interpret)
    return _workunit_pq_topk_jnp(luts, codes, valid, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _workunit_pq_topk_jnp(luts, codes, valid, k):
    return _ref.workunit_pq_topk_ref(luts, codes, valid, k)


def workunit_pq_topk_resident(
    table: jax.Array,  # f32 [U, M, 256] — the workload's resident ADC tables
    lut_idx: jax.Array,  # i32 [W, TQ] — per-slot row into ``table``
    codes: jax.Array,  # uint8 [W, TV, M]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Compressed work-unit dispatch indexing the resident LUT table directly.

    ``workunit_pq_topk`` takes pre-expanded per-unit [W, TQ, M, 256] tables —
    an operand the caller must materialize per bucket. This entry point takes
    the workload's resident [U, M, 256] table once plus per-slot row indices:
    on the Pallas path the kernel streams each unit's LUT rows from HBM into
    VMEM via scalar-prefetch index maps (``workunit_pq_scan_streamed``), so
    no [W, TQ, M, 256] array ever exists; on the jnp path the row gather
    happens inside the jit (fused by XLA, never a caller-visible operand).
    Numerics match ``workunit_pq_topk`` over ``take(table, lut_idx)`` exactly.
    """
    _DISPATCH.record_knn(
        ("pq-res", lut_idx.shape[0], lut_idx.shape[1], codes.shape[1], int(k))
    )
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .pq_scan import workunit_pq_scan_streamed

        return workunit_pq_scan_streamed(
            table, lut_idx, codes, valid, k=int(k), interpret=interpret
        )
    return _workunit_pq_topk_resident_jnp(table, lut_idx, codes, valid, int(k))


@functools.partial(jax.jit, static_argnames=("k",))
def _workunit_pq_topk_resident_jnp(table, lut_idx, codes, valid, k):
    luts = jnp.take(table, lut_idx, axis=0)  # fused into the scan by XLA
    return _ref.workunit_pq_topk_ref(luts, codes, valid, k)


# --------------------------------------------------------------------------
# Sharded dispatch (device-mesh execution, see core/planner.py's sharded path)
#
# Each wrapper runs ONE shard_map over the mesh's model axis: the leading dim
# of every stacked operand is the rank axis, so rank r executes exactly its
# own slice with the same per-unit math as the single-device kernels (results
# are bit-identical, which the mesh-parity suite asserts). The scan/ADC
# wrappers are collective-free; the only cross-rank traffic in the engine is
# ``sharded_merge_topk``'s all-gather of per-query top-k candidates —
# O(k · |model|) floats+ids per query, never distance rows.

_SHARDED_FN_CACHE: dict = {}


def _sharded_cached(key, build):
    fn = _SHARDED_FN_CACHE.get(key)
    if fn is None:
        fn = _SHARDED_FN_CACHE[key] = build()
    return fn


def _shard_map(local, mesh, axis, n_in, n_out, *, out_sharded: bool):
    """shard_map sharding the leading (rank) dim of every operand; outputs
    are rank-major sharded (scan stages) or replicated (the gather merge)."""
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import shard_map_compat

    out = P(axis) if out_sharded else P(None)
    return shard_map_compat(
        local,
        mesh=mesh,
        in_specs=tuple(P(axis) for _ in range(n_in)),
        out_specs=tuple(out for _ in range(n_out)),
    )


def sharded_workunit_topk(
    mesh,
    axis: str,
    q: jax.Array,  # f32 [R, W, TQ, D] — rank r's work units at [r]
    v: jax.Array,  # f32 [R, W, TV, D]
    valid: jax.Array,  # bool [R, W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """``workunit_topk`` across the mesh: one dispatch, every rank its slice.

    The leading dim must equal ``mesh.shape[axis]``; other mesh axes (data,
    pod) replicate — batch parallelism splits the query stream host-side.
    Collective-free: outputs stay rank-major [R, W, TQ, kk] for the host-side
    scatter into per-rank candidate tensors.
    """
    R = q.shape[0]
    _DISPATCH.record_knn(("sh", R, q.shape[1], q.shape[2], v.shape[2], int(k)))
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    key = ("wu", mesh, axis, q.shape, v.shape, int(k), metric, use_pallas, interpret)

    def build():
        scan = _unit_scan_fn(int(k), metric, use_pallas, interpret)

        def local(ql, vl, validl):  # leading dim R/R == 1 per rank
            s, i = scan(ql[0], vl[0], validl[0])
            return s[None], i[None]

        return jax.jit(_shard_map(local, mesh, axis, 3, 2, out_sharded=True))

    return _sharded_cached(key, build)(q, v, valid)


def sharded_workunit_pq_topk(
    mesh,
    axis: str,
    luts: jax.Array,  # f32 [U, M, 256] — resident ADC tables, REPLICATED
    lut_idx: jax.Array,  # i64 [R, W, TQ] — per-slot row into ``luts``
    codes: jax.Array,  # uint8 [R, W, TV, M] — rank r's gathered code tiles
    valid: jax.Array,  # bool [R, W, TV]
    k: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    stream: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Compressed (ADC) sharded scan — ``workunit_pq_topk`` across the mesh.

    The workload's ADC tables ship once, replicated. With ``stream=False``
    (the dense merge layout) each rank expands its per-unit [W, TQ, M, 256]
    LUT operand with an on-device gather before the scan. With ``stream=True``
    (the segmented layout) the rank's kernel indexes the resident table
    directly — the Pallas kernel DMA-streams LUT rows via scalar-prefetch
    index maps, the jnp path fuses the row gather into the jitted scan — so
    the expanded operand never exists. Collective-free either way.
    """
    R = codes.shape[0]
    _DISPATCH.record_knn(("sh-pq", R, codes.shape[1], lut_idx.shape[2], codes.shape[2], int(k)))
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    key = (
        "pq", mesh, axis, luts.shape, lut_idx.shape, codes.shape,
        int(k), use_pallas, interpret, bool(stream),
    )

    def build():
        from jax.sharding import PartitionSpec as P

        from ..distributed.sharding import shard_map_compat

        def local(luts_l, idx_l, codes_l, valid_l):
            if stream and use_pallas:
                from .pq_scan import workunit_pq_scan_streamed

                s, i = workunit_pq_scan_streamed(
                    luts_l, idx_l[0].astype(jnp.int32), codes_l[0], valid_l[0],
                    k=int(k), interpret=interpret,
                )
                return s[None], i[None]
            per_unit = jnp.take(luts_l, idx_l[0], axis=0)  # [W, TQ, M, 256]
            if use_pallas:
                from .pq_scan import workunit_pq_scan

                s, i = workunit_pq_scan(
                    per_unit, codes_l[0], valid_l[0], k=int(k), interpret=interpret
                )
            else:
                s, i = _ref.workunit_pq_topk_ref(per_unit, codes_l[0], valid_l[0], int(k))
            return s[None], i[None]

        fn = shard_map_compat(
            local,
            mesh=mesh,
            in_specs=(P(), P(axis), P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )
        return jax.jit(fn)

    return _sharded_cached(key, build)(luts, lut_idx, codes, valid)


def sharded_merge_topk(
    mesh,
    axis: str,
    scores: jax.Array,  # f32 [R, m, C] — rank r's candidate rows at [r]
    idx: jax.Array,  # i64 [R, m, C] — GLOBAL candidate ids (-1 = absent)
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """The engine's only cross-rank step: per-query top-k candidate gather.

    Each rank first reduces its own C candidate columns to its local top-k —
    on-device, collective-free — then ONE all-gather over ``axis`` moves the
    [m, k] survivors (k·|model| candidates per query, independent of DB and
    candidate-tensor size) and a final fused top-k selects the global result,
    replicated to every rank. This is Alg. 3's merge lifted onto the mesh:
    distance rows never cross ranks.
    """
    _DISPATCH.record_merge()
    key = ("mg", mesh, axis, scores.shape, idx.dtype, int(k))

    def build():
        def local(sl, il):  # [1, m, C] per rank
            top, pos = jax.lax.top_k(sl[0], int(k))
            li = jnp.take_along_axis(il[0], pos.astype(il.dtype), axis=1)
            top, li = _ref.normalize_merge_sentinels(top, li)
            all_s = jax.lax.all_gather(top, axis)  # [R, m, k] — THE comm step
            all_i = jax.lax.all_gather(li, axis)
            m = sl.shape[1]
            cat_s = jnp.moveaxis(all_s, 0, 1).reshape(m, -1)
            cat_i = jnp.moveaxis(all_i, 0, 1).reshape(m, -1)
            t, p = jax.lax.top_k(cat_s, int(k))
            oi = jnp.take_along_axis(cat_i, p.astype(cat_i.dtype), axis=1)
            return _ref.normalize_merge_sentinels(t, oi)

        return jax.jit(_shard_map(local, mesh, axis, 2, 2, out_sharded=False))

    return _sharded_cached(key, build)(scores, idx)


def merge_topk(
    scores: jax.Array,  # f32 [m, C] — per-query candidate scores (-inf = absent)
    idx: jax.Array,  # i64 [m, C] — candidate ids (-1 = absent)
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Device-side segmented top-k reduction over per-query candidate rows.

    The engine's final cross-partition merge (Alg. 3 line 12 for the whole
    workload): every query's candidates from every partition, template, and
    probe slot reduce to its top-k in one op instead of a per-(template ×
    partition) numpy merge loop.
    """
    _DISPATCH.record_merge()
    return _merge_topk_jnp(scores, idx, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_topk_jnp(scores, idx, k):
    top, pos = jax.lax.top_k(scores, k)
    out_i = jnp.take_along_axis(idx, pos.astype(idx.dtype), axis=1)
    return _ref.normalize_merge_sentinels(top, out_i)


def segmented_merge_topk(
    flat_s: jax.Array,  # f32 [C, kk] — flat candidate rows (CSR layout)
    flat_i: jax.Array,  # i64 [C, kk] — candidate ids (-1 = absent)
    seg_of: jax.Array,  # i32 [C] — owning query per row, ascending; >= n_segments = pad
    n_segments: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Ragged per-query top-k reduction — the segmented ``merge_topk``.

    One dispatch reduces every query's variable-width candidate segment to
    its top-k: queries routed to few partitions no longer pay the widest
    query's ``n_slots`` columns, so the merge buffer is Σ segments·kk instead
    of m·n_slots·kk (and per RANK on the sharded path). Bit-identical to the
    dense merge over the same per-segment candidate order — see
    ``ref.segmented_merge_topk_ref``.
    """
    _DISPATCH.record_merge()
    return _segmented_merge_topk_jnp(flat_s, flat_i, seg_of, int(n_segments), int(k))


@functools.partial(jax.jit, static_argnames=("n_segments", "k"))
def _segmented_merge_topk_jnp(flat_s, flat_i, seg_of, n_segments, k):
    return _ref.segmented_merge_topk_ref(flat_s, flat_i, seg_of, n_segments, k)
