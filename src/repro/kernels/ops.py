"""jit'd dispatch wrappers for the kernels package.

Every op has two implementations: the pure-jnp reference (``ref.py``) used on
CPU / in the dry-run, and a Pallas TPU kernel. Selection is per-call
(``use_pallas``) with a process-wide default settable via
``set_default_backend``. On this CPU container the Pallas path runs in
interpret mode (tests); on a real TPU fleet ``interpret=False``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_DEFAULT_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def set_default_backend(use_pallas: bool, interpret: bool = True) -> None:
    global _DEFAULT_PALLAS, _DEFAULT_INTERPRET
    _DEFAULT_PALLAS = use_pallas
    _DEFAULT_INTERPRET = interpret


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_scores(q: jax.Array, v: jax.Array, metric: str = "ip") -> jax.Array:
    """Dense score matrix (no masking/top-k) — plain GEMM, XLA-optimal."""
    return _ref.pairwise_scores_ref(q, v, metric)


def masked_topk(
    q: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked similarity top-k. See fused_knn.py for the TPU kernel."""
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .fused_knn import fused_knn

        return fused_knn(q, v, valid, k=k, metric=metric, interpret=interpret)
    return _masked_topk_jnp(q, v, valid, k, metric)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _masked_topk_jnp(q, v, valid, k, metric):
    return _ref.masked_topk_ref(q, v, valid, k, metric)


def batched_masked_topk(
    q: jax.Array,  # [W, TQ, D]  padded work units (see core/planner.py)
    v: jax.Array,  # [W, TV, D]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """vmapped work-unit execution: the device side of Algorithm 3.

    Each work unit is a (query-group tile × posting-list tile) pair assembled
    by the planner; one call evaluates all units in parallel.
    """
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .fused_knn import fused_knn

        fn = functools.partial(fused_knn, k=k, metric=metric, interpret=interpret)
        return jax.vmap(lambda a, b, c: fn(a, b, c))(q, v, valid)
    return _batched_masked_topk_jnp(q, v, valid, k, metric)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _batched_masked_topk_jnp(q, v, valid, k, metric):
    return jax.vmap(lambda a, b, c: _ref.masked_topk_ref(a, b, c, k, metric))(q, v, valid)
