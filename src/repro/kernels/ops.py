"""jit'd dispatch wrappers for the kernels package.

Every op has two implementations: the pure-jnp reference (``ref.py``) used on
CPU / in the dry-run, and a Pallas TPU kernel. Selection is per-call
(``use_pallas``) with a process-wide default settable via
``set_default_backend``. On this CPU container the Pallas path runs in
interpret mode (tests); on a real TPU fleet ``interpret=False``.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading

import jax
import jax.numpy as jnp

from . import ref as _ref

_DEFAULT_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_DEFAULT_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

# NV/NQ ratio above which the db-stationary grid wins (each DB tile read once
# from HBM while every query tile's top-k stays resident in VMEM scratch)
_DB_STATIONARY_RATIO = 4


def set_default_backend(use_pallas: bool, interpret: bool = True) -> None:
    global _DEFAULT_PALLAS, _DEFAULT_INTERPRET
    _DEFAULT_PALLAS = use_pallas
    _DEFAULT_INTERPRET = interpret


@dataclasses.dataclass
class DispatchStats:
    """Process-wide kernel-dispatch accounting (see core/planner.py).

    ``knn_calls`` counts similarity-scan dispatches (work-unit megabatches and
    the legacy batched path); ``merge_calls`` counts segmented top-k merges.
    ``shapes`` holds the distinct (W, TQ, TV, k) problem shapes seen — a proxy
    for XLA compile-cache pressure that the engine's shape budget bounds.

    Thread-safe: the serving layer's scheduler thread (repro.service) and
    foreground callers both dispatch kernels, so all mutation goes through a
    lock; read a consistent copy with ``snapshot()``.
    """

    knn_calls: int = 0
    merge_calls: int = 0
    shapes: set = dataclasses.field(default_factory=set)
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False
    )

    def record_knn(self, shape: tuple) -> None:
        with self._lock:
            self.knn_calls += 1
            self.shapes.add(shape)

    def record_merge(self) -> None:
        with self._lock:
            self.merge_calls += 1

    def reset(self) -> None:
        with self._lock:
            self.knn_calls = 0
            self.merge_calls = 0
            self.shapes = set()

    def snapshot(self) -> "DispatchStats":
        """Consistent point-in-time copy (counters + shape set)."""
        with self._lock:
            return DispatchStats(
                knn_calls=self.knn_calls,
                merge_calls=self.merge_calls,
                shapes=set(self.shapes),
            )


_DISPATCH = DispatchStats()


def dispatch_stats() -> DispatchStats:
    return _DISPATCH


def reset_dispatch_stats() -> None:
    _DISPATCH.reset()


@functools.partial(jax.jit, static_argnames=("metric",))
def pairwise_scores(q: jax.Array, v: jax.Array, metric: str = "ip") -> jax.Array:
    """Dense score matrix (no masking/top-k) — plain GEMM, XLA-optimal."""
    return _ref.pairwise_scores_ref(q, v, metric)


def masked_topk(
    q: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked similarity top-k. See fused_knn.py for the TPU kernel."""
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .fused_knn import fused_knn

        return fused_knn(q, v, valid, k=k, metric=metric, interpret=interpret)
    return _masked_topk_jnp(q, v, valid, k, metric)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _masked_topk_jnp(q, v, valid, k, metric):
    return _ref.masked_topk_ref(q, v, valid, k, metric)


def batched_masked_topk(
    q: jax.Array,  # [W, TQ, D]  padded work units (see core/planner.py)
    v: jax.Array,  # [W, TV, D]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """vmapped work-unit execution: the device side of Algorithm 3.

    Each work unit is a (query-group tile × posting-list tile) pair assembled
    by the planner; one call evaluates all units in parallel. Alias of
    ``workunit_topk`` (the engine's entry point), kept for its callers.
    """
    return workunit_topk(
        q, v, valid, k, metric=metric, use_pallas=use_pallas, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def _batched_masked_topk_jnp(q, v, valid, k, metric):
    return jax.vmap(lambda a, b, c: _ref.masked_topk_ref(a, b, c, k, metric))(q, v, valid)


def workunit_topk(
    q: jax.Array,  # [W, TQ, D]  one bucket's work units (see core/plan.py)
    v: jax.Array,  # [W, TV, D]
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    metric: str = "ip",
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Work-unit entry point of the execution engine: one bucket, one dispatch.

    The engine hands every work unit of a shape bucket — across all partitions
    and templates — to a single call. On the Pallas path this picks the
    db-stationary grid of ``fused_knn`` when the vector tile dominates the
    query tile (NV ≫ NQ, the batch-serving shape), and the query-stationary
    grid otherwise.
    """
    _DISPATCH.record_knn((q.shape[0], q.shape[1], v.shape[1], int(k)))
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .fused_knn import fused_knn, fused_knn_db_stationary

        if v.shape[1] >= _DB_STATIONARY_RATIO * max(int(q.shape[1]), 1):
            fn = functools.partial(
                fused_knn_db_stationary, k=k, metric=metric, interpret=interpret
            )
        else:
            fn = functools.partial(fused_knn, k=k, metric=metric, interpret=interpret)
        return jax.vmap(lambda a, b, c: fn(a, b, c))(q, v, valid)
    return _batched_masked_topk_jnp(q, v, valid, k, metric)


def workunit_pq_topk(
    luts: jax.Array,  # f32 [W, TQ, M, 256]  per-query ADC tables per work unit
    codes: jax.Array,  # uint8 [W, TV, M]     gathered PQ code rows per unit
    valid: jax.Array,  # bool [W, TV]
    k: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Compressed (ADC) work-unit entry point — ``workunit_topk`` over codes.

    One bucket of the engine's compressed scan stage, one dispatch: each work
    unit's TQ lookup tables scan its uint8 code tile via a batched one-hot
    MXU contraction (kernels/pq_scan.py). Codes stay uint8 across the
    dispatch boundary and widen in-register — HBM traffic per scanned row is
    M bytes instead of d·4.
    """
    _DISPATCH.record_knn(
        ("pq", luts.shape[0], luts.shape[1], codes.shape[1], int(k))
    )
    use_pallas = _DEFAULT_PALLAS if use_pallas is None else use_pallas
    interpret = _DEFAULT_INTERPRET if interpret is None else interpret
    if use_pallas:
        from .pq_scan import workunit_pq_scan

        return workunit_pq_scan(luts, codes, valid, k=k, interpret=interpret)
    return _workunit_pq_topk_jnp(luts, codes, valid, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _workunit_pq_topk_jnp(luts, codes, valid, k):
    return _ref.workunit_pq_topk_ref(luts, codes, valid, k)


def merge_topk(
    scores: jax.Array,  # f32 [m, C] — per-query candidate scores (-inf = absent)
    idx: jax.Array,  # i64 [m, C] — candidate ids (-1 = absent)
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Device-side segmented top-k reduction over per-query candidate rows.

    The engine's final cross-partition merge (Alg. 3 line 12 for the whole
    workload): every query's candidates from every partition, template, and
    probe slot reduce to its top-k in one op instead of a per-(template ×
    partition) numpy merge loop.
    """
    _DISPATCH.record_merge()
    return _merge_topk_jnp(scores, idx, k)


@functools.partial(jax.jit, static_argnames=("k",))
def _merge_topk_jnp(scores, idx, k):
    top, pos = jax.lax.top_k(scores, k)
    out_i = jnp.take_along_axis(idx, pos.astype(idx.dtype), axis=1)
    # normalize sentinels: absent results are (-inf, -1) on every path
    top = jnp.where(out_i < 0, -jnp.inf, top)
    out_i = jnp.where(jnp.isfinite(top), out_i, -1)
    return top, out_i
