"""Pallas TPU flash attention (fwd): online softmax, causal + sliding window,

GQA-aware. The on-hardware hot path for the 32k prefill cells; the pure-JAX
chunked implementation (models/attention.py) is the oracle and the dry-run
path. Grid (batch, q_heads, q_blocks, kv_blocks), kv innermost so the
(m, l, acc) running state lives in VMEM across a query block's sweep.

Block shapes: q (1, bq, 1, dh), kv (1, bk, 1, dh) — dh is kept whole (128 or
less → lane-aligned); bq/bk default 128/256 keeping the MXU busy and the
VMEM footprint ≈ bq·dh + 2·bk·dh + bq·bk floats ≈ 400 KB at defaults.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(-3.0e38)


def _flash_kernel(
    q_ref,  # [1, bq, 1, dh]
    k_ref,  # [1, bk, 1, dh]
    v_ref,  # [1, bk, 1, dh]
    o_ref,  # [1, bq, 1, dh]
    m_ref,  # scratch [bq, 1]
    l_ref,  # scratch [bq, 1]
    acc_ref,  # scratch [bq, dh]
    *,
    bq: int,
    bk: int,
    nk: int,
    seq_k: int,
    causal: bool,
    window: int,
    scale: float,
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # [bq, dh]
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # [bk, dh]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[:, 0] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, S, Hq, dh]
    k: jax.Array,  # [B, T, Hkv, dh]
    v: jax.Array,  # [B, T, Hkv, dh]
    *,
    causal: bool = True,
    window: int = 0,  # 0 = global
    bq: int = 128,
    bk: int = 256,
    interpret: bool = True,
) -> jax.Array:
    b, s, hq, dh = q.shape
    t, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = dh**-0.5
    bq = min(bq, max(8, s))
    bk = min(bk, max(8, t))
    sp = ((s + bq - 1) // bq) * bq
    tp = ((t + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, sp - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, tp - t), (0, 0), (0, 0)))
    nq, nk = sp // bq, tp // bk

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, seq_k=t,
        causal=causal, window=window, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, 1, dh), lambda bi, h, qi, ki: (bi, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, h, qi, ki: (bi, ki, h // group, 0)),
            pl.BlockSpec((1, bk, 1, dh), lambda bi, h, qi, ki: (bi, ki, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dh), lambda bi, h, qi, ki: (bi, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sp, hq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :s]
