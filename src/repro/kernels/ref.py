"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has its reference here; tests sweep shapes and
dtypes asserting allclose between the kernel (interpret mode on CPU) and
these functions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float(-3.4e38)


def normalize_merge_sentinels(
    scores: jax.Array, idx: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Canonical absent-result encoding shared by every merge path.

    Merge inputs carry two sentinel flavors — ``-inf`` (allocation padding)
    and the kernels' finite ``NEG_INF`` with idx -1 — and a top-k over them
    can pair a finite sentinel score with a real-looking index or vice versa.
    This maps every absent entry to exactly (-inf, -1): an entry is absent
    iff its idx is negative or its score is non-finite.
    """
    scores = jnp.where(idx < 0, -jnp.inf, scores)
    idx = jnp.where(jnp.isfinite(scores), idx, -1)
    return scores, idx


def segmented_merge_topk_ref(
    flat_s: jax.Array,  # f32 [C, kk] — candidate rows, any per-segment count
    flat_i: jax.Array,  # int [C, kk] — candidate ids (-1 = absent)
    seg_of: jax.Array,  # i32 [C] — owning segment per row, ASCENDING; >= n_segments = drop
    n_segments: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Ragged per-segment top-k oracle: CSR-style rows -> [n_segments, k].

    The segmented counterpart of ``merge_topk``: instead of a dense
    [m, n_slots, kk] tensor padded to the widest query, candidates arrive as
    a flat [C, kk] buffer whose rows belong to segments (queries) of varying
    width. One stable sort by (segment, -score) ranks every candidate inside
    its segment; rank < k survives. Stability preserves the original
    candidate order among EXACTLY equal scores, which is ``lax.top_k``'s
    smallest-index-first tie rule — so results are bit-identical to the
    dense merge over the same per-segment candidate sequence (the parity
    suite asserts ids AND scores). Rows whose ``seg_of`` is ``n_segments``
    or above are padding and are dropped.
    """
    C, kk = flat_s.shape
    if n_segments == 0:
        return (
            jnp.zeros((0, k), jnp.float32),
            jnp.zeros((0, k), flat_i.dtype),
        )
    n = C * kk
    s = flat_s.reshape(n)
    i = flat_i.reshape(n)
    seg = jnp.repeat(seg_of.astype(jnp.int32), kk)
    order = jnp.lexsort((-s, seg))  # stable: ties keep candidate order
    s_s, i_s, seg_s = s[order], i[order], seg[order]
    starts = jnp.searchsorted(seg_s, jnp.arange(n_segments, dtype=seg_s.dtype))
    pos = jnp.arange(n) - starts[jnp.clip(seg_s, 0, max(n_segments - 1, 0))]
    keep = (seg_s < n_segments) & (pos < k)
    rows = jnp.where(keep, seg_s, n_segments)  # out-of-range row -> dropped
    cols = jnp.where(keep, pos, 0)
    out_s = (
        jnp.full((n_segments, k), -jnp.inf, jnp.float32)
        .at[rows, cols].set(s_s.astype(jnp.float32), mode="drop")
    )
    out_i = (
        jnp.full((n_segments, k), -1, flat_i.dtype)
        .at[rows, cols].set(i_s, mode="drop")
    )
    return normalize_merge_sentinels(out_s, out_i)


def pairwise_scores_ref(q: jax.Array, v: jax.Array, metric: str = "ip") -> jax.Array:
    """Similarity scores, best = max. q [nq,d], v [nv,d] -> f32 [nq,nv].

    ip: q·v          l2: -||q - v||²  (negated so max = nearest)
    """
    q = q.astype(jnp.float32)
    v = v.astype(jnp.float32)
    ip = q @ v.T
    if metric == "ip":
        return ip
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)  # [nq,1]
        vn = jnp.sum(v * v, axis=1)[None, :]  # [1,nv]
        return 2.0 * ip - qn - vn
    raise ValueError(metric)


def masked_topk_ref(
    q: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    k: int,
    metric: str = "ip",
) -> tuple[jax.Array, jax.Array]:
    """Top-k masked similarity search oracle.

    q [nq,d], v [nv,d], valid bool [nv] (the pushdown bitmap of Section 4.2).
    Returns (scores f32 [nq,k] best-first, idx int32 [nq,k]); masked-out or
    absent entries have score -inf and idx -1.
    """
    scores = pairwise_scores_ref(q, v, metric)
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    top, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(top <= NEG_INF / 2, -1, idx).astype(jnp.int32)
    return top, idx


def adc_topk_ref(
    luts: jax.Array,  # f32 [nq, M, 256] — per-query ADC lookup tables
    codes: jax.Array,  # uint8/int32 [nv, M]
    valid: jax.Array,  # bool [nv]
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """ADC scan + top-k oracle (the compressed counterpart of masked_topk_ref).

    score[q, v] = Σ_m lut[q, m, code[v, m]] — higher is better (adc_tables
    negates l2). Returns (scores f32 [nq, k] best-first, idx int32 [nq, k]);
    masked-out or absent entries are (-inf-ish, -1).
    """
    c = codes.astype(jnp.int32)  # [nv, M]
    m = luts.shape[1]
    # fancy-gather per subspace: luts[q, m, c[v, m]] -> [nq, nv, M], then sum
    scores = luts[:, jnp.arange(m)[None, :], c].sum(axis=-1)  # [nq, nv]
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    top, idx = jax.lax.top_k(scores, k)
    idx = jnp.where(top <= NEG_INF / 2, -1, idx).astype(jnp.int32)
    return top, idx


def workunit_pq_topk_ref(
    luts: jax.Array,  # f32 [W, TQ, M, 256]
    codes: jax.Array,  # uint8/int32 [W, TV, M]
    valid: jax.Array,  # bool [W, TV]
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Batched work-unit ADC oracle: adc_topk_ref vmapped over the unit dim."""
    return jax.vmap(lambda l, c, v: adc_topk_ref(l, c, v, k))(luts, codes, valid)


def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Reference attention. q [B,Hq,S,Dh], k/v [B,Hkv,T,Dh] (GQA: Hq % Hkv == 0).

    window (if set) = sliding-window size W: position i attends to
    (i - W, i]  (causal local attention, gemma3-style).
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / (dh**0.5)
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kf = jnp.repeat(kf, group, axis=1)
    vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", qf, kf)
    t = kf.shape[2]
    qpos = jnp.arange(s)[:, None] + (t - s)  # right-aligned (decode: s << t)
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, vf)
    return out.astype(q.dtype)
