"""Pallas TPU kernel: fused masked-distance + top-k (the HQI hot loop).

This is Algorithm 3 line 10 + the Section 4.2 bitmap pushdown as one kernel:
for a tile of grouped query vectors and a tile of a posting list, compute
similarity scores on the MXU (one ``q_tile @ v_tileᵀ`` matmul), apply the
attribute-filter bitmap as a -inf mask *in VMEM*, and fold the tile into a
running per-query top-k carried in VMEM scratch across the vector-tile grid
dimension. HBM traffic is O(nq·k + nv·d) instead of O(nq·nv): the full
distance matrix is never materialized.

TPU adaptation notes (vs the paper's CPU/FAISS loop):
  * posting lists are padded to TV-aligned tiles; padding rows are masked via
    the same ``valid`` bitmap the attribute filter uses — zero extra cost;
  * the per-query result heap becomes an unrolled K-pass selection merge
    (K is small, ≤ 16 in all HQI configs), which lowers to pure
    max/compare/select ops — no sort network, MXU stays the bottleneck;
  * tiles are 128-aligned so the matmul maps onto the 128×128 MXU.

Grid: (nq_tiles, nv_tiles); the vector-tile dim is innermost so the running
top-k scratch for a query tile stays live in VMEM across its whole sweep.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float(-3.4e38)


def _merge_topk(run_s, run_i, tile_s, tile_i, k: int):
    """Select top-k of concat(running[k], tile[TV]) per row. Unrolled K-pass

    selection — only max/eq/where ops (Mosaic-safe).
    run_s f32 [TQ,K], run_i i32 [TQ,K], tile_s f32 [TQ,TV], tile_i i32 [TQ,TV].
    """
    cat_s = jnp.concatenate([run_s, tile_s], axis=1)  # [TQ, K+TV]
    cat_i = jnp.concatenate([run_i, tile_i], axis=1)
    width = cat_s.shape[1]
    pos = jax.lax.broadcasted_iota(jnp.int32, cat_s.shape, 1)
    out_s, out_i = [], []
    for _ in range(k):
        m = jnp.max(cat_s, axis=1, keepdims=True)  # [TQ,1]
        is_m = cat_s == m
        # first position attaining the max (stable tie-break)
        first = jnp.min(jnp.where(is_m, pos, width), axis=1, keepdims=True)
        sel = pos == first
        out_s.append(m[:, 0])
        out_i.append(jnp.sum(jnp.where(sel, cat_i, 0), axis=1))
        cat_s = jnp.where(sel, NEG_INF, cat_s)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1).astype(jnp.int32)


def _fused_knn_kernel(
    q_ref,  # [TQ, D]
    v_ref,  # [TV, D]
    valid_ref,  # [1, TV] int32 (0/1)
    out_s_ref,  # [TQ, K]
    out_i_ref,  # [TQ, K]
    acc_s_ref,  # scratch f32 [TQ, K]
    acc_i_ref,  # scratch i32 [TQ, K]
    *,
    k: int,
    tv: int,
    metric: str,
    nv_tiles: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_s_ref[...] = jnp.full(acc_s_ref.shape, NEG_INF, jnp.float32)
        acc_i_ref[...] = jnp.full(acc_i_ref.shape, -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)  # [TQ, D]
    v = v_ref[...].astype(jnp.float32)  # [TV, D]
    ip = jax.lax.dot_general(
        q, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [TQ, TV] on the MXU
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)  # [TQ,1]
        vn = jnp.sum(v * v, axis=1)[None, :]  # [1,TV]
        scores = 2.0 * ip - qn - vn
    else:
        scores = ip
    valid = valid_ref[0, :] != 0  # [TV]
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = col + j * tv  # global vector index
    gidx = jnp.where(valid[None, :], gidx, -1)

    new_s, new_i = _merge_topk(acc_s_ref[...], acc_i_ref[...], scores, gidx, k)
    acc_s_ref[...] = new_s
    acc_i_ref[...] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = acc_s_ref[...]
        out_i_ref[...] = acc_i_ref[...]


def _fused_knn_db_stationary_kernel(
    q_ref,  # [TQ, D]
    v_ref,  # [TV, D]
    valid_ref,  # [1, TV]
    out_s_ref,  # [TQ, K]
    out_i_ref,  # [TQ, K]
    acc_s_ref,  # scratch f32 [NQP, K] — ALL query tiles' running top-k
    acc_i_ref,  # scratch i32 [NQP, K]
    *,
    k: int,
    tq: int,
    tv: int,
    metric: str,
    nq_tiles: int,
    nv_tiles: int,
):
    """DB-stationary grid (v outer, q inner): each DB tile is read ONCE from

    HBM and every query tile's running top-k lives in VMEM scratch across the
    whole sweep. HBM traffic drops from O(nq_tiles · NV · d) to
    O(NV·d + NQ·d·nv_tiles) — the right order when NV ≫ NQ (batch search
    against a big posting-list/index shard, the HQI serving shape)."""
    j = pl.program_id(0)  # v tile (outer)
    i = pl.program_id(1)  # q tile (inner)

    @pl.when(j == 0)
    def _init():
        acc_s_ref[pl.ds(i * tq, tq), :] = jnp.full((tq, k), NEG_INF, jnp.float32)
        acc_i_ref[pl.ds(i * tq, tq), :] = jnp.full((tq, k), -1, jnp.int32)

    q = q_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    ip = jax.lax.dot_general(q, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if metric == "l2":
        qn = jnp.sum(q * q, axis=1, keepdims=True)
        vn = jnp.sum(v * v, axis=1)[None, :]
        scores = 2.0 * ip - qn - vn
    else:
        scores = ip
    valid = valid_ref[0, :] != 0
    scores = jnp.where(valid[None, :], scores, NEG_INF)
    col = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    gidx = jnp.where(valid[None, :], col + j * tv, -1)

    run_s = acc_s_ref[pl.ds(i * tq, tq), :]
    run_i = acc_i_ref[pl.ds(i * tq, tq), :]
    new_s, new_i = _merge_topk(run_s, run_i, scores, gidx, k)
    acc_s_ref[pl.ds(i * tq, tq), :] = new_s
    acc_i_ref[pl.ds(i * tq, tq), :] = new_i

    @pl.when(j == nv_tiles - 1)
    def _flush():
        out_s_ref[...] = new_s
        out_i_ref[...] = new_i


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "tq", "tv", "interpret"),
)
def fused_knn_db_stationary(
    q: jax.Array,
    v: jax.Array,
    valid: jax.Array,
    *,
    k: int,
    metric: str = "ip",
    tq: int = 128,
    tv: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """DB-stationary variant — preferred when NV ≫ NQ (see kernel docstring).

    VMEM budget: scratch is (NQ_padded, k) floats+ints ≈ 12·NQ·k bytes; with
    k=10 a full 64k-query batch fits in ~8 MB of VMEM."""
    nq, d = q.shape
    nv = v.shape[0]
    k = int(k)
    nq_p = max(tq, ((nq + tq - 1) // tq) * tq)
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    q_p = jnp.zeros((nq_p, d), q.dtype).at[:nq].set(q)
    v_p = jnp.zeros((nv_p, d), v.dtype).at[:nv].set(v)
    valid_p = jnp.zeros((1, nv_p), jnp.int32).at[0, :nv].set(valid.astype(jnp.int32))
    nq_tiles, nv_tiles = nq_p // tq, nv_p // tv

    kernel = functools.partial(
        _fused_knn_db_stationary_kernel,
        k=k, tq=tq, tv=tv, metric=metric, nq_tiles=nq_tiles, nv_tiles=nv_tiles,
    )
    call = pl.pallas_call(
        kernel,
        grid=(nv_tiles, nq_tiles),  # v outer, q inner
        in_specs=[
            pl.BlockSpec((tq, d), lambda j, i: (i, 0)),
            pl.BlockSpec((tv, d), lambda j, i: (j, 0)),
            pl.BlockSpec((1, tv), lambda j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda j, i: (i, 0)),
            pl.BlockSpec((tq, k), lambda j, i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq_p, k), jnp.float32),
            jax.ShapeDtypeStruct((nq_p, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq_p, k), jnp.float32),
            pltpu.VMEM((nq_p, k), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_i = call(q_p, v_p, valid_p)
    return out_s[:nq], out_i[:nq]


@functools.partial(
    jax.jit,
    static_argnames=("k", "metric", "tq", "tv", "interpret"),
)
def fused_knn(
    q: jax.Array,  # [NQ, D]
    v: jax.Array,  # [NV, D]
    valid: jax.Array,  # bool [NV]
    *,
    k: int,
    metric: str = "ip",
    tq: int = 128,
    tv: int = 512,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (scores f32 [NQ,k] best-first, idx i32 [NQ,k]; -1 = none).

    NQ, NV need not be tile-aligned — we pad here; D should be modest (the
    whole vector fits one block; HQI embeddings are 64–256 dims).
    """
    nq, d = q.shape
    nv = v.shape[0]
    k = int(k)
    nq_p = max(tq, ((nq + tq - 1) // tq) * tq)
    nv_p = max(tv, ((nv + tv - 1) // tv) * tv)
    q_p = jnp.zeros((nq_p, d), q.dtype).at[:nq].set(q)
    v_p = jnp.zeros((nv_p, d), v.dtype).at[:nv].set(v)
    valid_p = jnp.zeros((1, nv_p), jnp.int32).at[0, :nv].set(valid.astype(jnp.int32))
    nq_tiles, nv_tiles = nq_p // tq, nv_p // tv

    kernel = functools.partial(
        _fused_knn_kernel, k=k, tv=tv, metric=metric, nv_tiles=nv_tiles
    )
    call = pl.pallas_call(
        kernel,
        grid=(nq_tiles, nv_tiles),
        in_specs=[
            pl.BlockSpec((tq, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tv), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tq, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq_p, k), jnp.float32),
            jax.ShapeDtypeStruct((nq_p, k), jnp.int32),
        ],
        # Running top-k per query tile, carried in VMEM across the inner grid dim.
        scratch_shapes=[
            pltpu.VMEM((tq, k), jnp.float32),
            pltpu.VMEM((tq, k), jnp.int32),
        ],
        interpret=interpret,
    )
    out_s, out_i = call(q_p, v_p, valid_p)
    return out_s[:nq], out_i[:nq]
