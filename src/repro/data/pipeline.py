"""Deterministic, sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — this is the
fault-tolerance/straggler primitive: any host can (re)compute any shard of
any step with no coordination, restarts replay identically, and elastic
re-sharding (different host count) is just a different shard slicing of the
same step stream. A background prefetch thread keeps one batch ahead.

The "corpus" is a mixture of Zipfian token draws and repeated n-gram motifs
so that a small LM shows a real, declining loss curve (useful for the
end-to-end example), while needing no files on disk.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1  # hosts
    zipf_a: float = 1.2
    motif_len: int = 16
    n_motifs: int = 512


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed motif bank (the learnable structure)
        self.motifs = rng.integers(2, cfg.vocab, size=(cfg.n_motifs, cfg.motif_len)).astype(
            np.int32
        )
        # Zipf over vocab via inverse-CDF on ranks
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self.zipf_cdf = np.cumsum(p / p.sum())

    def _tokens(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        i = 0
        while i < n:
            if rng.random() < 0.5:
                m = self.motifs[rng.integers(0, self.cfg.n_motifs)]
                ln = min(len(m), n - i)
                out[i : i + ln] = m[:ln]
                i += ln
            else:
                ln = min(int(rng.integers(4, 17)), n - i)
                u = rng.random(ln)
                out[i : i + ln] = np.searchsorted(self.zipf_cdf, u).astype(np.int32)
                i += ln
        return out

    def batch(self, step: int, shard: int = 0) -> Dict[str, np.ndarray]:
        """The shard's slice of the global batch at ``step`` (pure function)."""
        cfg = self.cfg
        assert cfg.global_batch % cfg.n_shards == 0
        per_shard = cfg.global_batch // cfg.n_shards
        rng = np.random.default_rng((cfg.seed, step, shard))
        toks = np.stack([self._tokens(rng, cfg.seq_len + 1) for _ in range(per_shard)])
        return {"tokens": toks[:, :-1].astype(np.int32), "labels": toks[:, 1:].astype(np.int32)}


class Prefetcher:
    """One-batch-ahead background prefetch over a SyntheticLM stream."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0, shard: int = 0, depth: int = 2):
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.shard = shard
        self._stop = threading.Event()
        self._step = start_step
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self._step
        while not self._stop.is_set():
            b = self.ds.batch(s, self.shard)
            try:
                self.q.put((s, b), timeout=1.0)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def stop(self):
        self._stop.set()
