"""repro.obs — unified observability: tracing, metrics, drift monitoring.

Three pillars, one import surface:

  * ``obs.trace`` — process-wide span tracer exporting Chrome-trace JSON
    (Perfetto-loadable); disabled by default via a free ``NullTracer``.
  * ``obs.metrics`` — counters/gauges/bounded-histograms registry unifying
    the layers' ad-hoc stats behind one ``snapshot()``/``to_json()``.
  * ``obs.drift`` — sliding-window workload monitor emitting the
    ``DriftReport`` the hot-swap index tuner consumes.

This package is imported by hot serving paths — keep it stdlib-light at
module level (numpy only); anything heavy (jax, the engine) loads lazily
inside functions.
"""
from .drift import DriftConfig, DriftMonitor, DriftReport
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_registry,
    set_registry,
)
from .trace import (
    NullTracer,
    Tracer,
    disable,
    enable,
    fence,
    get_tracer,
    set_tracer,
    validate_chrome_trace,
)

__all__ = [
    "DriftConfig",
    "DriftMonitor",
    "DriftReport",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "NullTracer",
    "Tracer",
    "disable",
    "enable",
    "fence",
    "get_tracer",
    "set_tracer",
    "validate_chrome_trace",
]
