"""repro.obs — unified observability: tracing, metrics, drift monitoring.

Five pillars, one import surface:

  * ``obs.trace`` — process-wide span tracer exporting Chrome-trace JSON
    (Perfetto-loadable); disabled by default via a free ``NullTracer``.
  * ``obs.metrics`` — counters/gauges/bounded-histograms registry unifying
    the layers' ad-hoc stats behind one ``snapshot()``/``to_json()``, plus
    declarative ``Objective`` SLOs evaluated against registry instruments.
  * ``obs.drift`` — sliding-window workload monitor emitting the
    ``DriftReport`` the hot-swap index tuner consumes.
  * ``obs.profile`` — kernel-grained dispatch profiler attributing device
    time to plan-derived bytes/FLOPs against ``launch.roofline`` hardware
    terms; disabled by default via a free ``NullProfiler``.
  * ``obs.flight`` — always-on bounded flight recorder dumping atomic
    postmortem incident bundles when declarative trigger rules fire.

This package is imported by hot serving paths — keep it stdlib-light at
module level (numpy only); anything heavy (jax, the engine) loads lazily
inside functions.
"""
from .drift import DriftConfig, DriftMonitor, DriftReport
from .flight import (
    FlightRecorder,
    FlightSample,
    TriggerRule,
    default_rules,
    slo_rule,
    validate_incident_bundle,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Objective,
    get_registry,
    set_registry,
)
from .profile import (
    KernelProfiler,
    NullProfiler,
    disable_profiler,
    enable_profiler,
    get_profiler,
    set_profiler,
)
from .trace import (
    NullTracer,
    Tracer,
    disable,
    enable,
    fence,
    get_tracer,
    get_thread_name,
    set_thread_name,
    set_tracer,
    validate_chrome_trace,
)

__all__ = [
    "DriftConfig",
    "DriftMonitor",
    "DriftReport",
    "FlightRecorder",
    "FlightSample",
    "TriggerRule",
    "default_rules",
    "slo_rule",
    "validate_incident_bundle",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Objective",
    "get_registry",
    "set_registry",
    "KernelProfiler",
    "NullProfiler",
    "disable_profiler",
    "enable_profiler",
    "get_profiler",
    "set_profiler",
    "NullTracer",
    "Tracer",
    "disable",
    "enable",
    "fence",
    "get_tracer",
    "get_thread_name",
    "set_thread_name",
    "set_tracer",
    "validate_chrome_trace",
]
