"""SLO-triggered flight recorder: an always-on black box for serving.

The observability stack so far is *pull*: an operator enables tracing, runs
traffic, reads the export. Incidents don't wait for an operator. The
``FlightRecorder`` keeps a bounded black box running next to an
``HQIService`` — recent spans (it installs a bounded ``Tracer`` if none is
active), metric snapshots, recent flush records, health transitions — and
polls a set of declarative ``TriggerRule``s. When a rule trips it atomically
dumps a postmortem bundle to a bounded on-disk ring of incident directories:

    incidents/
      incident-0001-flush_crash/
        manifest.json   schema, seq, tripped rules + detail, health + recent
                        transitions, telemetry summary, recent flush records,
                        armed failpoints, CURRENT generation pointer
        trace.json      Chrome-trace export of the retained span ring (the
                        offending window — validate_chrome_trace-clean)
        metrics.json    registry snapshot with full histogram buckets
        profile.json    KernelProfiler report (``{"enabled": false}`` when
                        profiling is off)

Built-in rules are *edge-triggered* on (prev, cur) observation pairs —
flush crash (``flush_failures`` delta), index swap, deadline spike,
``health()`` leaving ``ok`` — plus ``slo_rule`` wrapping an
``obs.metrics.Objective`` (latency/recall SLOs), which fires once per
continuous breach. Every rule also has a cooldown, and one ``observe()``
dumps at most one bundle listing every rule that tripped — so a single
incident produces a single bundle, never a dump storm.

Bundles publish via tmp-dir + ``os.rename`` (atomic: a crash mid-dump never
leaves a half-readable incident) and the ring prunes oldest-first beyond
``max_incidents``. ``validate_incident_bundle`` is the schema check shared
by tests, the perf bench, and CI.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from . import trace as _trace
from .metrics import Objective, get_registry
from .profile import get_profiler

__all__ = [
    "FlightRecorder",
    "FlightSample",
    "TriggerRule",
    "default_rules",
    "slo_rule",
    "validate_incident_bundle",
    "INCIDENT_SCHEMA",
]

INCIDENT_SCHEMA = "hqi-incident-v1"

_MANIFEST_REQUIRED = {
    "schema",
    "seq",
    "rules",
    "detail",
    "t_unix",
    "health",
    "telemetry",
    "health_transitions",
    "recent_flushes",
    "armed_failpoints",
    "current_generation",
}

_BUNDLE_FILES = ("manifest.json", "trace.json", "metrics.json", "profile.json")


@dataclasses.dataclass
class FlightSample:
    """One poll's view of the service: health rollup + telemetry summary."""

    t: float  # perf_counter seconds
    health: Dict[str, Any]
    telemetry: Dict[str, float]


@dataclasses.dataclass(frozen=True)
class TriggerRule:
    """Edge-triggered incident predicate over (prev, cur) samples.

    ``check(prev, cur)`` returns a human-readable detail string to trip, or
    None. ``cooldown_s`` suppresses re-firing of the SAME rule while the
    condition persists across polls.
    """

    name: str
    check: Callable[[FlightSample, FlightSample], Optional[str]]
    cooldown_s: float = 5.0


def _delta_rule(name: str, key: str, threshold: float = 1.0) -> TriggerRule:
    def check(prev: FlightSample, cur: FlightSample) -> Optional[str]:
        d = cur.telemetry.get(key, 0.0) - prev.telemetry.get(key, 0.0)
        if d >= threshold:
            return f"{key} +{d:g} in one poll (threshold {threshold:g})"
        return None

    return TriggerRule(name, check)


def _health_rule() -> TriggerRule:
    def check(prev: FlightSample, cur: FlightSample) -> Optional[str]:
        was, now = prev.health.get("status"), cur.health.get("status")
        if was == "ok" and now != "ok":
            return f"health left ok: {was} -> {now}"
        return None

    return TriggerRule("health", check)


def slo_rule(obj: Objective, cooldown_s: float = 30.0) -> TriggerRule:
    """Objective → rule, firing once per *continuous* breach: histograms are
    lifetime-cumulative, so a breached p99 stays breached — without the
    edge-tracking here every poll past the cooldown would re-dump."""
    state = {"breached": False}

    def check(prev: FlightSample, cur: FlightSample) -> Optional[str]:
        detail = obj.evaluate()
        if detail is None:
            state["breached"] = False
            return None
        if state["breached"]:
            return None
        state["breached"] = True
        return detail

    return TriggerRule(f"slo:{obj.name}", check, cooldown_s)


def default_rules(
    objectives: Sequence[Objective] = (), deadline_spike: int = 8
) -> List[TriggerRule]:
    """The built-in trigger matrix: flush crash, index swap, deadline spike,
    health leaving ok, plus one slo_rule per objective."""
    rules = [
        _delta_rule("flush_crash", "flush_failures"),
        _delta_rule("index_swap", "index_swaps"),
        _delta_rule("deadline_spike", "deadline_expired", float(deadline_spike)),
        _health_rule(),
    ]
    rules.extend(slo_rule(o) for o in objectives)
    return rules


class FlightRecorder:
    """Bounded black box + trigger rules + atomic incident bundles.

    Drive it manually (``observe()`` per poll — what the tests do for
    determinism) or with ``start()``/``stop()`` for the background daemon
    (thread-labeled ``flight``). ``force(reason)`` dumps unconditionally.
    """

    def __init__(
        self,
        service,
        root: str,
        *,
        rules: Optional[Sequence[TriggerRule]] = None,
        objectives: Sequence[Objective] = (),
        max_incidents: int = 8,
        poll_s: float = 0.05,
        trace_capacity: int = 16_384,
        store_root: Optional[str] = None,
        history: int = 64,
    ) -> None:
        self.service = service
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.rules = list(rules) if rules is not None else default_rules(objectives)
        self.max_incidents = int(max_incidents)
        self.poll_s = float(poll_s)
        self.trace_capacity = int(trace_capacity)
        self.store_root = store_root
        self.incidents_written = 0
        self._lock = threading.Lock()
        self._history: deque = deque(maxlen=int(history))
        self._transitions: deque = deque(maxlen=int(history))
        self._last_fire: Dict[str, float] = {}
        self._prev: Optional[FlightSample] = None
        self._seq = self._max_existing_seq()
        self._owns_tracer = False
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        """Poll on a daemon thread; installs a bounded tracer (the black
        box's span ring) if none is active."""
        assert self._thread is None, "flight recorder already running"
        if not _trace.get_tracer().enabled:
            _trace.enable(capacity=self.trace_capacity)
            self._owns_tracer = True
        self._stop_flag.clear()

        def loop() -> None:
            _trace.set_thread_name("flight")
            while not self._stop_flag.wait(self.poll_s):
                try:
                    self.observe()
                except Exception:
                    pass  # the recorder must never take the service down

        self._thread = threading.Thread(target=loop, name="hqi-flight", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop_flag.set()
            self._thread.join()
            self._thread = None
        if self._owns_tracer:
            _trace.disable()
            self._owns_tracer = False

    # ------------------------------------------------------------ observing

    def _sample(self) -> FlightSample:
        return FlightSample(
            t=time.perf_counter(),
            health=self.service.health().as_dict(),
            telemetry=self.service.telemetry.summary(),
        )

    def observe(self) -> Optional[str]:
        """One poll: sample, track health transitions, evaluate every rule.
        At most ONE incident bundle per call (listing every tripped rule);
        returns its path, or None."""
        cur = self._sample()
        with self._lock:
            prev = self._prev
            self._prev = cur
            self._history.append(cur)
            if prev is not None and prev.health.get("status") != cur.health.get("status"):
                self._transitions.append(
                    {
                        "t": cur.t,
                        "from": prev.health.get("status"),
                        "to": cur.health.get("status"),
                    }
                )
            if prev is None:
                return None  # first sample: nothing to edge-trigger against
            tripped: List[Tuple[str, str]] = []
            for rule in self.rules:
                last = self._last_fire.get(rule.name)
                if last is not None and cur.t - last < rule.cooldown_s:
                    continue
                try:
                    detail = rule.check(prev, cur)
                except Exception:
                    detail = None  # a broken rule must not break the poll
                if detail:
                    tripped.append((rule.name, detail))
                    self._last_fire[rule.name] = cur.t
            if not tripped:
                return None
            return self._dump_locked(tripped, cur)

    def force(self, reason: str = "manual") -> str:
        """Unconditional dump (operator-initiated postmortem)."""
        cur = self._sample()
        with self._lock:
            self._prev = cur
            self._history.append(cur)
            return self._dump_locked([("forced", reason)], cur)

    # -------------------------------------------------------------- dumping

    def _max_existing_seq(self) -> int:
        seq = 0
        try:
            for name in os.listdir(self.root):
                if name.startswith("incident-"):
                    try:
                        seq = max(seq, int(name.split("-")[1]))
                    except (IndexError, ValueError):
                        continue
        except OSError:
            pass
        return seq

    def _dump_locked(self, tripped: List[Tuple[str, str]], cur: FlightSample) -> str:
        self._seq += 1
        rule_names = [n for n, _ in tripped]
        dirname = f"incident-{self._seq:04d}-{rule_names[0].replace(':', '_')}"
        final = os.path.join(self.root, dirname)
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)

        tracer = _trace.get_tracer()
        try:
            tracer.export(os.path.join(tmp, "trace.json"))
        except Exception:
            with open(os.path.join(tmp, "trace.json"), "w") as f:
                json.dump({"traceEvents": []}, f)
        with open(os.path.join(tmp, "metrics.json"), "w") as f:
            f.write(get_registry().to_json(indent=2, detail=True))
        prof = get_profiler()
        with open(os.path.join(tmp, "profile.json"), "w") as f:
            json.dump(prof.report(), f, indent=2)

        current_gen = None
        if self.store_root is not None:
            try:
                from ..store.snapshot import current_generation

                current_gen = current_generation(self.store_root)
            except Exception:
                pass
        try:
            from ..fault import failpoints as _fp

            armed = sorted(_fp.list_armed())
        except Exception:
            armed = []
        try:
            recent = self.service.telemetry.recent_flushes()
        except Exception:
            recent = []
        manifest = {
            "schema": INCIDENT_SCHEMA,
            "seq": self._seq,
            "rules": rule_names,
            "detail": dict(tripped),
            "t_unix": time.time(),
            "t_perf": cur.t,
            "health": cur.health,
            "telemetry": cur.telemetry,
            "health_transitions": list(self._transitions),
            "recent_flushes": recent,
            "armed_failpoints": armed,
            "current_generation": current_gen,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=2, default=str)

        os.rename(tmp, final)  # atomic publish: readers never see a partial
        self.incidents_written += 1
        self._prune_locked()
        return final

    def _prune_locked(self) -> None:
        dirs = sorted(
            n for n in os.listdir(self.root)
            if n.startswith("incident-") and not n.endswith(".tmp")
        )
        for name in dirs[: max(0, len(dirs) - self.max_incidents)]:
            shutil.rmtree(os.path.join(self.root, name), ignore_errors=True)

    def incidents(self) -> List[str]:
        """Retained incident directories, oldest first."""
        return sorted(
            os.path.join(self.root, n)
            for n in os.listdir(self.root)
            if n.startswith("incident-") and not n.endswith(".tmp")
        )


def validate_incident_bundle(path: str) -> Dict[str, Any]:
    """Schema-check one incident directory; returns its manifest.

    Shared by the tests, bench_perf's live-incident smoke, and CI: required
    files present, manifest fields complete, the trace Chrome-trace-valid,
    metrics/profile JSON-parseable. Raises ValueError on any violation.
    """
    for name in _BUNDLE_FILES:
        if not os.path.isfile(os.path.join(path, name)):
            raise ValueError(f"incident bundle {path!r} missing {name}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    missing = _MANIFEST_REQUIRED - set(manifest)
    if missing:
        raise ValueError(f"manifest missing fields {sorted(missing)}")
    if manifest["schema"] != INCIDENT_SCHEMA:
        raise ValueError(f"unknown incident schema {manifest['schema']!r}")
    if not manifest["rules"]:
        raise ValueError("incident tripped no rules")
    with open(os.path.join(path, "trace.json")) as f:
        _trace.validate_chrome_trace(json.load(f))
    with open(os.path.join(path, "metrics.json")) as f:
        json.load(f)
    with open(os.path.join(path, "profile.json")) as f:
        json.load(f)
    return manifest
