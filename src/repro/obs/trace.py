"""Lightweight end-to-end query tracing (Chrome-trace / Perfetto export).

One process-wide tracer (``get_tracer``/``set_tracer``) that every layer of
the serving stack reports into: the service records flush/queue-wait/WAL
spans, ``core.planner`` records per-bucket kernel dispatches and merges,
``repro.store`` records snapshot writes/loads and fsyncs. Spans nest by
thread (a thread-local stack tracks the enclosing span), use the monotonic
``time.perf_counter_ns`` clock — the SAME clock the service stamps
``QueryHandle.t_submit`` with, so retroactive spans (``add_span``) can cover
submit→flush queue waits exactly — and land in a bounded ring buffer, so a
long-lived service never grows memory with uptime.

``export(path)`` writes Chrome-trace JSON (the ``traceEvents`` array format)
that loads directly in Perfetto / chrome://tracing; ``validate_chrome_trace``
is the schema check shared by the tests and the CI guard.

Cost discipline: the default tracer is a ``NullTracer`` singleton whose
``span`` returns one shared no-op context manager — no event objects, no
ring-buffer traffic, nothing retained — so instrumentation left in hot paths
is free until an operator calls ``enable()``. Device-time honesty: span
bodies that dispatch async jax work call ``fence(...)`` before closing, which
``block_until_ready``s the outputs ONLY when tracing is enabled, so dispatch
spans measure real device time without perturbing the untraced fast path.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer",
    "NullTracer",
    "get_tracer",
    "set_tracer",
    "enable",
    "disable",
    "fence",
    "set_thread_name",
    "get_thread_name",
    "validate_chrome_trace",
]


# ---------------------------------------------------------------------------
# Thread labels: background loops (service scheduler, compactor, tuner,
# flight recorder) call set_thread_name() once at the top of their loop; the
# tracer stamps the label on every ROOT span that thread opens (nested spans
# already carry a parent chain) and emits one Chrome "M" thread_name
# metadata event per thread, so incident bundles can tell background work
# from request work in Perfetto.
# ---------------------------------------------------------------------------

_THREAD_CTX = threading.local()


def set_thread_name(name: Optional[str]) -> None:
    """Label the calling thread's future root spans (None clears it)."""
    _THREAD_CTX.name = None if name is None else str(name)


def get_thread_name() -> Optional[str]:
    return getattr(_THREAD_CTX, "name", None)


class _NullSpan:
    """Shared no-op context manager returned by ``NullTracer.span``."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every call is a no-op, nothing is ever recorded."""

    enabled = False

    def span(self, name: str, **args) -> _NullSpan:
        return _NULL_SPAN

    def add_span(self, name: str, t0_s: float, t1_s: float, **args) -> None:
        pass

    def instant(self, name: str, **args) -> None:
        pass

    def counter(self, name: str, value: float) -> None:
        pass

    @property
    def span_count(self) -> int:
        return 0

    def events(self) -> List[dict]:
        return []

    def reset(self) -> None:
        pass

    def export(self, path: str) -> str:
        doc = {"traceEvents": [], "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(doc, f)
        return path


class _Span:
    """Context manager recording one duration event on exit."""

    __slots__ = ("tracer", "name", "args", "t0", "tid", "parent")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]) -> None:
        self.tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        stack = self.tracer._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.name)
        self.tid = threading.get_ident()
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        stack = self.tracer._stack()
        if stack and stack[-1] == self.name:
            stack.pop()
        self.tracer._record(self.name, self.t0, t1, self.tid, self.parent, self.args)
        return False


class Tracer:
    """Ring-buffered span recorder exporting Chrome-trace JSON.

    Thread-safe: spans may open/close concurrently on the scheduler thread,
    writer threads, and foreground callers; each completed span appends one
    event under the lock. ``capacity`` bounds retained events (oldest spans
    evict first); ``span_count`` keeps the lifetime total so tests can assert
    activity even after eviction.
    """

    enabled = True

    def __init__(self, capacity: int = 65_536) -> None:
        assert capacity >= 1
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._head = 0  # ring cursor once the buffer is full
        self._count = 0
        self._local = threading.local()
        self._named_tids: set = set()  # tids with a thread_name "M" event
        # epoch for relative timestamps: the same perf_counter clock the
        # service uses, so add_span can take raw perf_counter floats
        self._t0_ns = time.perf_counter_ns()

    # --------------------------------------------------------------- recording

    def _stack(self) -> List[str]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def span(self, name: str, **args) -> _Span:
        """``with tracer.span("flush.dispatch", bucket=...):`` — one event."""
        return _Span(self, name, args)

    def _record(
        self,
        name: str,
        t0_ns: int,
        t1_ns: int,
        tid: int,
        parent: Optional[str],
        args: Dict[str, Any],
    ) -> None:
        ev = {
            "name": name,
            "ph": "X",
            "ts": (t0_ns - self._t0_ns) / 1e3,  # Chrome trace wants microseconds
            "dur": max(0.0, (t1_ns - t0_ns) / 1e3),
            "pid": 1,
            "tid": tid,
        }
        meta = None
        if parent is not None:
            args = dict(args, parent=parent)
        else:
            label = getattr(_THREAD_CTX, "name", None)
            if label is not None:
                args = dict(args, thread=label)
                if tid not in self._named_tids:
                    meta = {
                        "name": "thread_name",
                        "ph": "M",
                        "ts": 0.0,
                        "pid": 1,
                        "tid": tid,
                        "args": {"name": label},
                    }
        if args:
            ev["args"] = args
        with self._lock:
            if meta is not None and tid not in self._named_tids:
                self._named_tids.add(tid)
                if len(self._events) < self.capacity:
                    self._events.append(meta)
                else:
                    self._events[self._head] = meta
                    self._head = (self._head + 1) % self.capacity
                self._count += 1
            if len(self._events) < self.capacity:
                self._events.append(ev)
            else:  # ring: overwrite the oldest slot
                self._events[self._head] = ev
                self._head = (self._head + 1) % self.capacity
            self._count += 1

    def add_span(self, name: str, t0_s: float, t1_s: float, **args) -> None:
        """Record a span retroactively from two ``time.perf_counter()`` stamps
        (e.g. a query's submit→flush queue wait, known only at flush time)."""
        self._record(
            name,
            int(t0_s * 1e9),
            int(t1_s * 1e9),
            threading.get_ident(),
            None,
            args,
        )

    def instant(self, name: str, **args) -> None:
        """Point-in-time marker (Chrome-trace instant event)."""
        ev = {
            "name": name,
            "ph": "i",
            "ts": (time.perf_counter_ns() - self._t0_ns) / 1e3,
            "pid": 1,
            "tid": threading.get_ident(),
            "s": "t",
        }
        if args:
            ev["args"] = args
        with self._lock:
            if len(self._events) < self.capacity:
                self._events.append(ev)
            else:
                self._events[self._head] = ev
                self._head = (self._head + 1) % self.capacity
            self._count += 1

    def counter(self, name: str, value: float) -> None:
        """Chrome-trace counter sample (renders as a track in Perfetto)."""
        ev = {
            "name": name,
            "ph": "C",
            "ts": (time.perf_counter_ns() - self._t0_ns) / 1e3,
            "pid": 1,
            "tid": threading.get_ident(),
            "args": {"value": float(value)},
        }
        with self._lock:
            if len(self._events) < self.capacity:
                self._events.append(ev)
            else:
                self._events[self._head] = ev
                self._head = (self._head + 1) % self.capacity
            self._count += 1

    # ----------------------------------------------------------------- reading

    @property
    def span_count(self) -> int:
        """Lifetime number of recorded events (survives ring eviction)."""
        with self._lock:
            return self._count

    def events(self) -> List[dict]:
        """Retained events, oldest first (a consistent copy)."""
        with self._lock:
            return self._events[self._head:] + self._events[: self._head]

    def reset(self) -> None:
        with self._lock:
            self._events = []
            self._head = 0
            self._count = 0

    # ------------------------------------------------------------------ export

    def to_chrome_trace(self) -> dict:
        """The Chrome-trace document (``traceEvents`` array format)."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the trace as Chrome-trace JSON viewable in Perfetto."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


# ---------------------------------------------------------------------------
# Process-wide tracer (default: disabled)
# ---------------------------------------------------------------------------

_NULL = NullTracer()
_TRACER = _NULL


def get_tracer():
    """The process-wide tracer every instrumented layer reports to."""
    return _TRACER


def set_tracer(tracer) -> None:
    global _TRACER
    _TRACER = _NULL if tracer is None else tracer


def enable(capacity: int = 65_536) -> Tracer:
    """Install (and return) a fresh recording tracer."""
    t = Tracer(capacity=capacity)
    set_tracer(t)
    return t


def disable() -> None:
    """Back to the free no-op tracer."""
    set_tracer(_NULL)


# The KernelProfiler needs fenced dispatch timings even when no tracer is
# installed (profiling without the trace ring): obs.profile sets this hold
# on enable so fence() still blocks for real device time.
_FENCE_HOLD = False


def _set_fence_hold(on: bool) -> None:
    global _FENCE_HOLD
    _FENCE_HOLD = bool(on)


def fence(*arrays):
    """``jax.block_until_ready`` the values IFF tracing/profiling is enabled.

    Dispatch sites call this inside their span so the recorded duration is
    real device time, not async-dispatch time; with the NullTracer installed
    (and no profiler) it is a no-op and the async pipeline is untouched.
    """
    if (_TRACER.enabled or _FENCE_HOLD) and arrays:
        import jax

        jax.block_until_ready(arrays)
    return arrays[0] if len(arrays) == 1 else arrays


# ---------------------------------------------------------------------------
# Schema validation (shared by tests and the CI trace guard)
# ---------------------------------------------------------------------------

_REQUIRED = {"name", "ph", "ts", "pid", "tid"}
_PHASES = {"X", "i", "I", "C", "M", "b", "e", "B", "E"}


def validate_chrome_trace(doc: Any) -> int:
    """Validate a Chrome-trace document; returns the event count.

    Checks the contract Perfetto's importer relies on: a ``traceEvents``
    array (or a bare array) of events each carrying name/ph/ts/pid/tid,
    known phase codes, non-negative durations on complete events, and JSON-
    serializable args. Raises ``ValueError`` with the offending event index.
    """
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace document has no 'traceEvents' array")
    elif isinstance(doc, list):
        events = doc
    else:
        raise ValueError(f"not a trace document: {type(doc).__name__}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        missing = _REQUIRED - set(ev)
        if missing:
            raise ValueError(f"event {i} missing fields {sorted(missing)}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            raise ValueError(f"event {i} has a non-string/empty name")
        if ev["ph"] not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i} has non-numeric ts")
        if ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"event {i} ('X') needs a non-negative dur")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i} args is not an object")
    return len(events)
