"""Workload-drift monitor: the sensor the hot-swap index tuner reads.

The paper's thesis is that the index layout should follow the *workload* —
but the qd-tree/IVF layout is frozen at build time while live traffic moves.
``DriftMonitor`` watches the serving stream and answers the four questions a
re-partitioning tuner has to ask before spending a rebuild:

  1. **Template traffic** — a sliding window of per-query filter templates;
     ``report()`` splits the window in half and scores the total-variation
     distance between the older and recent halves' template shares
     (``share_shift`` in [0, 1]: 0 = stationary mix, 1 = disjoint mixes).
  2. **Probe heat** — per-partition routed-query counts over recent flushes,
     normalized to shares: a hot partition is a split candidate, a cold one
     a merge candidate.
  3. **Delta growth** — cumulative delta-store rows over time → rows/s, i.e.
     how fast the frozen layout is going stale.
  4. **Recall health** — a small reservoir sample of *answered* queries
     (vector, filter, served ids); ``live_recall`` replays them against a
     brute-force scan of the service's current live DB and scores overlap.
     This is ground truth — if it sags, nprobe/layout tuning is overdue.

Everything is O(window) memory and lock-protected (the scheduler thread
feeds it while callers read reports). The module stays import-light: heavy
deps (numpy at module level is fine; ``core.baselines`` for the recall
probe) load lazily so ``repro.obs`` never drags the engine in by accident.
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
import time
from collections import Counter, deque
from typing import Any, Dict, Hashable, Iterable, List, Optional, Tuple

import numpy as np

__all__ = ["DriftConfig", "DriftMonitor", "DriftReport"]


@dataclasses.dataclass
class DriftConfig:
    window: int = 4096  # per-query template observations retained
    heat_window: int = 256  # per-flush probe-heat observations retained
    growth_window: int = 256  # (t, delta_rows) samples retained
    reservoir: int = 64  # answered queries kept for the recall probe
    seed: int = 0  # reservoir RNG (deterministic for tests)


@dataclasses.dataclass
class DriftReport:
    """One point-in-time reading; the hot-swap tuner consumes this verbatim."""

    n_window: int  # template observations backing the shares
    window_span_s: float  # wall-time the window covers
    template_shares: Dict[str, float]  # recent-half traffic share per template
    reference_shares: Dict[str, float]  # older-half traffic share per template
    share_shift: float  # total-variation distance, recent vs older half
    part_heat: Dict[int, float]  # partition -> share of routed queries
    delta_rows: int  # current delta-store row count
    delta_growth_per_s: float  # delta rows per second over the growth window
    recall_at_k: Optional[float] = None  # live recall probe (None = not run)
    recall_k: int = 0
    recall_samples: int = 0

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent)


def _shares(counts: Counter) -> Dict[str, float]:
    total = sum(counts.values())
    if total == 0:
        return {}
    return {str(k): c / total for k, c in sorted(counts.items(), key=lambda kv: str(kv[0]))}


def _tv_distance(a: Dict[str, float], b: Dict[str, float]) -> float:
    keys = set(a) | set(b)
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0)) for k in keys)


class DriftMonitor:
    """Sliding-window workload observer (thread-safe)."""

    def __init__(self, cfg: Optional[DriftConfig] = None) -> None:
        self.cfg = DriftConfig() if cfg is None else cfg
        self._lock = threading.Lock()
        self._queries: deque = deque(maxlen=self.cfg.window)  # (t, template_key)
        self._heat: deque = deque(maxlen=self.cfg.heat_window)  # {part: count}
        # (t, cumulative inserts) — see observe_delta for the fold handling
        self._growth: deque = deque(maxlen=self.cfg.growth_window)
        self._growth_base = 0  # rows folded out of the buffer so far
        self._last_delta_rows = 0  # most recent raw buffer row count
        self._reservoir: List[Tuple[np.ndarray, tuple, np.ndarray]] = []
        self._seen = 0  # queries offered to the reservoir
        self._rng = random.Random(self.cfg.seed)

    # ----------------------------------------------------------------- feeding

    def observe_queries(self, keys: Iterable[Hashable], t: Optional[float] = None) -> None:
        """One entry per answered query; ``keys`` are template identities
        (filter tuples are frozen-dataclass tuples, hence hashable)."""
        now = time.monotonic() if t is None else t
        with self._lock:
            for k in keys:
                self._queries.append((now, k))

    def observe_probes(self, part_counts: Dict[int, int]) -> None:
        """Per-flush routed-query count per partition (engine ``part_probes``)."""
        if not part_counts:
            return
        with self._lock:
            self._heat.append(dict(part_counts))

    def observe_delta(self, rows: int, t: Optional[float] = None) -> None:
        """Current delta-store row count (the raw buffer size each flush sees).

        The buffer resets to zero at every refresh fold, so the raw series is
        sawtoothed — differencing it directly would report *negative* growth
        across a fold. The monitor detects the reset (``rows`` shrank) and
        maintains a monotone cumulative-inserts series instead: growth over
        the window is always ≥ 0 and ≈ the true insert rate. (Rows inserted
        AND folded between two observations are invisible to any sampler and
        are undercounted; flush-rate sampling keeps that gap negligible.)
        """
        now = time.monotonic() if t is None else t
        rows = int(rows)
        with self._lock:
            if rows < self._last_delta_rows:
                # fold detected: everything previously buffered left the
                # delta; rows present now arrived after the fold
                self._growth_base += self._last_delta_rows
            self._last_delta_rows = rows
            self._growth.append((now, self._growth_base + rows))

    def maybe_sample(self, vector: np.ndarray, filt: tuple, served_ids: np.ndarray) -> None:
        """Reservoir-sample an answered query for the live recall probe."""
        with self._lock:
            self._seen += 1
            entry = (
                np.array(vector, dtype=np.float32, copy=True),
                filt,
                np.array(served_ids, dtype=np.int64, copy=True),
            )
            if len(self._reservoir) < self.cfg.reservoir:
                self._reservoir.append(entry)
            else:
                j = self._rng.randrange(self._seen)
                if j < self.cfg.reservoir:
                    self._reservoir[j] = entry

    # ---------------------------------------------------------------- reading

    def traffic_snapshot(
        self,
    ) -> Tuple[
        List[Tuple[float, Hashable]], List[Tuple[np.ndarray, tuple, np.ndarray]]
    ]:
        """(template window, reservoir) — the RAW observations, filter tuples
        and sampled query vectors intact. ``DriftReport`` stringifies template
        keys for JSON; workload reconstruction (``core.workload.
        reconstruct_workload``, consumed by the hot-swap tuner) needs the
        actual filters back, so it reads this instead."""
        with self._lock:
            return list(self._queries), list(self._reservoir)

    def reset(self) -> None:
        """Forget every observation (window, heat, growth, reservoir).

        Called after an index swap: the retained traffic and served answers
        describe the *displaced* layout, and a share-shift computed across
        the swap boundary would immediately re-trigger the tuner on its own
        rebuild."""
        with self._lock:
            self._queries.clear()
            self._heat.clear()
            self._growth.clear()
            self._growth_base = 0
            self._last_delta_rows = 0
            self._reservoir = []
            self._seen = 0

    def live_recall(self, service: Any, k: Optional[int] = None) -> Optional[Tuple[float, int, int]]:
        """(recall@k, k, n_samples) replaying the reservoir against a
        brute-force scan of ``service``'s live DB; None when nothing sampled.

        Ground truth, not an estimate: ``exhaustive_search`` over
        ``service.snapshot_db()`` (indexed + delta rows minus tombstones).
        Positions map through ``db.ids`` back to the global ids the service
        serves. Reservoir entries sampled before deletes may legitimately
        hold now-dead ids — that recall loss is real and should be reported.
        """
        from ..core.baselines import exhaustive_search  # lazy: keep obs light
        from ..core.types import Workload

        with self._lock:
            sample = list(self._reservoir)
        if not sample:
            return None
        db = service.snapshot_db()
        if db.n == 0:
            return None
        kk = int(k if k is not None else service.cfg.k)
        queries = np.stack([v for v, _, _ in sample])
        interned: Dict[tuple, int] = {}
        template_of = np.empty(len(sample), dtype=np.int32)
        for i, (_, filt, _) in enumerate(sample):
            template_of[i] = interned.setdefault(filt, len(interned))
        templates: List[tuple] = [None] * len(interned)  # type: ignore[list-item]
        for f, ti in interned.items():
            templates[ti] = f
        wl = Workload(vectors=queries, templates=templates, template_of=template_of, k=kk)
        truth = exhaustive_search(db, wl)
        hits = 0
        denom = 0
        for i, (_, _, served) in enumerate(sample):
            pos = truth.ids[i]
            true_gids = set(int(g) for g in db.ids[pos[pos >= 0]])
            if not true_gids:
                continue
            denom += len(true_gids)
            hits += len(true_gids & set(int(g) for g in served if g >= 0))
        if denom == 0:
            return None
        return hits / denom, kk, len(sample)

    def report(
        self,
        service: Any = None,
        *,
        probe_recall: bool = False,
        k: Optional[int] = None,
    ) -> DriftReport:
        """Current ``DriftReport``; set ``probe_recall=True`` (with the
        service) to also run the brute-force recall probe — it scans the
        live DB, so leave it off on latency-sensitive paths."""
        with self._lock:
            q = list(self._queries)
            heat = list(self._heat)
            growth = list(self._growth)
            delta_rows = self._last_delta_rows
        half = len(q) // 2
        older = Counter(key for _, key in q[:half])
        recent = Counter(key for _, key in q[half:])
        ref_shares = _shares(older)
        rec_shares = _shares(recent)
        shift = _tv_distance(rec_shares, ref_shares) if older and recent else 0.0
        heat_counts: Counter = Counter()
        for pc in heat:
            heat_counts.update(pc)
        heat_total = sum(heat_counts.values())
        part_heat = (
            {int(p): c / heat_total for p, c in sorted(heat_counts.items())}
            if heat_total
            else {}
        )
        # growth entries are (t, cumulative inserts) — monotone across folds
        # (see observe_delta), so the window rate can never go negative
        growth_per_s = 0.0
        if len(growth) >= 2:
            dt = growth[-1][0] - growth[0][0]
            if dt > 0:
                growth_per_s = (growth[-1][1] - growth[0][1]) / dt
        span = (q[-1][0] - q[0][0]) if len(q) >= 2 else 0.0
        recall = None
        rk = 0
        rn = 0
        if probe_recall and service is not None:
            probed = self.live_recall(service, k=k)
            if probed is not None:
                recall, rk, rn = probed
                # publish so recall Objectives (obs.metrics) have a live
                # instrument to watch between reports
                from .metrics import get_registry

                get_registry().gauge("service.live_recall").set(recall)
        return DriftReport(
            n_window=len(q),
            window_span_s=span,
            template_shares=rec_shares,
            reference_shares=ref_shares,
            share_shift=shift,
            part_heat=part_heat,
            delta_rows=delta_rows,
            delta_growth_per_s=growth_per_s,
            recall_at_k=recall,
            recall_k=rk,
            recall_samples=rn,
        )
