"""Process-wide metrics registry: counters, gauges, bounded histograms.

Before this module each layer kept its own ad-hoc sums — flush-level
``ServiceTelemetry``, the process-wide ``DispatchStats`` counter, per-search
``ScanStats``/``ShardStats`` — with no single place an operator (or the CI
guard, or the drift tuner) could read. The registry unifies them:

  * native instruments — ``counter``/``gauge``/``histogram`` get-or-create by
    dotted name; histograms are *streaming and bounded* (fixed log-spaced
    bucket boundaries + count/sum/min/max — O(1) memory per observation, so a
    long-lived service's fsync-latency or queue-wait histogram never grows);
  * attached sources — ``attach_source(name, fn)`` folds existing surfaces
    (``ServiceTelemetry.summary``, ``DispatchStats.snapshot``) into the same
    ``snapshot()``/``to_json()`` read path without duplicating their state.

The default registry (``get_registry``) ships with the kernel dispatch
counter pre-attached under ``"dispatch"``. Standard histogram names recorded
by the instrumented layers:

    wal.fsync_s                  fsync latency per group commit (seconds)
    service.queue_wait_s         per-query submit→flush wait (seconds)
    service.flush_size           real queries per flush
    engine.bytes_scanned         arena bytes gathered per flush
    engine.peak_candidate_bytes  candidate merge buffer per flush

All instruments are thread-safe; ``snapshot()`` is a consistent point-in-time
read (each instrument snapshots under its own lock; sources are called
outside any registry lock so a slow source cannot stall recorders).
"""
from __future__ import annotations

import bisect
import dataclasses
import json
import math
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Objective",
    "get_registry",
    "set_registry",
]


class Counter:
    """Monotonically increasing count (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


class Gauge:
    """Last-written value (thread-safe)."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def snapshot(self) -> float:
        return self.value


def _default_bounds() -> List[float]:
    # quarter-decade log spacing, 1e-7 .. 1e12: covers microsecond latencies
    # through terabyte byte counts with bounded (< ~35%) quantile error
    return [10.0 ** (e / 4.0) for e in range(-28, 49)]


class Histogram:
    """Streaming histogram over fixed bucket boundaries — bounded memory.

    ``observe`` is O(log #buckets); state is one count per bucket plus
    count/sum/min/max. Quantiles interpolate within the owning bucket, so
    their error is bounded by the bucket width (a quarter decade for the
    default bounds) — the right trade for an always-on serving metric, where
    an exact percentile would need an unbounded (or windowed-and-resorted)
    value log like the one ``ServiceTelemetry`` keeps for latencies only.
    """

    def __init__(self, bounds: Optional[Sequence[float]] = None) -> None:
        b = list(bounds) if bounds is not None else _default_bounds()
        assert b == sorted(b) and len(b) >= 1, "bounds must be ascending"
        self.bounds = b
        self._lock = threading.Lock()
        self._counts = [0] * (len(b) + 1)  # bucket i: value <= bounds[i]; last = overflow
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, x: float) -> None:
        x = float(x)
        i = bisect.bisect_left(self.bounds, x)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += x
            self._min = min(self._min, x)
            self._max = max(self._max, x)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def percentile(self, q: float) -> float:
        """Approximate q-th percentile (q in [0, 100]); 0.0 when empty."""
        with self._lock:
            return self._percentile_locked(q)

    def _percentile_locked(self, q: float) -> float:
        if self._count == 0:
            return 0.0
        rank = q / 100.0 * (self._count - 1)
        seen = 0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if seen + c > rank:
                lo = self.bounds[i - 1] if i > 0 else self._min
                hi = self.bounds[i] if i < len(self.bounds) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self._max

    def _snapshot_locked(self) -> Dict[str, float]:
        if self._count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p99": 0.0}
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": self._sum / self._count,
            "min": self._min,
            "max": self._max,
            "p50": self._percentile_locked(50.0),
            "p99": self._percentile_locked(99.0),
        }

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return self._snapshot_locked()

    def to_json(self) -> Dict[str, Any]:
        """Summary fields plus the real distribution.

        ``buckets`` carries the occupied window of the bucket ladder:
        ``le[j]`` is the inclusive upper boundary of bucket ``first + j``
        (``None`` for the overflow bucket beyond the last bound) and
        ``counts[j]`` its occupancy — enough for obsdump / flight bundles to
        render the actual shape, not just interpolated p50/p99.
        """
        with self._lock:
            snap = self._snapshot_locked()
            counts = list(self._counts)
        nz = [i for i, c in enumerate(counts) if c]
        if nz:
            lo, hi = nz[0], nz[-1]
            snap["buckets"] = {
                "first": lo,
                "le": [self.bounds[i] if i < len(self.bounds) else None
                       for i in range(lo, hi + 1)],
                "counts": counts[lo:hi + 1],
            }
        else:
            snap["buckets"] = {"first": 0, "le": [], "counts": []}
        return snap


class MetricsRegistry:
    """Name → instrument map plus attached external snapshot sources."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}
        self._sources: Dict[str, Callable[[], Any]] = {}

    def _get(self, name: str, kind, *args, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(*args, **kw)
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(name, Histogram, bounds)

    def attach_source(self, name: str, fn: Callable[[], Any]) -> None:
        """Fold an external surface (e.g. ``telemetry.summary``) into
        ``snapshot()`` under ``name``; re-attaching replaces (latest wins)."""
        with self._lock:
            self._sources[name] = fn

    def detach_source(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)

    def get(self, name: str) -> Optional[Any]:
        """The instrument registered under ``name`` (None when absent) —
        read-only lookups (e.g. ``Objective.evaluate``) must not create."""
        with self._lock:
            return self._metrics.get(name)

    def snapshot(self, detail: bool = False) -> Dict[str, Any]:
        """One consistent read of every instrument and attached source.

        ``detail=True`` expands histograms via ``Histogram.to_json`` (bucket
        boundaries + counts) — the form flight bundles persist.
        """
        with self._lock:
            metrics = dict(self._metrics)
            sources = dict(self._sources)
        out: Dict[str, Any] = {
            name: (m.to_json() if detail and isinstance(m, Histogram)
                   else m.snapshot())
            for name, m in sorted(metrics.items())
        }
        for name, fn in sorted(sources.items()):
            try:
                out[name] = fn()
            except Exception as e:  # a dead source must not poison the read
                out[name] = {"error": repr(e)}
        return out

    def to_json(self, indent: Optional[int] = None, detail: bool = False) -> str:
        return json.dumps(self.snapshot(detail=detail), indent=indent,
                          default=_jsonable)


@dataclasses.dataclass(frozen=True)
class Objective:
    """Declarative SLO over one registry instrument.

    ``metric`` names a registered instrument; for histograms ``stat`` picks
    the snapshot statistic (``p50``/``p99``/``mean``/``max``/``min``), for
    counters/gauges use ``stat="value"``. ``evaluate`` returns a human-
    readable breach description when the objective is violated, else None —
    the flight recorder's slo_burn rule dumps an incident on the None→breach
    edge. ``min_count`` suppresses evaluation until a histogram has seen
    enough samples (no breach on the first slow warmup call).
    """

    name: str
    metric: str
    stat: str = "p99"
    max_value: Optional[float] = None
    min_value: Optional[float] = None
    min_count: int = 1

    def evaluate(self, registry: Optional["MetricsRegistry"] = None) -> Optional[str]:
        reg = get_registry() if registry is None else registry
        inst = reg.get(self.metric)
        if inst is None:
            return None
        if isinstance(inst, Histogram):
            s = inst.snapshot()
            if s["count"] < self.min_count:
                return None
            v = s.get(self.stat)
            if v is None:
                return None
        else:
            v = inst.value
        if self.max_value is not None and v > self.max_value:
            return (f"{self.name}: {self.metric}.{self.stat}={v:.6g} "
                    f"> max {self.max_value:.6g}")
        if self.min_value is not None and v < self.min_value:
            return (f"{self.name}: {self.metric}.{self.stat}={v:.6g} "
                    f"< min {self.min_value:.6g}")
        return None


def _jsonable(o: Any) -> Any:
    try:
        import numpy as np

        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, np.generic):
            return o.item()
    except ImportError:  # pragma: no cover
        pass
    if isinstance(o, set):
        return sorted(map(str, o))
    return str(o)


# ---------------------------------------------------------------------------
# Process-wide default registry
# ---------------------------------------------------------------------------

_REGISTRY: Optional[MetricsRegistry] = None
_REG_LOCK = threading.Lock()


def _dispatch_source() -> Dict[str, Any]:
    from ..kernels import ops as kops  # lazy: keep obs import-light

    st = kops.dispatch_stats().snapshot()
    return {
        "knn_calls": st.knn_calls,
        "merge_calls": st.merge_calls,
        "distinct_shapes": len(st.shapes),
        "peak_candidate_bytes": st.peak_candidate_bytes,
        "lut_expand_bytes": st.lut_expand_bytes,
    }


def get_registry() -> MetricsRegistry:
    """The process-wide registry (created on first use, ``dispatch``
    pre-attached so kernel-dispatch accounting shows up with no wiring)."""
    global _REGISTRY
    with _REG_LOCK:
        if _REGISTRY is None:
            _REGISTRY = MetricsRegistry()
            _REGISTRY.attach_source("dispatch", _dispatch_source)
        return _REGISTRY


def set_registry(reg: Optional[MetricsRegistry]) -> MetricsRegistry:
    """Swap the process-wide registry (tests isolate with a fresh one);
    ``None`` installs a fresh default. Returns the active registry."""
    global _REGISTRY
    with _REG_LOCK:
        _REGISTRY = reg
    return get_registry()
