"""Kernel-grained dispatch profiler with roofline attribution.

PR 7's spans show *that* a dispatch ran; this module shows *how well*. The
planner's dispatch sites already fence their outputs inside a span, so the
wall time between ``prof.t0()`` and the span close is real device time. On
top of that timing, each site reports plan-derived shape facts and the
profiler attributes the dispatch:

  bytes touched   operand + output bytes actually shipped (rows / codes /
                  streamed LUT slices from the PackedArena plus the bucket's
                  padded shape)
  distance FLOPs  2·d·Σ(nq·rows) for the f32 GEMM; 2·M·256·Σ(nq·rows) for
                  the PQ one-hot MXU contraction — both as *real* work over
                  live rows and as *padded* work over the full bucket
  occupancy       real vs padded work units and rows per bucket — the
                  padding-waste % the bucket ladder trades for few dispatches
  roofline        achieved GB/s and GFLOP/s as a fraction of the
                  launch/roofline.py hardware terms (REPRO_HW selectable)

Aggregation is per (phase, mode, bucket shape) — phases: scan / merge /
rerank / gather — plus a per-mesh-rank table for the sharded path fed from
each dispatch's ``rank_units``/``rank_bytes``.

Cost discipline mirrors ``trace.NullTracer``: the default profiler is a
``NullProfiler`` singleton — ``get_profiler().enabled`` is one attribute
load, ``t0()`` returns 0 without reading a clock, and every attribution
branch in the planner is guarded by ``prof.enabled`` — so the hot path
allocates nothing when profiling is off (tracemalloc-asserted in tests).
Enabling installs the fence hold (``trace._set_fence_hold``) so timings are
fenced even without a tracer, attaches a ``"profile"`` source to the
metrics registry, and installs the ``kernels.ops`` issue hook so coverage
(attributed vs issued dispatches) is visible.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "KernelProfiler",
    "NullProfiler",
    "get_profiler",
    "set_profiler",
    "enable_profiler",
    "disable_profiler",
]


@dataclasses.dataclass
class DispatchAgg:
    """Running totals for one (phase, mode, bucket shape) cell."""

    dispatches: int = 0
    device_s: float = 0.0
    bytes: int = 0
    flops: float = 0.0
    flops_padded: float = 0.0
    units: int = 0
    units_padded: int = 0
    rows: int = 0
    rows_padded: int = 0

    def derived(self, hw) -> Dict[str, Any]:
        t = self.device_s
        out = {
            "dispatches": self.dispatches,
            "device_s": t,
            "bytes": self.bytes,
            "flops": self.flops,
            "flops_padded": self.flops_padded,
            "units": self.units,
            "units_padded": self.units_padded,
            "rows": self.rows,
            "rows_padded": self.rows_padded,
            "gbps": (self.bytes / t / 1e9) if t > 0 else 0.0,
            "gflops": (self.flops / t / 1e9) if t > 0 else 0.0,
            "frac_hbm": (self.bytes / t / hw.hbm_bw) if t > 0 else 0.0,
            "frac_peak": (self.flops / t / hw.peak_flops) if t > 0 else 0.0,
            "unit_occupancy": (self.units / self.units_padded)
            if self.units_padded else 1.0,
            "row_occupancy": (self.rows / self.rows_padded)
            if self.rows_padded else 1.0,
            "flop_efficiency": (self.flops / self.flops_padded)
            if self.flops_padded else 1.0,
        }
        out["padding_waste"] = 1.0 - out["row_occupancy"]
        return out


class NullProfiler:
    """Disabled profiler: every call is a no-op, nothing is ever recorded."""

    enabled = False

    @staticmethod
    def t0() -> int:
        return 0

    def record_dispatch(self, *a, **kw) -> None:
        pass

    def reset(self) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"enabled": False}

    def report(self) -> Dict[str, Any]:
        return {"enabled": False}

    def totals(self, phase: Optional[str] = None, mode: Optional[str] = None) -> Dict[str, Any]:
        return {}

    def format_table(self) -> str:
        return "(profiler disabled)"


class KernelProfiler:
    """Accumulates fenced per-dispatch timings + shape-fact attribution."""

    enabled = True

    def __init__(self, hardware=None) -> None:
        if hardware is None:
            from ..launch.roofline import current_hardware

            hardware = current_hardware()
        self.hardware = hardware
        self._lock = threading.Lock()
        # (phase, mode, shape) -> DispatchAgg
        self._agg: Dict[Tuple[str, str, int], DispatchAgg] = {}
        # mesh rank -> {dispatches, units, bytes}
        self._ranks: Dict[int, Dict[str, int]] = {}
        self._issued: Dict[str, int] = {}  # ops-level hook: kind -> count
        self._attributed = 0

    # ------------------------------------------------------------- recording

    @staticmethod
    def t0() -> int:
        """Timestamp taken just before a fenced dispatch span opens."""
        return time.perf_counter_ns()

    def record_dispatch(
        self,
        phase: str,
        mode: str,
        shape: int,
        t0_ns: int,
        *,
        nbytes: int,
        flops: float,
        flops_padded: float,
        units: int,
        units_padded: int,
        rows: int,
        rows_padded: int,
        rank_units: Optional[Sequence[int]] = None,
        rank_bytes: Optional[Sequence[int]] = None,
    ) -> None:
        """Attribute one fenced dispatch (called right after its span closes,
        so perf_counter_ns() - t0_ns covers the block_until_ready)."""
        dt = (time.perf_counter_ns() - t0_ns) / 1e9 if t0_ns else 0.0
        key = (phase, mode, int(shape))
        with self._lock:
            agg = self._agg.get(key)
            if agg is None:
                agg = self._agg[key] = DispatchAgg()
            agg.dispatches += 1
            agg.device_s += dt
            agg.bytes += int(nbytes)
            agg.flops += float(flops)
            agg.flops_padded += float(flops_padded)
            agg.units += int(units)
            agg.units_padded += int(units_padded)
            agg.rows += int(rows)
            agg.rows_padded += int(rows_padded)
            self._attributed += 1
            if rank_units is not None:
                rb = rank_bytes if rank_bytes is not None else [0] * len(rank_units)
                for r, (u, b) in enumerate(zip(rank_units, rb)):
                    rr = self._ranks.get(r)
                    if rr is None:
                        rr = self._ranks[r] = {"dispatches": 0, "units": 0, "bytes": 0}
                    rr["dispatches"] += 1
                    rr["units"] += int(u)
                    rr["bytes"] += int(b)
        from .trace import get_tracer

        tracer = get_tracer()
        if tracer.enabled:
            tracer.instant(
                "profile.dispatch",
                phase=phase,
                mode=mode,
                shape=int(shape),
                device_us=round(dt * 1e6, 2),
                rows=int(rows),
                rows_padded=int(rows_padded),
            )

    def _on_issue(self, kind: str, shape) -> None:
        """kernels.ops hook: count every dispatch issued, attributed or not."""
        with self._lock:
            self._issued[kind] = self._issued.get(kind, 0) + 1

    def reset(self) -> None:
        with self._lock:
            self._agg.clear()
            self._ranks.clear()
            self._issued.clear()
            self._attributed = 0

    # --------------------------------------------------------------- reading

    @staticmethod
    def _key_str(key: Tuple[str, str, int]) -> str:
        return f"{key[0]}/{key[1]}/{key[2]}"

    def report(self) -> Dict[str, Any]:
        """Full attribution tables (the form bundles and obsdump persist)."""
        with self._lock:
            agg = {k: dataclasses.replace(v) for k, v in self._agg.items()}
            ranks = {r: dict(v) for r, v in self._ranks.items()}
            issued = dict(self._issued)
            attributed = self._attributed
        hw = self.hardware
        n_issued = sum(issued.values())
        return {
            "enabled": True,
            "hardware": hw.as_dict(),
            "phases": {
                self._key_str(k): agg[k].derived(hw) for k in sorted(agg)
            },
            "ranks": {str(r): ranks[r] for r in sorted(ranks)},
            "issued": issued,
            "attributed": attributed,
            "coverage": (attributed / n_issued) if n_issued else 1.0,
        }

    def totals(self, phase: Optional[str] = None, mode: Optional[str] = None) -> Dict[str, Any]:
        """Aggregate of all cells matching phase/mode (None = wildcard);
        ``{}`` when nothing matches (same contract as the NullProfiler)."""
        total = DispatchAgg()
        with self._lock:
            for (p, m, _s), a in self._agg.items():
                if phase is not None and p != phase:
                    continue
                if mode is not None and m != mode:
                    continue
                total.dispatches += a.dispatches
                total.device_s += a.device_s
                total.bytes += a.bytes
                total.flops += a.flops
                total.flops_padded += a.flops_padded
                total.units += a.units
                total.units_padded += a.units_padded
                total.rows += a.rows
                total.rows_padded += a.rows_padded
        if total.dispatches == 0:
            return {}
        return total.derived(self.hardware)

    def snapshot(self) -> Dict[str, Any]:
        """Compact rollup for the metrics-registry ``"profile"`` source."""
        by_phase: Dict[str, DispatchAgg] = {}
        with self._lock:
            for (p, _m, _s), a in self._agg.items():
                t = by_phase.get(p)
                if t is None:
                    t = by_phase[p] = DispatchAgg()
                t.dispatches += a.dispatches
                t.device_s += a.device_s
                t.bytes += a.bytes
                t.flops += a.flops
                t.flops_padded += a.flops_padded
                t.units += a.units
                t.units_padded += a.units_padded
                t.rows += a.rows
                t.rows_padded += a.rows_padded
            attributed = self._attributed
            n_issued = sum(self._issued.values())
        hw = self.hardware
        out: Dict[str, Any] = {
            "enabled": True,
            "hardware": hw.name,
            "attributed": attributed,
            "issued": n_issued,
        }
        for p in sorted(by_phase):
            d = by_phase[p].derived(hw)
            out[p] = {
                "dispatches": d["dispatches"],
                "device_s": round(d["device_s"], 6),
                "gbps": round(d["gbps"], 3),
                "gflops": round(d["gflops"], 3),
                "row_occupancy": round(d["row_occupancy"], 4),
            }
        return out

    def format_table(self) -> str:
        """Fixed-width text table (obsdump --profile, incident bundles)."""
        rep = self.report()
        hw = rep["hardware"]
        lines = [
            f"hardware: {hw['name']}  peak {hw['peak_flops'] / 1e12:g} TFLOP/s"
            f"  HBM {hw['hbm_bw'] / 1e9:g} GB/s",
            f"coverage: {rep['attributed']} attributed / "
            f"{sum(rep['issued'].values())} issued "
            f"({100.0 * rep['coverage']:.1f}%)",
            f"{'phase/mode/shape':<28}{'disp':>6}{'ms':>10}{'GB/s':>9}"
            f"{'GFLOP/s':>10}{'%HBM':>8}{'%peak':>8}{'occ':>7}{'waste':>7}",
        ]
        for key, d in rep["phases"].items():
            lines.append(
                f"{key:<28}{d['dispatches']:>6}{d['device_s'] * 1e3:>10.3f}"
                f"{d['gbps']:>9.2f}{d['gflops']:>10.2f}"
                f"{100 * d['frac_hbm']:>7.2f}%{100 * d['frac_peak']:>7.2f}%"
                f"{d['row_occupancy']:>7.2f}{100 * d['padding_waste']:>6.1f}%"
            )
        if rep["ranks"]:
            lines.append(f"{'rank':<8}{'disp':>8}{'units':>10}{'bytes':>14}")
            for r, v in rep["ranks"].items():
                lines.append(
                    f"{r:<8}{v['dispatches']:>8}{v['units']:>10}{v['bytes']:>14}"
                )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Process-wide profiler (default: disabled)
# ---------------------------------------------------------------------------

_NULL = NullProfiler()
_PROFILER = _NULL


def get_profiler():
    """The process-wide profiler every dispatch site reports to."""
    return _PROFILER


def set_profiler(p) -> None:
    """Install a profiler (None → the free NullProfiler) and wire the side
    channels: the trace fence hold (fenced timings without a tracer), the
    kernels.ops issue hook (dispatch coverage), and the metrics-registry
    ``"profile"`` source."""
    global _PROFILER
    _PROFILER = _NULL if p is None else p
    from . import trace as _trace
    from .metrics import get_registry

    _trace._set_fence_hold(_PROFILER.enabled)
    try:  # lazy + tolerant: profiling must not force the kernels import path
        from ..kernels import ops as kops

        kops.set_profile_hook(_PROFILER._on_issue if _PROFILER.enabled else None)
    except Exception:  # pragma: no cover
        pass
    if _PROFILER.enabled:
        get_registry().attach_source("profile", _PROFILER.snapshot)
    else:
        get_registry().detach_source("profile")


def enable_profiler(hardware=None) -> KernelProfiler:
    """Install (and return) a fresh recording profiler."""
    p = KernelProfiler(hardware=hardware)
    set_profiler(p)
    return p


def disable_profiler() -> None:
    """Back to the free no-op profiler."""
    set_profiler(None)
