"""Optimizers (raw JAX): AdamW and Adafactor, with cosine / WSD schedules.

Adafactor (factored second moment, no first moment by default) is selected by
the ≥100B configs — at 1T params a full Adam state (8 bytes/param fp32 m+v)
cannot fit the assigned mesh; the factored state is O(rows + cols) per matrix.
WSD (warmup–stable–decay) is minicpm's schedule (arXiv:2404.06395).

Optimizer states mirror the param tree structure, so the same sharding rules
(distributed/sharding.py) apply to them — ZeRO-1 falls out of FSDP rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"  # adamw | adafactor
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | wsd | constant
    wsd_decay_frac: float = 0.1  # last 10% of steps decay (WSD)
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    clip_norm: float = 1.0
    # adafactor
    factored_min_dim: int = 128
    decay_rate: float = 0.8


def make_schedule(cfg: OptConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            post = 1.0
        elif cfg.schedule == "cosine":
            t = jnp.clip(
                (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1
            )
            post = 0.5 * (1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "wsd":
            decay_start = cfg.total_steps * (1 - cfg.wsd_decay_frac)
            t = jnp.clip((step - decay_start) / max(cfg.total_steps - decay_start, 1), 0, 1)
            post = 1.0 - t  # linear decay tail after the stable phase
        else:
            raise ValueError(cfg.schedule)
        return cfg.peak_lr * warm * post

    return sched


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: OptConfig, lr):
    step = state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p.astype(jnp.float32) - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), no first moment
# ---------------------------------------------------------------------------


def _factored(p, cfg: OptConfig) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= cfg.factored_min_dim and p.shape[-2] >= cfg.factored_min_dim


def adafactor_init(params, cfg: OptConfig) -> Dict[str, Any]:
    def init(p):
        if _factored(p, cfg):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row stats
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # col stats
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"slots": jax.tree.map(init, params, is_leaf=None), "step": jnp.zeros((), jnp.int32)}


def adafactor_update(params, grads, state, cfg: OptConfig, lr):
    step = state["step"] + 1
    beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)

    def upd(p, g, slot):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if "vr" in slot:
            vr = beta2 * slot["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * slot["vc"] + (1 - beta2) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            u = g / jnp.sqrt(r[..., None] * vc[..., None, :] + 1e-30)
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta2 * slot["v"] + (1 - beta2) * g2
            u = g / jnp.sqrt(v + 1e-30)
            new_slot = {"v": v}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        new_p = p.astype(jnp.float32) - lr * u - lr * cfg.weight_decay * p.astype(jnp.float32)
        return new_p.astype(p.dtype), new_slot

    is_slot = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
    out = jax.tree.map(upd, params, grads, state["slots"], is_leaf=None)
    # out is a tree of (param, slot) tuples
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_slots = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"slots": new_slots, "step": step}


# ---------------------------------------------------------------------------
# unified facade
# ---------------------------------------------------------------------------


def init_opt(params, cfg: OptConfig):
    if cfg.name == "adamw":
        return adamw_init(params)
    if cfg.name == "adafactor":
        return adafactor_init(params, cfg)
    raise ValueError(cfg.name)


def apply_opt(params, grads, state, cfg: OptConfig, step_for_lr: Optional[jax.Array] = None):
    lr = make_schedule(cfg)(step_for_lr if step_for_lr is not None else state["step"])
    if cfg.name == "adamw":
        return adamw_update(params, grads, state, cfg, lr)
    return adafactor_update(params, grads, state, cfg, lr)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), n
