"""Checkpointing: atomic, integrity-checked, async-capable save/restore.

Layout: <dir>/step_<N>/
    manifest.json   — tree structure, shapes, dtypes, per-array sha256, step
    arrays.npz      — flattened leaves keyed by path

Fault-tolerance properties:
  * atomic publish: written to ``step_<N>.tmp`` then os.rename'd — a crash
    mid-save never corrupts the latest checkpoint;
  * integrity: every array hashed; restore verifies before handing params out;
  * async: ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread so the train loop never blocks on disk;
  * ``latest_step``/``restore`` pick up the newest *complete* checkpoint, so
    restart-after-failure is one call.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten(treedef_example, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(treedef_example)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = flat[key]
        if not hasattr(leaf, "shape"):  # python scalar leaf (e.g. step counter)
            leaves.append(type(leaf)(arr))
            continue
        assert tuple(arr.shape) == tuple(leaf.shape), f"{key}: {arr.shape} != {leaf.shape}"
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save(ckpt_dir: str, step: int, state: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "arrays": {}}
    for k, v in flat.items():
        manifest["arrays"][k] = {
            "shape": list(v.shape),
            "dtype": str(v.dtype),
            "sha256": hashlib.sha256(v.tobytes()).hexdigest(),
        }
    np.savez(os.path.join(tmp, "arrays.npz"), **{k.replace("/", "|"): v for k, v in flat.items()})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


_PENDING: Dict[str, threading.Thread] = {}


def save_async(ckpt_dir: str, step: int, state: Any) -> threading.Thread:
    """Snapshot device arrays to host now; write to disk in the background."""
    host_state = jax.tree.map(lambda x: np.asarray(x), state)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_state), daemon=True)
    t.start()
    _PENDING[ckpt_dir] = t
    return t


def wait_pending(ckpt_dir: Optional[str] = None):
    for d, t in list(_PENDING.items()):
        if ckpt_dir is None or d == ckpt_dir:
            t.join()
            _PENDING.pop(d, None)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, state_like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
    """Load the checkpoint into the structure of ``state_like`` (verifying

    shapes + hashes). Returns (state, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k.replace("|", "/"): z[k] for k in z.files}
    for k, meta in manifest["arrays"].items():
        h = hashlib.sha256(flat[k].tobytes()).hexdigest()
        if h != meta["sha256"]:
            raise IOError(f"checkpoint corruption detected in {k} at step {step}")
    return _unflatten(state_like, flat), step
