"""train_step builder: loss → grads (microbatched, remat'd) → clip → update.

Microbatching is a ``lax.scan`` over gradient accumulation steps; XLA overlaps
the reduce-scatter of microbatch i's grads with microbatch i+1's compute —
this is the main compute/communication overlap lever on the DP axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import api
from ..models.transformer import ModelConfig
from .optimizer import OptConfig, apply_opt, clip_by_global_norm, init_opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    grad_dtype: Any = jnp.float32  # accumulate grads in fp32


def make_train_step(model_cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    batch leaves have leading dim = global_batch; with microbatching they are
    reshaped to [M, B/M, ...] and scanned.
    """

    def loss_for(params, mb):
        loss, aux = api.loss_fn(params, model_cfg, mb)
        return loss, aux

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(params, opt_state, batch):
        m = tcfg.microbatches
        if m <= 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch
            )
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, tcfg.grad_dtype), params)

            def acc_step(carry, mb):
                g_acc, loss_acc = carry
                (loss, aux), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(tcfg.grad_dtype), g_acc, g)
                return (g_acc, loss_acc + loss), aux

            (grads, loss), aux = jax.lax.scan(acc_step, (zero, jnp.float32(0)), mb_batch)
            grads = jax.tree.map(lambda g: g / m, grads)
            loss = loss / m
            aux = jax.tree.map(lambda a: a[-1], aux)

        grads, gnorm = clip_by_global_norm(grads, tcfg.opt.clip_norm)
        params, opt_state = apply_opt(params, grads, opt_state, tcfg.opt)
        metrics = {"loss": loss, "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    return train_step


def init_train_state(model_cfg: ModelConfig, tcfg: TrainConfig, key):
    params = api.init_model(model_cfg, key)
    opt_state = init_opt(params, tcfg.opt)
    return params, opt_state
