"""Fault tolerance: retrying step execution, elastic re-meshing, and the

resilient train loop (checkpoint/restart + deterministic data replay).

Design (1000+-node posture):
  * Checkpoint/restart — ``TrainLoop`` saves async every N steps and resumes
    from the newest complete checkpoint; data is a pure function of step, so
    replay after restart is exact.
  * Node failure — on any step exception the loop retries; after
    ``max_retries`` it re-meshes over the still-available devices (elastic)
    and re-lowers. Sharding rules are pure functions of the mesh, so this is
    a configuration change, not a code path change.
  * Stragglers — deterministic per-(step, shard) data means a slow/absent
    host's shard can be recomputed by any other host; in the single-process
    simulation this is exercised by reassigning shards mid-run (tests).
  * Gradient compression — optional int8+error-feedback on the DP axis via
    shard_map (``dp_train_step_compressed``).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.pipeline import DataConfig, SyntheticLM
from ..distributed import compression as comp
from ..models import api
from ..models.transformer import ModelConfig
from . import checkpoint as ckpt
from .optimizer import OptConfig, apply_opt, clip_by_global_norm, init_opt
from .train_step import TrainConfig, make_train_step

log = logging.getLogger("repro.train")


def with_retries(fn: Callable, max_retries: int = 2, on_failure: Optional[Callable] = None):
    def wrapped(*a, **kw):
        err = None
        for attempt in range(max_retries + 1):
            try:
                return fn(*a, **kw)
            except Exception as e:  # noqa: BLE001 — any device/step failure
                err = e
                log.warning("step failed (attempt %d/%d): %s", attempt + 1, max_retries + 1, e)
                if on_failure is not None:
                    on_failure(attempt, e)
        raise err

    return wrapped


@dataclasses.dataclass
class LoopConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    max_retries: int = 2
    async_ckpt: bool = True


class TrainLoop:
    """Resilient single-controller loop (the multi-host launcher drives one

    of these per controller; all device placement goes through pjit)."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        tcfg: TrainConfig,
        dcfg: DataConfig,
        loop_cfg: LoopConfig = LoopConfig(),
        *,
        seed: int = 0,
    ):
        self.model_cfg, self.tcfg, self.dcfg, self.loop_cfg = model_cfg, tcfg, dcfg, loop_cfg
        self.data = SyntheticLM(dcfg)
        self.step_fn = jax.jit(make_train_step(model_cfg, tcfg))
        params = api.init_model(model_cfg, jax.random.key(seed))
        opt_state = init_opt(params, tcfg.opt)
        self.state = {"params": params, "opt": opt_state, "step": 0}
        self.metrics_history = []

    def maybe_restore(self) -> bool:
        last = ckpt.latest_step(self.loop_cfg.ckpt_dir)
        if last is None:
            return False
        self.state, step = ckpt.restore(self.loop_cfg.ckpt_dir, self.state)
        self.state["step"] = step
        log.info("restored checkpoint at step %d", step)
        return True

    def run(self, n_steps: int, fail_injector: Optional[Callable[[int], None]] = None):
        lc = self.loop_cfg
        start = int(self.state["step"])

        def one_step(step: int):
            if fail_injector is not None:
                fail_injector(step)  # tests: raise to simulate node failure
            batch = self.data.batch(step, shard=0)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            p, o, m = self.step_fn(self.state["params"], self.state["opt"], batch)
            self.state.update(params=p, opt=o, step=step + 1)
            return m

        guarded = with_retries(one_step, lc.max_retries)
        for step in range(start, start + n_steps):
            m = guarded(step)
            if step % lc.log_every == 0:
                mm = {k: float(v) for k, v in m.items()}
                self.metrics_history.append({"step": step, **mm})
                log.info("step %d: %s", step, mm)
            if lc.ckpt_every and (step + 1) % lc.ckpt_every == 0:
                if lc.async_ckpt:
                    ckpt.save_async(lc.ckpt_dir, step + 1, self.state)
                else:
                    ckpt.save(lc.ckpt_dir, step + 1, self.state)
        ckpt.wait_pending(lc.ckpt_dir)
        return self.metrics_history


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------


def elastic_mesh(preferred_shape: Tuple[int, ...], axis_names: Tuple[str, ...]):
    """Build the largest mesh of ``axis_names`` that fits the devices that are

    actually available — degraded-fleet restarts shrink the data axis first."""
    n = len(jax.devices())
    shape = list(preferred_shape)
    total = int(np.prod(shape))
    while total > n and shape[0] > 1:
        shape[0] //= 2
        total = int(np.prod(shape))
    if total > n:
        shape = [1] * (len(shape) - 1) + [n]
    return jax.make_mesh(tuple(shape), axis_names)


# ---------------------------------------------------------------------------
# compressed data-parallel train step (shard_map over "data")
# ---------------------------------------------------------------------------


def dp_train_step_compressed(model_cfg: ModelConfig, opt_cfg: OptConfig, mesh):
    """Pure data-parallel step with int8+error-feedback gradient exchange.

    Params/opt-state are replicated; the per-shard grads are quantized, the
    int8 payload is all-gathered over "data", dequantized and averaged. The
    residual rides in the optimizer state ("ef" slot).
    """
    from jax.sharding import PartitionSpec as P

    from ..distributed.sharding import shard_map_compat

    def local_step(params, opt_state, residual, batch):
        def loss(p):
            l, _ = api.loss_fn(p, model_cfg, batch)
            return l

        lval, grads = jax.value_and_grad(loss)(params)
        q, s, new_res = comp.compress_tree(grads, residual)
        grads = comp.allreduce_compressed(q, s, "data")
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = apply_opt(params, grads, opt_state, opt_cfg)
        lval = jax.lax.pmean(lval, "data")
        return params, opt_state, new_res, {"loss": lval, "grad_norm": gnorm}

    batch_spec = {"tokens": P("data"), "labels": P("data")}
    return jax.jit(
        shard_map_compat(
            local_step,
            mesh=mesh,
            in_specs=(P(), P(), P(), batch_spec),
            out_specs=(P(), P(), P(), P()),
        )
    )
