"""Index-evolution tuner: drift-triggered re-partitioning with hot swap.

See ``tuner.Tuner`` — the control loop that closes the paper's workload-
awareness story: the qd-tree/IVF layout follows the *live* workload instead
of staying frozen at build time.
"""
from .tuner import SwapRecord, Tuner, TunerConfig

__all__ = ["SwapRecord", "Tuner", "TunerConfig"]
