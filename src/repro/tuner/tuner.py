"""Background index evolution: drift-triggered rebuild + blue/green swap.

The paper's central claim is that the index layout should be *workload-
aware* — but the qd-tree/IVF partitioning is mined from a historical
workload at build time, and live traffic moves. ``obs.drift.DriftMonitor``
(PR 7) already measures exactly when the frozen layout goes stale;
``store.snapshot`` generations (PR 5) are exactly the mechanism for
introducing a new layout atomically. ``Tuner`` closes the loop:

  1. **watch** — poll ``HQIService.drift_report()`` against trigger
     thresholds: template-mix ``share_shift``, live-recall sag, delta-growth
     rate (how fast the layout is going stale under ingest);
  2. **rebuild off to the side** — reconstruct a representative ``Workload``
     from the drift window's observed traffic (``core.workload.
     reconstruct_workload``), then re-run the full build — qd-tree routing,
     IVF, ``PackedArena``, PQ carry-over, per-template ``tune_nprobe`` —
     against a captured copy of the serving state, holding **no** service
     lock while the heavy work runs. The build covers the *full* captured
     row space (dead rows included, same order), so global ids — which are
     row positions — never renumber and post-swap answers stay bit-identical;
  3. **persist** — write the candidate layout as a snapshot generation
     stamped with the WAL seq it covers, WITHOUT flipping ``CURRENT``
     (blue/green: a failed swap must leave restarts on the serving layout);
  4. **swap** — ``HQIService.swap_index`` under the flush lock: in-flight
     batches drained on the old index, acked writes past the build's seq
     replayed from the WAL into a fresh ``DeltaStore`` on the new index,
     caches invalidated, zero dropped queries. Only then is the generation
     promoted (``set_current``) and the displaced one pinned on disk for
     instant ``rollback()``.

Fault containment mirrors the rest of ``repro.fault``: the ``tuner.build``
and ``tuner.swap`` failpoints fire before any serving state is touched, so
a faulted build or swap leaves the old index serving untouched, and the
background loop backs off exponentially like the ``Compactor``'s.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.hqi import HQIIndex
from ..core.metrics import tune_nprobe
from ..core.types import SearchResult, VectorDatabase, Workload
from ..core.workload import reconstruct_workload
from ..fault.failpoints import failpoint
from ..obs.drift import DriftReport
from ..obs.metrics import get_registry
from ..obs.trace import get_tracer, set_thread_name
from ..service.service import HQIService
from ..store.snapshot import (
    build_state,
    current_generation,
    pin_generation,
    set_current,
    unpin_generation,
    write_generation,
)


@dataclasses.dataclass
class TunerConfig:
    # ---- trigger thresholds (None disables that trigger) ----
    share_shift: Optional[float] = 0.35  # TV distance, recent vs older half
    recall_floor: Optional[float] = None  # live recall@k below this trips
    delta_growth_per_s: Optional[float] = None  # ingest rate above this trips
    min_window: int = 64  # drift observations required before any trigger
    min_interval_s: float = 0.0  # cooldown between swaps (rebuilds are heavy)
    # ---- rebuild ----
    workload_queries: int = 256  # reconstructed-workload size
    retune_nprobe: bool = True  # re-run tune_nprobe on the new layout
    target_recall: float = 0.8  # the paper's Recall >= 0.8 @ k protocol
    max_nprobe: int = 256
    sample_per_template: int = 64
    seed: int = 0
    # ---- lifecycle ----
    interval_s: float = 30.0  # background poll period
    max_backoff_s: float = 300.0  # cap on the failure backoff
    keep_rollback: bool = True  # pin the displaced generation on disk


@dataclasses.dataclass
class SwapRecord:
    """One completed rebuild + blue/green swap (``Tuner.swaps`` keeps them)."""

    reason: str  # which trigger fired ("share-shift" | "recall-sag" | ...)
    generation: Optional[str]  # persisted candidate generation (None: no root)
    covered_seq: int  # highest WAL seq the rebuild includes
    n_rows: int  # row count of the rebuilt index (dead rows included)
    replayed: int  # WAL records replayed into the fresh delta at swap
    nprobe_by_filter: Optional[Dict[tuple, int]]  # tuned overrides installed
    build_s: float  # off-to-the-side rebuild wall time
    swap_s: float  # under-flush-lock swap wall time


@dataclasses.dataclass
class _Build:
    """A candidate layout waiting to be swapped in (internal)."""

    index: HQIIndex
    live: np.ndarray  # tombstone mask over ALL rebuilt rows
    covered_seq: int  # _applied_seq at capture
    nprobe_by_filter: Optional[Dict[tuple, int]]
    generation: Optional[str]
    reason: str
    build_s: float


class Tuner:
    """Drift-triggered index evolution for one ``HQIService``.

    Drive it synchronously (``tune_once``) or as a daemon thread
    (``start``/``stop``) — same lifecycle contract as ``store.compact.
    Compactor``, including failure accounting (``consecutive_failures`` /
    ``last_error`` feed ``HQIService.health()``) and exponential backoff.
    ``root`` is the snapshot store root for generation persistence; None
    runs purely in memory (no durability, still zero-downtime swaps).
    """

    def __init__(
        self,
        service: HQIService,
        root: Optional[str] = None,
        *,
        cfg: Optional[TunerConfig] = None,
    ) -> None:
        self.service = service
        self.root = root
        self.cfg = TunerConfig() if cfg is None else cfg
        self.swaps: List[SwapRecord] = []
        self.consecutive_failures = 0
        self.last_error: Optional[BaseException] = None
        self.last_reason: Optional[str] = None
        self._last_swap_t: Optional[float] = None
        # (old_index, old_live, old_covered_seq, old_gen, new_gen) — what
        # rollback() swaps back in; kept until the next successful swap
        self._rollback: Optional[tuple] = None
        self._thread: Optional[threading.Thread] = None
        self._stop_flag = threading.Event()
        get_registry().attach_source("tuner", self._metrics)
        service._tuner = self  # health() back-ref, like the compactor's

    def _metrics(self) -> dict:
        return {
            "swaps": len(self.swaps),
            "consecutive_failures": self.consecutive_failures,
            "last_error": None if self.last_error is None else repr(self.last_error),
            "last_reason": self.last_reason,
            "rollback_armed": self._rollback is not None,
            "backoff_s": self._backoff_s(),
        }

    def _backoff_s(self) -> float:
        if self.consecutive_failures == 0:
            return self.cfg.interval_s
        return min(
            self.cfg.max_backoff_s,
            self.cfg.interval_s * (2.0 ** self.consecutive_failures),
        )

    # ---------------------------------------------------------------- trigger

    def should_rebuild(self, report: DriftReport) -> Optional[str]:
        """The trigger reason a report trips, or None (also None inside the
        ``min_interval_s`` cooldown — rebuilds are heavy, and the drift
        window right after a swap describes almost no traffic anyway)."""
        cfg = self.cfg
        if report.n_window < cfg.min_window:
            return None
        if (
            self._last_swap_t is not None
            and time.monotonic() - self._last_swap_t < cfg.min_interval_s
        ):
            return None
        if cfg.share_shift is not None and report.share_shift >= cfg.share_shift:
            return "share-shift"
        if (
            cfg.recall_floor is not None
            and report.recall_at_k is not None
            and report.recall_at_k < cfg.recall_floor
        ):
            return "recall-sag"
        if (
            cfg.delta_growth_per_s is not None
            and report.delta_growth_per_s >= cfg.delta_growth_per_s
        ):
            return "delta-growth"
        return None

    # ------------------------------------------------------------------- once

    def tune_once(self, force: bool = False) -> Optional[SwapRecord]:
        """One watch → rebuild → swap cycle; returns the record, or None when
        no trigger fired. ``force=True`` skips the trigger check (operator
        'rebuild now'). Failure accounting lives here so synchronously driven
        tuners report the same health as the background loop."""
        try:
            rec = self._tune_once(force)
        except Exception as e:
            self.consecutive_failures += 1
            self.last_error = e
            raise
        else:
            self.consecutive_failures = 0
            self.last_error = None
            return rec

    def _tune_once(self, force: bool) -> Optional[SwapRecord]:
        report = self.service.drift_report(
            probe_recall=self.cfg.recall_floor is not None
        )
        reason = "forced" if force else self.should_rebuild(report)
        if reason is None:
            return None
        built = self._build(reason)
        return self._swap(built)

    # ------------------------------------------------------------------ build

    def _build(self, reason: str) -> _Build:
        """Rebuild the layout off to the side; no service lock held while the
        heavy work (k-means, arena packing, nprobe tuning, blob I/O) runs.

        Id-space preservation is the load-bearing invariant: the new index is
        built over the FULL captured DB — base rows plus delta rows, dead
        rows *included*, same order — so global ids (row positions) never
        renumber and the swap can replay the WAL tail on top with bit-exact
        id continuity. Dead rows stay invisible exactly as they already were:
        through the live mask at search time.
        """
        svc = self.service
        t0 = time.perf_counter()
        with get_tracer().span("tuner.build", reason=reason):
            failpoint("tuner.build")
            with svc._lock:
                # refs only — index mutations are array replacements, so the
                # captured objects stay immutable after the lock drops
                base_db = svc.index.db
                base_live = svc._live.copy()
                delta_db, delta_live = svc.delta.snapshot()
                covered_seq = svc._applied_seq
                index_cfg = svc.index.cfg
                old_pq = svc.index.pq
            prev_pin = None
            if svc.wal is not None:
                # shield the tail the swap must replay from a concurrent
                # compactor's WAL pruning for the whole build
                prev_pin = svc.wal.pin_seq
                svc.wal.pin_seq = (
                    covered_seq if prev_pin is None else min(prev_pin, covered_seq)
                )
            try:
                full_db = (
                    base_db
                    if delta_db is None
                    else VectorDatabase.concat(base_db, delta_db)
                )
                full_live = np.concatenate([base_live, delta_live])
                wl = self._reconstruct(full_db, full_live)
                new_index = HQIIndex.build(full_db, wl, index_cfg)
                if old_pq is not None and new_index.pq is None:
                    # the codebook is trained on vector space, not layout —
                    # carry it over so degraded-mode serving survives the swap
                    new_index.attach_pq(old_pq)
                by_filter = None
                if self.cfg.retune_nprobe:
                    by_filter = self._retune(new_index, full_db, full_live, wl)
                gen = None
                if self.root is not None:
                    gen = write_generation(
                        self.root,
                        build_state(new_index, live=full_live),
                        wal_seq=covered_seq,
                        meta={"source": "tuner", "reason": reason},
                        set_current=False,  # promote only after the swap lands
                    )
            except BaseException:
                if svc.wal is not None:
                    svc.wal.pin_seq = prev_pin
                raise
        return _Build(
            index=new_index,
            live=full_live,
            covered_seq=covered_seq,
            nprobe_by_filter=by_filter,
            generation=gen,
            reason=reason,
            build_s=time.perf_counter() - t0,
        )

    def _reconstruct(self, full_db: VectorDatabase, full_live: np.ndarray) -> Workload:
        """Representative workload from observed traffic (recent half of the
        drift window + the recall reservoir's real query vectors); falls back
        to an unfiltered self-similarity sample when nothing was observed
        (forced rebuild on an idle service)."""
        traffic, samples = self.service.drift.traffic_snapshot()
        recent = traffic[len(traffic) // 2 :]
        live_idx = np.nonzero(full_live)[0]
        fallback = full_db.vectors[live_idx] if len(live_idx) else full_db.vectors
        wl = reconstruct_workload(
            recent,
            samples,
            fallback_vectors=fallback,
            n_queries=self.cfg.workload_queries,
            k=self.service.cfg.k,
            seed=self.cfg.seed,
        )
        if wl is not None:
            return wl
        rng = np.random.default_rng(self.cfg.seed)
        m = min(self.cfg.workload_queries, max(1, len(fallback)))
        return Workload(
            vectors=fallback[rng.integers(0, len(fallback), size=m)],
            templates=[()],
            template_of=np.zeros(m, dtype=np.int32),
            k=self.service.cfg.k,
        )

    def _retune(
        self,
        new_index: HQIIndex,
        full_db: VectorDatabase,
        full_live: np.ndarray,
        wl: Workload,
    ) -> Dict[tuple, int]:
        """Per-template nprobe on the NEW layout (the paper's Recall >= 0.8
        protocol), returned keyed by filter tuple — template indices are
        flush-local in the service, filters are not."""
        from ..core.baselines import exhaustive_search  # lazy: engine dep

        live_idx = np.nonzero(full_live)[0]
        truth = exhaustive_search(full_db.take(live_idx), wl)
        # exhaustive ids are positions into the live-only view; the index
        # serves global ids — map through live_idx before comparing
        gids = np.where(truth.ids >= 0, live_idx[truth.ids], -1)
        truth = SearchResult(ids=gids, scores=truth.scores)

        def search_fn(sub: Workload, npr: Dict[int, int]) -> SearchResult:
            return new_index.search(sub, nprobe=npr, live_mask=full_live)

        per_template = tune_nprobe(
            search_fn,
            wl,
            truth,
            target_recall=self.cfg.target_recall,
            max_nprobe=self.cfg.max_nprobe,
            sample_per_template=self.cfg.sample_per_template,
            seed=self.cfg.seed,
        )
        return {
            filt: per_template[ti] for ti, filt in enumerate(wl.templates)
        }

    # ------------------------------------------------------------------- swap

    def _swap(self, built: _Build) -> SwapRecord:
        svc = self.service
        t0 = time.perf_counter()
        prev_gen = None if self.root is None else current_generation(self.root)
        with get_tracer().span("tuner.swap", reason=built.reason):
            old_index, old_live, old_seq, replayed = svc.swap_index(
                built.index, built.live, built.covered_seq
            )
        swap_s = time.perf_counter() - t0
        # ---- the swap landed: promote the candidate generation and arm the
        # rollback. A crash between here and the pin still restarts correctly
        # (CURRENT now names the layout that is serving).
        if self.root is not None and built.generation is not None:
            set_current(self.root, built.generation)
            if self._rollback is not None and self._rollback[3] is not None:
                unpin_generation(self.root, self._rollback[3])
            if self.cfg.keep_rollback and prev_gen is not None:
                pin_generation(self.root, prev_gen)
        if svc.wal is not None:
            # rollback replays records past the OLD folded seq; keep them
            svc.wal.pin_seq = old_seq if self.cfg.keep_rollback else None
        if built.nprobe_by_filter is not None:
            svc.set_nprobe_by_filter(built.nprobe_by_filter)
        self._rollback = (
            old_index,
            old_live,
            old_seq,
            prev_gen if self.cfg.keep_rollback else None,
            built.generation,
        )
        self._last_swap_t = time.monotonic()
        self.last_reason = built.reason
        rec = SwapRecord(
            reason=built.reason,
            generation=built.generation,
            covered_seq=built.covered_seq,
            n_rows=built.index.db.n,
            replayed=replayed,
            nprobe_by_filter=built.nprobe_by_filter,
            build_s=built.build_s,
            swap_s=swap_s,
        )
        self.swaps.append(rec)
        return rec

    @property
    def can_rollback(self) -> bool:
        """True while a displaced layout is held for instant ``rollback()``."""
        return self._rollback is not None

    def rollback(self) -> None:
        """Instantly swap the displaced layout back in (same blue/green
        mechanism, in reverse). Writes acknowledged after the forward swap
        are preserved: the WAL tail past the old layout's covered seq —
        pinned on disk since the swap — replays into its fresh delta, and
        the displaced index may even have grown via folds during the build;
        the replay handles both generically because the id space is shared.
        """
        if self._rollback is None:
            raise RuntimeError("no swap to roll back")
        old_index, old_live, old_seq, old_gen, new_gen = self._rollback
        svc = self.service
        svc.swap_index(old_index, old_live, old_seq)
        if self.root is not None and old_gen is not None:
            set_current(self.root, old_gen)
            unpin_generation(self.root, old_gen)
        if svc.wal is not None:
            svc.wal.pin_seq = None
        svc.set_nprobe_by_filter(None)
        self._rollback = None
        self.last_reason = "rollback"

    def forget_rollback(self) -> None:
        """Release the rollback pin (disk + WAL) once the new layout has
        proven itself; the next generation prune collects the old one."""
        if self._rollback is None:
            return
        old_gen = self._rollback[3]
        if self.root is not None and old_gen is not None:
            unpin_generation(self.root, old_gen)
        if self.service.wal is not None:
            self.service.wal.pin_seq = None
        self._rollback = None

    # ------------------------------------------------------------ background

    def start(self) -> None:
        """Poll ``tune_once`` on a daemon thread every ``interval_s``."""
        assert self._thread is None, "tuner already running"
        self._stop_flag.clear()

        def loop() -> None:
            set_thread_name("tuner")  # root spans tagged for trace triage
            while not self._stop_flag.wait(self._backoff_s()):
                try:
                    self.tune_once()
                except Exception:
                    # the service must outlive its tuner: tune_once already
                    # recorded last_error / consecutive_failures, and the
                    # next wait backs off exponentially. Crucially a failed
                    # build or swap left the old index serving untouched.
                    pass

        self._thread = threading.Thread(target=loop, name="hqi-tuner", daemon=True)
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop_flag.set()
            self._thread.join()
            self._thread = None
