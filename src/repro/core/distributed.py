"""Distributed batch hybrid search on the production mesh (shard_map).

Mapping of HQI onto the (pod, data, model) mesh:

  * the packed vector index (qd-tree partitions → contiguous posting lists)
    is sharded over the **model** axis — each model-rank owns a slice of the
    database rows and its bitmap slice;
  * the query stream is sharded over **data** (and **pod**) — batch
    parallelism, queries never need to see each other;
  * each device computes the masked top-k of its queries against its DB
    shard (one fused kernel call — Alg. 3's matmul), then an
    **all-gather over "model"** collects the per-shard top-k candidates
    (k·|model| per query, NOT the full distance rows) and a static merge
    selects the global top-k.

Communication per query is O(k · model_axis) — independent of DB size; the
index is read-only so pods replicate it and split the stream (linear scaling
across pods). This step is a first-class dry-run/roofline row ("hqi-search").
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..kernels import ref as kref

from ..distributed.sharding import shard_map_compat


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def chunked_masked_topk(queries, db, bitmap, k: int, metric: str, tile: int = 16_384):
    """Running top-k over DB tiles — the jnp mirror of the fused Pallas

    kernel's schedule: the M×N score matrix is never materialized (peak
    O(M × tile)), HBM traffic is one DB read + O(M·k) instead of O(M·N)
    score spills. §Perf iteration for the hqi-search cells."""
    n = db.shape[0]
    if n <= tile:
        return kref.masked_topk_ref(queries, db, bitmap, k, metric)
    nt = (n + tile - 1) // tile
    npad = nt * tile
    dbp = jnp.pad(db, ((0, npad - n), (0, 0)))
    bmp = jnp.pad(bitmap, (0, npad - n))
    m = queries.shape[0]

    def step(carry, inp):
        rs, ri = carry
        dtile, btile, off = inp
        s, i = kref.masked_topk_ref(queries, dtile, btile, k, metric)
        gi = jnp.where(i >= 0, i + off, -1)
        cat_s = jnp.concatenate([rs, s], axis=1)
        cat_i = jnp.concatenate([ri, gi], axis=1)
        top, pos = jax.lax.top_k(cat_s, k)
        return (top, jnp.take_along_axis(cat_i, pos, axis=1)), None

    init = (
        jnp.full((m, k), kref.NEG_INF, jnp.float32),
        jnp.full((m, k), -1, jnp.int32),
    )
    tiles = dbp.reshape(nt, tile, -1)
    bts = bmp.reshape(nt, tile)
    offs = jnp.arange(nt, dtype=jnp.int32) * tile
    (rs, ri), _ = jax.lax.scan(step, init, (tiles, bts, offs))
    ri = jnp.where(jnp.isfinite(rs) & (rs > kref.NEG_INF / 2), ri, -1)
    return rs, ri


def make_search_step(mesh: Mesh, *, k: int, metric: str = "ip", db_tile: int = 16_384):
    """Returns jit'd search_step(db, norms, bitmap, queries) -> (scores, ids).

    db      f32 [N, d]    sharded P("model", None)   — packed index shard
    bitmap  bool [N]      sharded P("model")         — pushdown bitmap
    queries f32 [M, d]    sharded P(batch_axes, None)
    out     [M, k] scores / global ids.
    """
    baxes = _batch_axes(mesh)

    def local(db, bitmap, queries):
        # per-device shapes: db [N/mp, d], bitmap [N/mp], queries [M/dp, d]
        n_local = db.shape[0]
        shard_idx = jax.lax.axis_index("model")
        scores, idx = chunked_masked_topk(queries, db, bitmap, k, metric, tile=db_tile)
        gids = jnp.where(idx >= 0, idx + shard_idx * n_local, -1)
        # collect candidates from every model shard: [mp, M/dp, k]
        all_s = jax.lax.all_gather(scores, "model")
        all_i = jax.lax.all_gather(gids, "model")
        mshards = all_s.shape[0]
        cat_s = jnp.moveaxis(all_s, 0, 1).reshape(queries.shape[0], mshards * k)
        cat_i = jnp.moveaxis(all_i, 0, 1).reshape(queries.shape[0], mshards * k)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return top_s, top_i

    fn = shard_map_compat(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P("model"), P(baxes, None)),
        out_specs=(P(baxes, None), P(baxes, None)),
    )
    return jax.jit(fn)


def search_step_specs(mesh: Mesh, *, n: int, d: int, m: int):
    """ShapeDtypeStructs with shardings for the dry-run."""
    baxes = _batch_axes(mesh)
    sh = lambda spec: NamedSharding(mesh, spec)
    return (
        jax.ShapeDtypeStruct((n, d), jnp.float32, sharding=sh(P("model", None))),
        jax.ShapeDtypeStruct((n,), jnp.bool_, sharding=sh(P("model"))),
        jax.ShapeDtypeStruct((m, d), jnp.float32, sharding=sh(P(baxes, None))),
    )
