"""Distributed batch hybrid search: the plan/execute engine on a device mesh.

Mapping of HQI onto the (pod, data, model) mesh:

  * the ``PackedArena`` is sharded over the **model** axis as contiguous
    partition slices (``PackedArena.shard``): each rank owns a slice of the
    f32 rows, uint8 PQ codes, posting-list table, and bitmap slices;
  * the plan is replicated: ``build_plan_sharded`` routes every engine task
    to its partition's owner rank, so each rank executes exactly its shard's
    work units — bucket dispatches (``workunit_topk`` / ``workunit_pq_topk``)
    run inside ``shard_map`` with every rank's units stacked along the model
    axis, and bitmap pushdown / PQ compose unchanged;
  * the only cross-rank traffic is the per-query top-k candidate all-gather
    of ``ops.sharded_merge_topk`` — O(k · |model|) (score, id) pairs per
    query, independent of DB size, never distance rows;
  * the query stream splits over **data** (and **pod**) host-side — batch
    parallelism, queries never need to see each other, so the serving layer
    (or the pods themselves) partition the stream and every model group
    answers its slice independently.

``execute_sharded`` is the whole entry point: ``HQIIndex.search`` routes
through it when ``HQIConfig.mesh`` is set, and ``batch_search_ivf(mesh=...)``
uses it for standalone indexes. Results are bit-identical to the
single-device engine — tests/test_engine_sharded.py proves it across mesh
sizes on CPU host devices (``--xla_force_host_platform_device_count``).

``make_roofline_search_step`` survives for the dry-run: it models the
sharded engine's device program (per-rank tiled scans + the k·|model|
candidate gather) at 100M-vector scale where the host-side planner is
abstracted to a resident full scan — a roofline row, not a search API.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed.sharding import shard_map_compat
from ..kernels import ref as kref
from .arena import ShardedArena
from .ivf import ScanStats
from .plan import EngineTask, PlanConfig, build_plan_sharded
from .planner import ExtraCandidates, ShardStats, execute_plan_sharded


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Which mesh axis the engine shards the arena over.

    Only ``model_axis`` is read: every other mesh axis (data, pod)
    replicates — the query stream splits over those host-side at the
    serving layer, where each group runs its slice of the workload through
    this engine independently.
    """

    model_axis: str = "model"

    def n_shards(self, mesh: Mesh) -> int:
        return int(mesh.shape[self.model_axis])


def execute_sharded(
    sharded: ShardedArena,
    tasks: List[EngineTask],
    q_vecs: np.ndarray,  # f32 [m, d]
    *,
    mesh: Mesh,
    spec: Optional[ShardSpec] = None,
    m: int,
    k: int,
    cfg: Optional[PlanConfig] = None,
    extra: Sequence[ExtraCandidates] = (),
    stats: Optional[ScanStats] = None,
) -> Tuple[np.ndarray, np.ndarray, ShardStats]:
    """Plan + execute one workload's vector work across the mesh.

    The thin mesh entry: replicate the plan (``build_plan_sharded`` routes
    tasks to arena-shard owners), execute with per-rank bucket dispatches and
    the all-gather top-k merge. Returns (scores f32 [m, k], ids i64 [m, k],
    per-rank ``ShardStats``) — scores/ids bit-identical to the single-device
    ``build_plan``/``execute_plan`` pair.
    """
    spec = ShardSpec() if spec is None else spec
    cfg = PlanConfig() if cfg is None else cfg
    assert sharded.n_shards == spec.n_shards(mesh), (
        f"arena sharded {sharded.n_shards} ways but mesh axis "
        f"{spec.model_axis!r} has {spec.n_shards(mesh)} ranks"
    )
    splan = build_plan_sharded(
        sharded, tasks, q_vecs, m=m, k=k, cfg=cfg, stats=stats
    )
    shard_stats = ShardStats.zeros(sharded.n_shards)
    scores, ids = execute_plan_sharded(
        splan, sharded, q_vecs,
        mesh=mesh, axis=spec.model_axis, cfg=cfg,
        extra=extra, stats=stats, shard_stats=shard_stats,
    )
    return scores, ids, shard_stats


# ----------------------------------------------------------- dry-run roofline


def make_roofline_search_step(mesh: Mesh, *, k: int, metric: str = "ip", db_tile: int = 16_384):
    """jit'd (db, bitmap, queries) -> (scores, ids): the dry-run's model of
    the sharded engine's device program at production scale.

    Each model rank scans its resident row shard as fixed-shape tiles with a
    running masked top-k (the work-unit schedule with host planning
    abstracted to a dense scan: the M×N score matrix never materializes,
    HBM traffic is one shard read + O(M·k)), then the k·|model| candidate
    all-gather and a static merge select the global top-k — the same
    communication structure ``ops.sharded_merge_topk`` gives the real
    engine. This is a roofline/HLO-cost row ("hqi-search"), not a search
    API: real searches go through ``execute_sharded``.
    """
    baxes = _batch_axes(mesh)

    def tiled_scan(queries, db, bitmap):
        n = db.shape[0]
        if n <= db_tile:
            return kref.masked_topk_ref(queries, db, bitmap, k, metric)
        nt = (n + db_tile - 1) // db_tile
        npad = nt * db_tile
        dbp = jnp.pad(db, ((0, npad - n), (0, 0)))
        bmp = jnp.pad(bitmap, (0, npad - n))
        mq = queries.shape[0]

        def step(carry, inp):
            rs, ri = carry
            dtile, btile, off = inp
            s, i = kref.masked_topk_ref(queries, dtile, btile, k, metric)
            gi = jnp.where(i >= 0, i + off, -1)
            cat_s = jnp.concatenate([rs, s], axis=1)
            cat_i = jnp.concatenate([ri, gi], axis=1)
            top, pos = jax.lax.top_k(cat_s, k)
            return (top, jnp.take_along_axis(cat_i, pos, axis=1)), None

        init = (
            jnp.full((mq, k), kref.NEG_INF, jnp.float32),
            jnp.full((mq, k), -1, jnp.int32),
        )
        tiles = dbp.reshape(nt, db_tile, -1)
        bts = bmp.reshape(nt, db_tile)
        offs = jnp.arange(nt, dtype=jnp.int32) * db_tile
        (rs, ri), _ = jax.lax.scan(step, init, (tiles, bts, offs))
        ri = jnp.where(jnp.isfinite(rs) & (rs > kref.NEG_INF / 2), ri, -1)
        return rs, ri

    def local(db, bitmap, queries):
        # per-device shapes: db [N/mp, d], bitmap [N/mp], queries [M/dp, d]
        n_local = db.shape[0]
        shard_idx = jax.lax.axis_index("model")
        scores, idx = tiled_scan(queries, db, bitmap)
        gids = jnp.where(idx >= 0, idx + shard_idx * n_local, -1)
        # THE cross-rank step: k·|model| candidates per query, never rows
        all_s = jax.lax.all_gather(scores, "model")
        all_i = jax.lax.all_gather(gids, "model")
        mshards = all_s.shape[0]
        cat_s = jnp.moveaxis(all_s, 0, 1).reshape(queries.shape[0], mshards * k)
        cat_i = jnp.moveaxis(all_i, 0, 1).reshape(queries.shape[0], mshards * k)
        top_s, pos = jax.lax.top_k(cat_s, k)
        top_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return top_s, top_i

    fn = shard_map_compat(
        local,
        mesh=mesh,
        in_specs=(P("model", None), P("model"), P(baxes, None)),
        out_specs=(P(baxes, None), P(baxes, None)),
    )
    return jax.jit(fn)


def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def roofline_search_specs(mesh: Mesh, *, n: int, d: int, m: int):
    """ShapeDtypeStructs with shardings for the dry-run."""
    baxes = _batch_axes(mesh)
    sh = lambda spec: NamedSharding(mesh, spec)
    return (
        jax.ShapeDtypeStruct((n, d), jnp.float32, sharding=sh(P("model", None))),
        jax.ShapeDtypeStruct((n,), jnp.bool_, sharding=sh(P("model"))),
        jax.ShapeDtypeStruct((m, d), jnp.float32, sharding=sh(P(baxes, None))),
    )
