"""Index-wide packed vector arena — the storage side of the execution engine.

Every partition's IVF stores its vectors re-ordered so each posting list is a
contiguous slice (see ivf.py). The arena concatenates those per-partition
``packed`` arrays into ONE index-wide array and exposes a *global* posting-list
table: posting list ``g`` of any partition lives at
``packed[list_start[g] : list_start[g] + list_len[g]]``.

This is what lets the planner bucket work units across partitions and
templates: a single ``packed[rows]`` gather (and a single device transfer)
serves every partition, so one kernel dispatch can mix posting lists from
anywhere in the index. ``gid`` maps packed rows straight back to the caller's
tuple ids (global database rows for HQI, local vector indices for a standalone
IVF), so executor output needs no per-partition id translation.

Compressed storage: when a ``PQCodebook`` is attached, the arena also carries
``codes`` — uint8 [N, M] PQ codes row-aligned with ``packed`` — so the
engine's ADC scan stage gathers M-byte code rows instead of d·4-byte vectors
and the exact re-rank stage gathers the (few) surviving f32 rows from the
same arena. Codes are encoded once per partition block and maintained
incrementally through ``updated()``.

Sharded storage: ``shard()`` splits the arena into contiguous *partition*
slices, one per model-axis rank of a device mesh. Because partitions are
contiguous blocks of the packed array, every per-rank structure — f32 rows,
uint8 PQ codes, posting-list table, id map — is a zero-copy view of the base
arena, re-based to rank-local coordinates. ``gid`` stays *global* in every
shard, so the sharded executor's outputs need no cross-rank id translation,
and ``packed_bitmap`` keeps working per shard because bitmap slices are
partition-local already.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import kmeans as km
from .ivf import IVFIndex
from .pq import PQCodebook, encode_pq


def _nearest_cuts(boundary_rows: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """Index of the boundary NEAREST each row target (not the next one up —
    snapping up degenerates badly under skew, e.g. partitions of 10 and 900
    rows split 2 ways must cut at 10, not at the end)."""
    hi = np.clip(
        np.searchsorted(boundary_rows, targets, side="left"),
        1, len(boundary_rows) - 1,
    )
    lo = hi - 1
    pick_lo = (targets - boundary_rows[lo]) <= (boundary_rows[hi] - targets)
    return np.where(pick_lo, lo, hi).astype(np.int64)


@dataclasses.dataclass
class PackedArena:
    """Concatenated posting-list storage for one or more IVF partitions."""

    packed: np.ndarray  # f32 [N, d] — all partitions, posting-list order
    gid: np.ndarray  # i64 [N] — packed row -> caller tuple id
    local_of: np.ndarray  # i64 [N] — packed row -> partition-local vector idx
    list_start: np.ndarray  # i64 [G] — first packed row of global list g
    list_len: np.ndarray  # i64 [G]
    list_base: np.ndarray  # i64 [P + 1] — partition p owns lists [base[p], base[p+1])
    part_row: np.ndarray  # i64 [P + 1] — partition p owns packed rows [row[p], row[p+1])
    centroids: List[np.ndarray]  # per-partition coarse quantizer
    metric: str
    pq: Optional[PQCodebook] = None  # index-wide codebook (compressed mode)
    codes: Optional[np.ndarray] = None  # uint8 [N, M], row-aligned with packed

    @property
    def n(self) -> int:
        return int(self.packed.shape[0])

    @property
    def d(self) -> int:
        return int(self.packed.shape[1])

    @property
    def n_parts(self) -> int:
        return len(self.centroids)

    @property
    def n_lists(self) -> int:
        return int(self.list_start.shape[0])

    def n_lists_of(self, part: int) -> int:
        return int(self.list_base[part + 1] - self.list_base[part])

    def probe(self, part: int, q_vecs: np.ndarray, nprobe: int) -> np.ndarray:
        """nprobe nearest posting lists of partition ``part`` as GLOBAL list ids.

        int32 [m, min(nprobe, n_lists_of(part))]. Identical ranking to
        ``IVFIndex.probe`` (same quantizer, same top-m kernel) so engine
        results match the per-query scan path exactly.
        """
        nprobe = int(min(nprobe, self.n_lists_of(part)))
        local = km.topm_centroids(q_vecs, self.centroids[part], nprobe, metric=self.metric)
        return local + np.int32(self.list_base[part])

    def packed_bitmap(self, part: int, local_bitmap: np.ndarray) -> np.ndarray:
        """Partition-local vector-order bitmap -> that partition's packed order."""
        s, e = int(self.part_row[part]), int(self.part_row[part + 1])
        return local_bitmap[self.local_of[s:e]]

    def attach_pq(self, pq: PQCodebook) -> None:
        """Encode the packed rows under ``pq`` (idempotent per codebook).

        Used by the single-index path (``batch_search_ivf``) where the arena
        is built before a codebook exists; ``HQIIndex`` instead passes ``pq``
        at construction so codes ride every (incremental) rebuild.
        """
        if self.pq is pq and self.codes is not None:
            return
        if pq.d != self.d:
            raise ValueError(
                f"PQ codebook shape mismatch: codebook encodes d={pq.d} "
                f"(m={pq.m} subspaces × dsub={pq.dsub}), arena rows have "
                f"d={self.d}"
            )
        self.pq = pq
        self.codes = encode_pq(pq, self.packed)

    # ------------------------------------------------------------ persistence

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): arrays stay np.ndarray leaves.

        The arena is derivable from the partitions, but persisting it makes a
        loaded index *warm* — the first engine-backed search after a load
        skips the O(N·d) concatenation (and the O(N·M) re-encode in pq mode)
        and serves straight off the mmap'd blobs.
        """
        state = {
            "metric": self.metric,
            "packed": self.packed,
            "gid": self.gid,
            "local_of": self.local_of,
            "list_start": self.list_start,
            "list_len": self.list_len,
            "list_base": self.list_base,
            "part_row": self.part_row,
            "centroids": {str(p): c for p, c in enumerate(self.centroids)},
            "pq": None if self.pq is None else self.pq.to_state(),
            "codes": self.codes,
        }
        return state

    @staticmethod
    def from_state(state: dict) -> "PackedArena":
        cents = state["centroids"]
        return PackedArena(
            packed=np.asarray(state["packed"]),
            gid=np.asarray(state["gid"]),
            local_of=np.asarray(state["local_of"]),
            list_start=np.asarray(state["list_start"]),
            list_len=np.asarray(state["list_len"]),
            list_base=np.asarray(state["list_base"]),
            part_row=np.asarray(state["part_row"]),
            centroids=[np.asarray(cents[str(p)]) for p in range(len(cents))],
            metric=state["metric"],
            pq=None if state["pq"] is None else PQCodebook.from_state(state["pq"]),
            codes=None if state["codes"] is None else np.asarray(state["codes"]),
        )

    # ------------------------------------------------------------------ shard

    def shard(
        self, n_shards: int, bounds: Optional[Sequence[int]] = None
    ) -> "ShardedArena":
        """Split into contiguous slices, one per model-axis rank.

        The split is at *posting-list* granularity — the finest sharding
        that keeps every work unit's posting list whole on one rank, the
        invariant the sharded executor's bit-exact parity rests on — and
        prefers cuts on whole partition boundaries (the HQI case: each rank
        owns contiguous partition slices, so rows, codes, posting lists, and
        bitmap slices move together) unless partition skew would leave the
        mesh imbalanced, in which case the cut falls on posting-list
        boundaries inside a partition (e.g. the standalone-IVF case, one
        partition spread over every rank).

        ``bounds`` (optional, ``n_shards + 1`` monotone GLOBAL list ids with
        ``bounds[0] == 0`` and ``bounds[-1] == n_lists``) pins the split —
        tests use it to force skewed and empty shards. The default cuts at
        the boundary NEAREST each balanced-row target. Shards are index
        ranges, not copies: the base arena stays the single storage and
        ``gid`` stays global, so no result ever needs per-rank id
        translation.
        """
        n_shards = int(n_shards)
        assert n_shards >= 1, n_shards
        G = self.n_lists
        row_starts = np.append(self.list_start, self.n)  # i64 [G + 1]
        if bounds is None:
            targets = np.arange(1, n_shards) * (self.n / n_shards)
            # candidate splits at both granularities; keep the better-balanced
            # one (partition slices win ties — whole-slice shards are the
            # deployment-friendly layout)
            by_part = self.list_base[
                _nearest_cuts(self.part_row[: self.n_parts + 1], targets)
            ]
            by_list = _nearest_cuts(row_starts, targets)
            candidates = []
            for cuts in (by_part, by_list):
                b = np.concatenate([[0], np.clip(cuts, 0, G), [G]]).astype(np.int64)
                b = np.maximum.accumulate(b)
                candidates.append((int(np.diff(row_starts[b]).max()), b))
            list_bounds = min(candidates, key=lambda c: c[0])[1]
        else:
            list_bounds = np.asarray(bounds, dtype=np.int64)
            assert list_bounds.shape == (n_shards + 1,), list_bounds
            assert list_bounds[0] == 0 and list_bounds[-1] == G, list_bounds
            assert (np.diff(list_bounds) >= 0).all(), list_bounds
        return ShardedArena(
            base=self,
            list_bounds=list_bounds,
            row_bounds=row_starts[list_bounds],
        )

    # ------------------------------------------------------------ constructors

    @staticmethod
    def from_partitions(
        parts: Sequence[Tuple[np.ndarray, IVFIndex]],
        pq: Optional[PQCodebook] = None,
    ) -> "PackedArena":
        """parts: (rows, ivf) pairs; ``rows`` maps ivf-local idx -> caller id."""
        if not parts:
            raise ValueError("arena needs at least one partition")
        metric = parts[0][1].metric
        if len(parts) == 1:
            rows, ivf = parts[0]
            return PackedArena(
                packed=ivf.packed,
                gid=np.asarray(rows, dtype=np.int64)[ivf.order],
                local_of=ivf.order,
                list_start=ivf.offsets[:-1].astype(np.int64),
                list_len=np.diff(ivf.offsets).astype(np.int64),
                list_base=np.array([0, ivf.n_lists], dtype=np.int64),
                part_row=np.array([0, ivf.n], dtype=np.int64),
                centroids=[ivf.centroids],
                metric=metric,
                pq=pq,
                codes=None if pq is None else encode_pq(pq, ivf.packed),
            )
        packed, gid, local_of, starts, lens, cents = [], [], [], [], [], []
        list_base = np.zeros(len(parts) + 1, dtype=np.int64)
        part_row = np.zeros(len(parts) + 1, dtype=np.int64)
        for p, (rows, ivf) in enumerate(parts):
            assert ivf.metric == metric, "mixed-metric partitions"
            packed.append(ivf.packed)
            gid.append(np.asarray(rows, dtype=np.int64)[ivf.order])
            local_of.append(ivf.order)
            starts.append(ivf.offsets[:-1].astype(np.int64) + part_row[p])
            lens.append(np.diff(ivf.offsets).astype(np.int64))
            cents.append(ivf.centroids)
            list_base[p + 1] = list_base[p] + ivf.n_lists
            part_row[p + 1] = part_row[p] + ivf.n
        packed_all = np.concatenate(packed, axis=0)
        return PackedArena(
            packed=packed_all,
            gid=np.concatenate(gid),
            local_of=np.concatenate(local_of),
            list_start=np.concatenate(starts),
            list_len=np.concatenate(lens),
            list_base=list_base,
            part_row=part_row,
            centroids=cents,
            metric=metric,
            pq=pq,
            codes=None if pq is None else encode_pq(pq, packed_all),
        )

    @staticmethod
    def updated(
        old: "PackedArena",
        parts: Sequence[Tuple[np.ndarray, IVFIndex]],
        changed: Sequence[int],
    ) -> "PackedArena":
        """Incremental rebuild after the serving layer extends some partitions.

        ``parts`` is the full current partition list; only partitions in
        ``changed`` are re-derived from their (rows, ivf) pair — every other
        partition's packed block, id map, posting-list table, and PQ code
        block are reused from ``old`` as views (no per-partition recompute or
        re-encode), and only the final concatenation is paid. Partition count
        and order must match.
        """
        assert len(parts) == old.n_parts, "partition count changed; rebuild instead"
        changed_set = set(int(c) for c in changed)
        packed, gid, local_of, starts, lens, cents = [], [], [], [], [], []
        codes: List[np.ndarray] = []
        list_base = np.zeros(len(parts) + 1, dtype=np.int64)
        part_row = np.zeros(len(parts) + 1, dtype=np.int64)
        for p, (rows, ivf) in enumerate(parts):
            assert ivf.metric == old.metric, "mixed-metric partitions"
            if p in changed_set:
                packed.append(ivf.packed)
                gid.append(np.asarray(rows, dtype=np.int64)[ivf.order])
                local_of.append(ivf.order)
                starts.append(ivf.offsets[:-1].astype(np.int64) + part_row[p])
                lens.append(np.diff(ivf.offsets).astype(np.int64))
                if old.pq is not None:
                    codes.append(encode_pq(old.pq, ivf.packed))
                n_p, nl_p = ivf.n, ivf.n_lists
            else:
                r0, r1 = int(old.part_row[p]), int(old.part_row[p + 1])
                l0, l1 = int(old.list_base[p]), int(old.list_base[p + 1])
                packed.append(old.packed[r0:r1])
                gid.append(old.gid[r0:r1])
                local_of.append(old.local_of[r0:r1])
                starts.append(old.list_start[l0:l1] - r0 + part_row[p])
                lens.append(old.list_len[l0:l1])
                if old.pq is not None:
                    codes.append(old.codes[r0:r1])
                n_p, nl_p = r1 - r0, l1 - l0
            cents.append(ivf.centroids)
            list_base[p + 1] = list_base[p] + nl_p
            part_row[p + 1] = part_row[p] + n_p
        return PackedArena(
            packed=np.concatenate(packed, axis=0),
            gid=np.concatenate(gid),
            local_of=np.concatenate(local_of),
            list_start=np.concatenate(starts),
            list_len=np.concatenate(lens),
            list_base=list_base,
            part_row=part_row,
            centroids=cents,
            metric=old.metric,
            pq=old.pq,
            codes=np.concatenate(codes, axis=0) if old.pq is not None else None,
        )

    @staticmethod
    def sharded_from_ivf(ivf: IVFIndex, n_shards: int) -> "ShardedArena":
        """Sharded single-index arena, memoized per shard count.

        The shard is just index bounds over the (memoized) ``from_ivf``
        arena, but still worth caching: repeated sharded ``batch_search_ivf``
        calls over one IVF reuse the split instead of re-deriving boundaries
        per call. Codebook changes need no invalidation — the bounds are
        pq-independent and ``attach_pq``'s code swap is visible through the
        shared ``base`` reference.
        """
        arena = PackedArena.from_ivf(ivf)
        cache = getattr(ivf, "_sharded_cache", None)
        if cache is None:
            cache = ivf._sharded_cache = {}
        key = int(n_shards)
        if key not in cache:
            cache[key] = arena.shard(n_shards)
        return cache[key]

    @staticmethod
    def from_ivf(ivf: IVFIndex) -> "PackedArena":
        """Single-index arena; ``gid`` is the ivf-local vector index.

        Memoized on the (immutable) index instance — repeated
        ``batch_search_ivf`` calls over one IVF pay the O(n) id mapping once.
        """
        arena = getattr(ivf, "_arena_cache", None)
        if arena is None:
            arena = PackedArena.from_partitions([(np.arange(ivf.n, dtype=np.int64), ivf)])
            ivf._arena_cache = arena
        return arena


@dataclasses.dataclass
class ShardedArena:
    """The arena split into per-rank contiguous posting-list ranges.

    Built by ``PackedArena.shard``. Rank r owns global posting lists
    ``[list_bounds[r], list_bounds[r+1])`` and therefore global packed rows
    ``[row_bounds[r], row_bounds[r+1])`` — the sharded planner routes each
    work unit to ``owner_of_list(unit.glist)`` and the compressed path's
    re-rank uses ``owner_of_row`` to hand every rank exactly the candidate
    rows it stores. An empty range is a rank with no data (all rows on other
    ranks), which executes as fully-masked padding.
    """

    base: PackedArena
    list_bounds: np.ndarray  # i64 [R + 1] — global posting-list split
    row_bounds: np.ndarray  # i64 [R + 1] — global packed-row split

    @property
    def n_shards(self) -> int:
        return len(self.list_bounds) - 1

    @property
    def rows_per_shard(self) -> np.ndarray:
        return np.diff(self.row_bounds)

    def owner_of_list(self, glists: np.ndarray) -> np.ndarray:
        """Owning rank per global list id (duplicate bounds = empty shards)."""
        return np.searchsorted(self.list_bounds, glists, side="right") - 1

    def owner_of_row(self, rows: np.ndarray) -> np.ndarray:
        """Owning rank per global packed row."""
        return np.searchsorted(self.row_bounds, rows, side="right") - 1
