"""Hybrid-query baselines — Strategies A–D of Section 2.2.

  * Exhaustive (A): bitmap + full scan; produces the ground truth.
  * PreFilter (B): one IVF over V + bitmap pushdown; per-query scans with
    attribute-constraint batching (bitmaps amortized per template) — the
    paper's strongest baseline and its FAISS-equivalent configuration.
  * Range (C): range partitioning on one numeric attribute + per-partition
    IVF; inapplicable when constraints have no predicate on that attribute
    beyond pruning (falls back to all partitions), and NA for workloads with
    IN / IS NOT NULL constraints only (as in RelatedQS/LP — Table 3 footnote).
  * PostFilter (D): IVF search first (expanded k'), attribute filter after.

All baselines share the same IVF implementation as HQI so the comparison
isolates the paper's two contributions (layout + batching), not kernel
quality.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .arena import PackedArena
from .ivf import IVFIndex, ScanStats
from .plan import EngineTask, PlanConfig, build_plan
from .planner import batch_search_ivf, execute_plan
from .predicates import Between, Cmp, evaluate_filter
from .types import SearchResult, VectorDatabase, Workload


# ---------------------------------------------------------------------------
# Strategy A — exhaustive (ground truth)
# ---------------------------------------------------------------------------


def exhaustive_search(db: VectorDatabase, workload: Workload, *, chunk: int = 4096) -> SearchResult:
    """Exact hybrid search: bitmap per template + full masked scan (jit'd)."""
    m, k = workload.m, workload.k
    out_s = np.full((m, k), -np.inf, np.float32)
    out_i = np.full((m, k), -1, np.int64)
    scanned = 0
    v = jnp.asarray(db.vectors)
    for ti, filt in enumerate(workload.templates):
        qidx = workload.queries_for_template(ti)
        if len(qidx) == 0:
            continue
        bitmap = evaluate_filter(filt, db)
        scanned += db.n * len(qidx)
        valid = jnp.asarray(bitmap)
        for s in range(0, len(qidx), chunk):
            qs = qidx[s : s + chunk]
            sc, ix = kops.masked_topk(
                jnp.asarray(workload.vectors[qs]), v, valid, k, metric=db.metric, use_pallas=False
            )
            out_s[qs] = np.asarray(sc)
            out_i[qs] = np.asarray(ix).astype(np.int64)
    return SearchResult(ids=out_i, scores=out_s, tuples_scanned=scanned)


# ---------------------------------------------------------------------------
# Strategy B — PreFilter (attribute filter → IVF with bitmap pushdown)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PreFilterIndex:
    db: VectorDatabase
    ivf: IVFIndex
    build_seconds: float = 0.0

    @staticmethod
    def build(db: VectorDatabase, *, n_centroids: Optional[int] = None, kmeans_iters: int = 8, seed: int = 0) -> "PreFilterIndex":
        t0 = time.perf_counter()
        ivf = IVFIndex.build(
            db.vectors, metric=db.metric, n_centroids=n_centroids, kmeans_iters=kmeans_iters, seed=seed
        )
        return PreFilterIndex(db=db, ivf=ivf, build_seconds=time.perf_counter() - t0)

    def search(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
        batch_attr: bool = True,
        batch_vec: bool = False,
        plan: Optional[PlanConfig] = None,
    ) -> SearchResult:
        """batch_attr: amortize bitmaps per template (on for all baselines,

        as in the paper). batch_vec: Alg.-3 style vector batching through the
        plan/execute engine (planner.py) — off for the PreFilter baseline, on
        gives the "batching on a vanilla IVF" ablation of Sections 6.3/6.5.
        """
        plan = PlanConfig() if plan is None else plan
        m, k = workload.m, workload.k
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i = np.full((m, k), -1, np.int64)
        stats = ScanStats()
        bitmap_cache: Dict[int, np.ndarray] = {}
        if batch_attr:
            order = [(ti, workload.queries_for_template(ti)) for ti in range(len(workload.templates))]
        else:
            order = [(int(workload.template_of[qi]), np.array([qi])) for qi in range(m)]
        arena = PackedArena.from_ivf(self.ivf) if batch_vec else None
        tasks = []
        for ti, qidx in order:
            if len(qidx) == 0:
                continue
            if batch_attr and ti in bitmap_cache:
                bitmap = bitmap_cache[ti]
            else:
                bitmap = evaluate_filter(workload.templates[ti], self.db)
                if batch_attr:
                    bitmap_cache[ti] = bitmap
            np_t = nprobe[ti] if isinstance(nprobe, dict) else nprobe
            if batch_vec:
                # all-false bitmaps still become tasks: build_plan accounts the
                # scanned (bitmap-killed) lists exactly like search_single does
                packed = None if bitmap.all() else arena.packed_bitmap(0, bitmap)
                tasks.append(
                    EngineTask(
                        part=0,
                        qrows=qidx.astype(np.int64),
                        nprobe=int(min(np_t, self.ivf.n_lists)),
                        packed_bitmap=packed,
                    )
                )
            else:
                for qi in qidx:
                    s, ix = self.ivf.search_single(
                        workload.vectors[qi], nprobe=np_t, k=k, bitmap=bitmap, stats=stats
                    )
                    out_s[qi], out_i[qi] = s, ix
        if batch_vec:
            # one global plan across ALL templates — a single megabatched
            # dispatch per bucket shape instead of one loop pass per template
            eplan = build_plan(arena, tasks, workload.vectors, m=m, k=k, cfg=plan, stats=stats)
            out_s, out_i = execute_plan(eplan, arena, workload.vectors, cfg=plan)
        return SearchResult(ids=out_i, scores=out_s, tuples_scanned=stats.tuples_scanned)


# ---------------------------------------------------------------------------
# Strategy D — PostFilter (ANN first, filter after)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PostFilterIndex:
    db: VectorDatabase
    ivf: IVFIndex
    build_seconds: float = 0.0

    @staticmethod
    def build(db: VectorDatabase, *, n_centroids: Optional[int] = None, kmeans_iters: int = 8, seed: int = 0) -> "PostFilterIndex":
        t0 = time.perf_counter()
        ivf = IVFIndex.build(
            db.vectors, metric=db.metric, n_centroids=n_centroids, kmeans_iters=kmeans_iters, seed=seed
        )
        return PostFilterIndex(db=db, ivf=ivf, build_seconds=time.perf_counter() - t0)

    def search(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
        expansion: int = 10,  # k' = expansion * k candidates before filtering
    ) -> SearchResult:
        m, k = workload.m, workload.k
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i = np.full((m, k), -1, np.int64)
        stats = ScanStats()
        kprime = min(expansion * k, self.db.n)
        for ti, filt in enumerate(workload.templates):
            qidx = workload.queries_for_template(ti)
            if len(qidx) == 0:
                continue
            bitmap = evaluate_filter(filt, self.db)
            np_t = nprobe[ti] if isinstance(nprobe, dict) else nprobe
            for qi in qidx:
                s, ix = self.ivf.search_single(
                    workload.vectors[qi], nprobe=np_t, k=kprime, bitmap=None, stats=stats
                )
                ok = (ix >= 0) & bitmap[np.maximum(ix, 0)]
                s, ix = s[ok][:k], ix[ok][:k]
                out_s[qi, : len(s)] = s
                out_i[qi, : len(ix)] = ix
        return SearchResult(ids=out_i, scores=out_s, tuples_scanned=stats.tuples_scanned)


# ---------------------------------------------------------------------------
# Strategy C — Range partitioning on one attribute
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RangeIndex:
    db: VectorDatabase
    attr: str
    bounds: np.ndarray  # [nb + 1] bucket edges over the partitioning attribute
    partitions: List[Tuple[np.ndarray, IVFIndex]]  # (rows, ivf)
    build_seconds: float = 0.0

    @staticmethod
    def build(
        db: VectorDatabase,
        attr: str,
        *,
        n_buckets: int = 16,
        kmeans_iters: int = 8,
        seed: int = 0,
    ) -> "RangeIndex":
        t0 = time.perf_counter()
        col = db.columns[attr]
        vals = col.values.astype(np.float64)
        qs = np.linspace(0, 1, n_buckets + 1)
        bounds = np.quantile(vals[~col.null_mask], qs)  # equi-depth
        bounds[0], bounds[-1] = -np.inf, np.inf
        which = np.clip(np.searchsorted(bounds, vals, side="right") - 1, 0, n_buckets - 1)
        parts = []
        for b in range(n_buckets):
            rows = np.nonzero(which == b)[0]
            if len(rows) == 0:
                continue
            ivf = IVFIndex.build(
                db.vectors[rows],
                metric=db.metric,
                n_centroids=max(1, int(math.isqrt(len(rows)))),
                kmeans_iters=kmeans_iters,
                seed=seed,
            )
            parts.append((rows, ivf))
        return RangeIndex(db=db, attr=attr, bounds=bounds, partitions=parts, build_seconds=time.perf_counter() - t0)

    @staticmethod
    def applicable(workload: Workload) -> bool:
        """Range requires numeric range/comparison predicates (Table 3: NA for

        RelatedQS/LP whose constraints are IN / IS NOT NULL over many attrs)."""
        for t in workload.templates:
            for p in t:
                if not isinstance(p, (Between, Cmp)):
                    return False
        return True

    def _bucket_range(self, filt) -> Tuple[float, float]:
        lo, hi = -np.inf, np.inf
        for p in filt:
            if isinstance(p, Between) and p.attr == self.attr:
                lo, hi = max(lo, p.lo), min(hi, p.hi)
            elif isinstance(p, Cmp) and p.attr == self.attr:
                if p.op in (">", ">="):
                    lo = max(lo, p.value)
                elif p.op in ("<", "<="):
                    hi = min(hi, p.value)
                elif p.op == "==":
                    lo, hi = max(lo, p.value), min(hi, p.value)
        return lo, hi

    def search(
        self,
        workload: Workload,
        *,
        nprobe: Union[int, Dict[int, int]] = 8,
    ) -> SearchResult:
        m, k = workload.m, workload.k
        out_s = np.full((m, k), -np.inf, np.float32)
        out_i = np.full((m, k), -1, np.int64)
        stats = ScanStats()
        for ti, filt in enumerate(workload.templates):
            qidx = workload.queries_for_template(ti)
            if len(qidx) == 0:
                continue
            bitmap = evaluate_filter(filt, self.db)
            lo, hi = self._bucket_range(filt)
            np_t = nprobe[ti] if isinstance(nprobe, dict) else nprobe
            for rows, ivf in self.partitions:
                vals = self.db.columns[self.attr].values[rows]
                # prune bucket iff its value range is disjoint from [lo, hi)
                bmin, bmax = float(vals.min()), float(vals.max())
                if bmax < lo or bmin >= hi:
                    continue
                local_bitmap = bitmap[rows]
                if not local_bitmap.any():
                    continue
                for qi in qidx:
                    s, loc = ivf.search_single(
                        workload.vectors[qi], nprobe=np_t, k=k, bitmap=local_bitmap, stats=stats
                    )
                    gid = np.where(loc >= 0, rows[np.maximum(loc, 0)], -1)
                    cat_s = np.concatenate([out_s[qi], s])
                    cat_i = np.concatenate([out_i[qi], gid])
                    top = np.argsort(-cat_s, kind="stable")[:k]
                    out_s[qi], out_i[qi] = cat_s[top], cat_i[top]
        return SearchResult(ids=out_i, scores=out_s, tuples_scanned=stats.tuples_scanned)
