"""Workload-aware balanced qd-tree (Section 4.1, Algorithms 1 and 2).

The tree partitions the vector database using *cut predicates* mined from a
historical hybrid-query workload: the attribute predicates of the templates
plus — when m > 0 — per-centroid ``CentroidIn`` predicates derived from the
k-means transformation of Section 4.1.1.

Balanced splits (Algorithm 1): a node accumulates a *set* S of cut predicates
until the union of their matches covers at least half the node's tuples;
left child = tuples satisfying ⋁S, right child = tuples satisfying none.

Semantic descriptions: each leaf carries
  * ``all_false``   — cut predicates no tuple in the leaf satisfies
                      (from right-branch ancestors; one entry per s ∈ S), and
  * ``all_true_or`` — predicate sets S where every tuple satisfies ⋁S
                      (from left-branch ancestors).
Routing (Section 4.1.3) prunes a leaf for a conjunctive filter f iff
  * some conjunct p ∈ f implies an all_false predicate, or
  * some conjunct p ∈ f is pairwise-disjoint with every s of an all_true_or
    set (then p ∧ ⋁S is unsatisfiable).
Both tests are conservative ⇒ routing is *sound* (never loses a result); the
property tests in tests/test_qdtree.py verify this on random workloads.

Cost model: ``cost_mode="tuples"`` implements Eq. (1) directly
(Σ |P_i| · #templates routed, weighted by query counts); ``"queries"`` is the
unweighted count as literally printed in Algorithm 2. Default is "tuples"
since Eq. (1) is the paper's stated objective.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from .predicates import (
    CentroidIn,
    Predicate,
    predicate_from_state,
    predicate_to_state,
)
from .types import VectorDatabase, Workload


@dataclasses.dataclass
class Leaf:
    leaf_id: int
    rows: np.ndarray  # int64 indices into the original DB
    all_false: List[int]  # cut-pred indices no tuple satisfies
    all_true_or: List[Tuple[int, ...]]  # sets S with "every tuple satisfies ⋁S"
    depth: int


@dataclasses.dataclass
class QDTree:
    preds: List[Predicate]  # the extracted cut predicates
    leaves: List[Leaf]
    imp: np.ndarray  # bool [C, C]: imp[i, j] = preds[i] ⇒ preds[j]
    disj: np.ndarray  # bool [C, C]: preds[i] ∧ preds[j] unsatisfiable
    n_centroids: int = 0  # coarse centroids (m > 0 mode); 0 = attributes only

    @property
    def n_leaves(self) -> int:
        return len(self.leaves)

    # -- persistence -------------------------------------------------------

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): the full tree structure —
        cut predicates, implication/disjointness tables, and every leaf's
        row set + semantic description — so routing after a load is
        bit-identical to the tree that was saved (no re-mining)."""
        return {
            "n_centroids": int(self.n_centroids),
            "preds": [predicate_to_state(p) for p in self.preds],
            "imp": self.imp,
            "disj": self.disj,
            "leaves": [
                {
                    "leaf_id": int(leaf.leaf_id),
                    "rows": leaf.rows,
                    "all_false": [int(c) for c in leaf.all_false],
                    "all_true_or": [[int(s) for s in S] for S in leaf.all_true_or],
                    "depth": int(leaf.depth),
                }
                for leaf in self.leaves
            ],
        }

    @staticmethod
    def from_state(state: dict) -> "QDTree":
        return QDTree(
            preds=[predicate_from_state(s) for s in state["preds"]],
            leaves=[
                Leaf(
                    leaf_id=int(ls["leaf_id"]),
                    rows=np.asarray(ls["rows"]),
                    all_false=[int(c) for c in ls["all_false"]],
                    all_true_or=[tuple(int(s) for s in S) for S in ls["all_true_or"]],
                    depth=int(ls["depth"]),
                )
                for ls in state["leaves"]
            ],
            imp=np.asarray(state["imp"]),
            disj=np.asarray(state["disj"]),
            n_centroids=int(state["n_centroids"]),
        )

    # -- routing -----------------------------------------------------------

    def _match_pred(self, p: Predicate) -> Tuple[np.ndarray, np.ndarray]:
        """(implies_vec, disjoint_vec) of p against the cut-pred set."""
        C = len(self.preds)
        imp = np.zeros(C, dtype=bool)
        dis = np.zeros(C, dtype=bool)
        try:
            i = self.preds.index(p)
            return self.imp[i], self.disj[i]
        except ValueError:
            pass
        for j, c in enumerate(self.preds):
            if p.implies(c):
                imp[j] = True
            if predicates_disjoint(p, c):
                dis[j] = True
        return imp, dis

    def route_filter(self, filt: Tuple[Predicate, ...]) -> np.ndarray:
        """bool [n_leaves]: which leaves may contain matches for the filter."""
        out = np.ones(self.n_leaves, dtype=bool)
        if not filt:
            return out
        per_conj = [self._match_pred(p) for p in filt]
        for li, leaf in enumerate(self.leaves):
            pruned = False
            for imp, dis in per_conj:
                if any(imp[c] for c in leaf.all_false):
                    pruned = True
                    break
                if any(all(dis[s] for s in S) for S in leaf.all_true_or):
                    pruned = True
                    break
            out[li] = not pruned
        return out

    def centroid_allowed(self) -> Optional[np.ndarray]:
        """bool [n_leaves, n_centroids]: leaf may contain tuples of centroid c.

        None when the tree was built attribute-only (m = 0).
        """
        if self.n_centroids == 0:
            return None
        allowed = np.ones((self.n_leaves, self.n_centroids), dtype=bool)
        cent_sets = [
            (i, p.centroids) for i, p in enumerate(self.preds) if isinstance(p, CentroidIn)
        ]
        pred_to_set = dict(cent_sets)
        for li, leaf in enumerate(self.leaves):
            for c in leaf.all_false:
                if c in pred_to_set:
                    allowed[li, list(pred_to_set[c])] = False
            for S in leaf.all_true_or:
                if all(s in pred_to_set for s in S):
                    union: Set[int] = set()
                    for s in S:
                        union |= pred_to_set[s]
                    mask = np.zeros(self.n_centroids, dtype=bool)
                    mask[list(union)] = True
                    allowed[li] &= mask
        return allowed

    def route_tuples(
        self, db: VectorDatabase, centroid_of: Optional[np.ndarray] = None
    ) -> np.ndarray:
        """int64 [db.n]: the unique leaf each tuple belongs to.

        Each leaf's semantic description is exactly its root-to-leaf path:
        every left turn contributes an all_true_or set S (the tuple satisfies
        ⋁S) and every right turn contributes |S| all_false predicates (the
        tuple satisfies none of them). Since each split partitions its node
        on ⋁S, the descriptions partition tuple space — this is how the
        serving layer's ``refresh()`` folds freshly inserted tuples into the
        existing partitioning without re-running Algorithm 1.
        """
        n = db.n
        if not self.preds or len(self.leaves) == 1:
            return np.zeros(n, dtype=np.int64)
        pm = np.stack([p.evaluate(db, centroid_of) for p in self.preds])  # [C, n]
        out = np.full(n, -1, dtype=np.int64)
        for li, leaf in enumerate(self.leaves):
            mask = out < 0
            for c in leaf.all_false:
                mask &= ~pm[c]
            for S in leaf.all_true_or:
                acc = np.zeros(n, dtype=bool)
                for s in S:
                    acc |= pm[s]
                mask &= acc
            out[mask] = li
        assert (out >= 0).all(), "leaf descriptions must cover tuple space"
        return out


def predicates_disjoint(p: Predicate, q: Predicate) -> bool:
    """Conservative: True only if p ∧ q is provably unsatisfiable."""
    from .predicates import Between, Cmp, Contains, In, NotNull

    if isinstance(p, CentroidIn) and isinstance(q, CentroidIn):
        return not (p.centroids & q.centroids)
    attr_p = getattr(p, "attr", None)
    attr_q = getattr(q, "attr", None)
    if attr_p is None or attr_p != attr_q:
        return False
    if isinstance(p, Between) and isinstance(q, Between):
        return p.hi <= q.lo or q.hi <= p.lo
    if isinstance(p, In) and isinstance(q, In):
        return not (p.values & q.values)
    if isinstance(p, Cmp) and isinstance(q, Cmp) and p.op == "==" and q.op == "==":
        return p.value != q.value
    if isinstance(p, Between) and isinstance(q, Cmp):
        if q.op == "==":
            return not (p.lo <= q.value < p.hi)
        if q.op in ("<", "<="):
            return p.lo > q.value or (q.op == "<" and p.lo >= q.value)
        if q.op in (">", ">="):
            # [lo, hi) lies entirely at or below q.value in both cases: every
            # range member is < hi <= q.value, so none is > (or >=) q.value
            return p.hi <= q.value
    if isinstance(q, Between) and isinstance(p, Cmp):
        return predicates_disjoint(q, p)
    return False


# ---------------------------------------------------------------------------
# Construction (Algorithms 1 + 2)
# ---------------------------------------------------------------------------


def extract_cut_predicates(
    templates: Sequence[Tuple[Predicate, ...]],
    query_centroids: Optional[np.ndarray] = None,
) -> List[Predicate]:
    """All unary predicates in the workload + per-centroid predicates."""
    preds: List[Predicate] = []
    seen = set()
    for t in templates:
        for p in t:
            if p not in seen:
                seen.add(p)
                preds.append(p)
    if query_centroids is not None:
        for c in np.unique(query_centroids):
            p = CentroidIn(frozenset([int(c)]))
            if p not in seen:
                seen.add(p)
                preds.append(p)
    return preds


def build_qdtree(
    db: VectorDatabase,
    workload: Workload,
    *,
    centroid_of: Optional[np.ndarray] = None,  # t.c per tuple (m > 0 mode)
    query_centroids: Optional[np.ndarray] = None,  # q.c [m, m_cent]
    n_centroids: int = 0,
    min_size: int = 4096,
    max_leaves: int = 4096,
    max_preds_per_split: int = 8,
    cost_mode: str = "tuples",
    template_weights: Optional[np.ndarray] = None,
) -> QDTree:
    preds = extract_cut_predicates(workload.templates, query_centroids)
    C = len(preds)
    n = db.n
    if C == 0:
        # No usable cut predicates: single leaf.
        return QDTree(preds=[], leaves=[Leaf(0, np.arange(n), [], [], 0)], imp=np.zeros((0, 0), bool), disj=np.zeros((0, 0), bool), n_centroids=n_centroids)

    # Evaluate every cut predicate once over V: bool [C, n].
    pred_matrix = np.stack([p.evaluate(db, centroid_of) for p in preds])

    # Pairwise implication / disjointness between cut predicates.
    imp = np.zeros((C, C), dtype=bool)
    disj = np.zeros((C, C), dtype=bool)
    for i in range(C):
        for j in range(C):
            if i != j and preds[i].implies(preds[j]):
                imp[i, j] = True
            if i < j and predicates_disjoint(preds[i], preds[j]):
                disj[i, j] = disj[j, i] = True
        imp[i, i] = True

    # Template → conjunct cut-pred indices; weights = query counts.
    pred_index = {p: i for i, p in enumerate(preds)}
    T = len(workload.templates)
    conj_tid: List[int] = []
    conj_pid: List[int] = []
    for ti, t in enumerate(workload.templates):
        for p in t:
            conj_tid.append(ti)
            conj_pid.append(pred_index[p])
    conj_tid_a = np.array(conj_tid, dtype=np.int64)
    conj_pid_a = np.array(conj_pid, dtype=np.int64)
    if template_weights is None:
        template_weights = np.bincount(workload.template_of, minlength=T).astype(np.float64)
    # M_imp[t, c]: template t has a conjunct implying cut pred c
    M_imp = np.zeros((T, C), dtype=bool)
    if len(conj_tid_a):
        np.logical_or.at(M_imp, conj_tid_a, imp[conj_pid_a])

    leaves: List[Leaf] = []

    def routed_weight(tmask: np.ndarray) -> float:
        return float(template_weights[tmask].sum())

    def recurse(
        rows: np.ndarray,
        tmpl_alive: np.ndarray,  # bool [T] — templates routed to this node
        all_false: List[int],
        all_true_or: List[Tuple[int, ...]],
        depth: int,
    ) -> None:
        nP = len(rows)
        if nP <= min_size or len(leaves) + 1 >= max_leaves or not tmpl_alive.any():
            leaves.append(Leaf(len(leaves), rows, list(all_false), list(all_true_or), depth))
            return

        sub = pred_matrix[:, rows]  # [C, nP]
        counts = sub.sum(axis=1)
        # usable candidates: split the node non-trivially, not already decided
        decided = np.zeros(C, dtype=bool)
        decided[list(all_false)] = True
        usable = (counts > 0) & (counts < nP) & ~decided

        S: List[int] = []
        left_mask = np.zeros(nP, dtype=bool)
        # conjunct "alive for disjointness" state: ∀s∈S disj[conj, s]
        conj_alive = np.ones(len(conj_pid_a), dtype=bool)
        pre_right = np.zeros(T, dtype=bool)  # templates pruned from right by S so far
        pre_left = np.zeros(T, dtype=bool)

        while left_mask.sum() <= nP // 2 and len(S) < max_preds_per_split:
            cand = np.nonzero(usable)[0]
            if len(cand) == 0:
                break
            # --- Algorithm 2 (vectorized over candidates) ---
            # right-prune: template has a conjunct implying any s ∈ S∪{p}
            pr_right = pre_right[:, None] | M_imp[:, cand]  # [T, |cand|]
            # left-prune: some conjunct disjoint with every s ∈ S∪{p}
            pr_left = np.zeros((T, len(cand)), dtype=bool)
            if len(conj_pid_a):
                dmat = disj[conj_pid_a][:, cand]  # [J, |cand|]
                alive_d = conj_alive[:, None] & dmat
                np.logical_or.at(pr_left, conj_tid_a, alive_d)
            w = template_weights * tmpl_alive
            wq_left = ((~pr_left) * w[:, None]).sum(axis=0)
            wq_right = ((~pr_right) * w[:, None]).sum(axis=0)
            new_left = left_mask[None, :] | sub[cand]  # [|cand|, nP]
            nL = new_left.sum(axis=1).astype(np.float64)
            nR = nP - nL
            if cost_mode == "tuples":
                cost = nL * wq_left + nR * wq_right  # Eq. (1)
            else:
                cost = wq_left + wq_right  # Algorithm 2 as printed
            # tie-break toward balance
            cost = cost + 1e-9 * np.abs(nL - nP / 2.0)
            best = int(cand[np.argmin(cost)])
            gain_rows = int((sub[best] & ~left_mask).sum())
            if gain_rows == 0 and len(S) > 0:
                usable[best] = False
                continue
            S.append(best)
            left_mask |= sub[best]
            usable[best] = False
            pre_right |= M_imp[:, best]
            # pre_left[t] = ∃ conjunct of t disjoint with every s ∈ S
            pre_left = np.zeros(T, dtype=bool)
            if len(conj_pid_a):
                conj_alive &= disj[conj_pid_a, best]
                np.logical_or.at(pre_left, conj_tid_a, conj_alive)

        nL = int(left_mask.sum())
        if not S or nL == 0 or nL == nP:
            leaves.append(Leaf(len(leaves), rows, list(all_false), list(all_true_or), depth))
            return

        t_left = tmpl_alive & ~pre_left
        t_right = tmpl_alive & ~pre_right
        recurse(rows[left_mask], t_left, all_false, all_true_or + [tuple(S)], depth + 1)
        recurse(rows[~left_mask], t_right, all_false + list(S), all_true_or, depth + 1)

    recurse(np.arange(n, dtype=np.int64), np.ones(T, dtype=bool), [], [], 0)
    return QDTree(preds=preds, leaves=leaves, imp=imp, disj=disj, n_centroids=n_centroids)
