"""Clustering-based IVF index with contiguous posting lists and bitmap pushdown.

The index stores vectors re-ordered so that every posting list is a dense,
contiguous slice (TPU adaptation: scans become dense tiles instead of pointer
chases). ``search_single`` is the *online* path used by the PreFilter /
PostFilter / Range baselines (per-query scan, numpy/BLAS — a faithful stand-in
for FAISS's per-query IVF scan incl. its IDSelector bitmap pushdown).
Batched execution (Algorithm 3) lives in planner.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import kmeans as km
from .types import METRIC_IP, METRIC_L2


@dataclasses.dataclass
class ScanStats:
    tuples_scanned: int = 0  # posting-list entries touched
    dists_computed: int = 0  # distance computations after bitmap skip
    # bytes the engine's scan stages gathered from arena storage (f32 vector
    # tiles, or uint8 code tiles + re-rank rows in scan_mode="pq") — the HBM
    # traffic the compressed path exists to cut; engine path only
    bytes_scanned: int = 0
    # largest candidate merge buffer (scores + ids) any single execution
    # allocated — m·n_slots·k-shaped under merge_layout="dense", Σ segments·k
    # under "segmented"; the quantity the skewed-routing bench compares
    peak_candidate_bytes: int = 0
    # ADC LUT bytes materialized on device: the resident [U, M, 256] table
    # once per pq execution, plus (dense layout only) every per-bucket
    # [W, TQ, M, 256] expansion — segmented keeps this at the resident size
    lut_bytes: int = 0

    def __iadd__(self, o: "ScanStats"):
        self.tuples_scanned += o.tuples_scanned
        self.dists_computed += o.dists_computed
        self.bytes_scanned += o.bytes_scanned
        self.peak_candidate_bytes = max(self.peak_candidate_bytes, o.peak_candidate_bytes)
        self.lut_bytes += o.lut_bytes
        return self


@dataclasses.dataclass
class IVFIndex:
    centroids: np.ndarray  # [nc, d]
    packed: np.ndarray  # [n, d] vectors re-ordered by posting list
    order: np.ndarray  # [n] packed row -> local vector index
    offsets: np.ndarray  # [nc + 1] list boundaries in packed order
    metric: str
    # memoized single-index PackedArena (set by arena.PackedArena.from_ivf;
    # typed loosely to avoid a circular import)
    _arena_cache: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False, init=False
    )

    @property
    def n(self) -> int:
        return int(self.packed.shape[0])

    @property
    def n_lists(self) -> int:
        return int(self.centroids.shape[0])

    def list_len(self, l: int) -> int:
        return int(self.offsets[l + 1] - self.offsets[l])

    @staticmethod
    def build(
        vectors: np.ndarray,
        *,
        metric: str = METRIC_IP,
        n_centroids: Optional[int] = None,
        kmeans_iters: int = 8,
        seed: int = 0,
    ) -> "IVFIndex":
        n = vectors.shape[0]
        if n_centroids is None:
            # FAISS-style sqrt(n), rounded to a power of two so the jit'd
            # k-means update specializes on O(log n) distinct shapes across
            # the many per-partition indexes
            k0 = max(1, int(math.isqrt(n)))
            n_centroids = 1 << (k0 - 1).bit_length()
        n_centroids = min(n_centroids, n)
        cents = km.train_kmeans(vectors, n_centroids, iters=kmeans_iters, metric=metric, seed=seed)
        assign = km.assign_kmeans(vectors, cents, metric=metric)
        order = np.argsort(assign, kind="stable").astype(np.int64)
        sorted_assign = assign[order]
        offsets = np.zeros(len(cents) + 1, dtype=np.int64)
        counts = np.bincount(sorted_assign, minlength=len(cents))
        offsets[1:] = np.cumsum(counts)
        return IVFIndex(
            centroids=cents,
            packed=np.ascontiguousarray(vectors[order]),
            order=order,
            offsets=offsets,
            metric=metric,
        )

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): arrays stay np.ndarray leaves."""
        return {
            "metric": self.metric,
            "centroids": self.centroids,
            "packed": self.packed,
            "order": self.order,
            "offsets": self.offsets,
        }

    @staticmethod
    def from_state(state: dict) -> "IVFIndex":
        return IVFIndex(
            centroids=np.asarray(state["centroids"]),
            packed=np.asarray(state["packed"]),
            order=np.asarray(state["order"]),
            offsets=np.asarray(state["offsets"]),
            metric=state["metric"],
        )

    def extend(self, vectors: np.ndarray) -> "IVFIndex":
        """New index with ``vectors`` appended to the existing posting lists.

        The incremental-insert path of the serving layer's ``refresh()``: the
        quantizer (centroids) is kept, each new vector is assigned to its
        nearest existing list, and the packed layout is re-sorted so lists
        stay contiguous. New vectors get local indices ``n .. n+len-1`` (the
        caller appends their ids to its row table in the same order).
        O(n + new) repacking, no k-means.
        """
        vectors = np.ascontiguousarray(vectors, dtype=np.float32)
        if vectors.shape[0] == 0:
            return self
        assign_new = km.assign_kmeans(vectors, self.centroids, metric=self.metric)
        list_of_packed = np.repeat(
            np.arange(self.n_lists, dtype=np.int64), np.diff(self.offsets)
        )
        all_list = np.concatenate([list_of_packed, assign_new.astype(np.int64)])
        all_local = np.concatenate(
            [self.order, self.n + np.arange(vectors.shape[0], dtype=np.int64)]
        )
        all_vecs = np.concatenate([self.packed, vectors], axis=0)
        sort = np.argsort(all_list, kind="stable")
        counts = np.bincount(all_list, minlength=self.n_lists)
        offsets = np.zeros(self.n_lists + 1, dtype=np.int64)
        offsets[1:] = np.cumsum(counts)
        return IVFIndex(
            centroids=self.centroids,
            packed=np.ascontiguousarray(all_vecs[sort]),
            order=all_local[sort],
            offsets=offsets,
            metric=self.metric,
        )

    # -- coarse quantizer ----------------------------------------------------

    def probe(self, q_vecs: np.ndarray, nprobe: int) -> np.ndarray:
        """nprobe nearest posting lists per query: int32 [m, nprobe]."""
        nprobe = int(min(nprobe, self.n_lists))
        return km.topm_centroids(q_vecs, self.centroids, nprobe, metric=self.metric)

    # -- online (per-query) scan ----------------------------------------------

    def search_single(
        self,
        q: np.ndarray,  # [d]
        *,
        nprobe: int,
        k: int,
        bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
        stats: Optional[ScanStats] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k (scores desc, local idx). The FAISS-like per-query path."""
        lists = self.probe(q[None, :], nprobe)[0]
        cand_scores = []
        cand_idx = []
        for l in lists:
            s, e = int(self.offsets[l]), int(self.offsets[l + 1])
            if e == s:
                continue
            members = self.order[s:e]
            if stats is not None:
                stats.tuples_scanned += e - s
            if bitmap is not None:
                sel = bitmap[members]
                if not sel.any():
                    continue
                members = members[sel]
                block = self.packed[s:e][sel]
            else:
                block = self.packed[s:e]
            if stats is not None:
                stats.dists_computed += block.shape[0]
            ip = block @ q
            if self.metric == METRIC_L2:
                sc = 2.0 * ip - (block * block).sum(axis=1) - float(q @ q)
            else:
                sc = ip
            cand_scores.append(sc)
            cand_idx.append(members)
        if not cand_scores:
            return np.full(k, -np.inf, np.float32), np.full(k, -1, np.int64)
        sc = np.concatenate(cand_scores)
        ix = np.concatenate(cand_idx)
        kk = min(k, len(sc))
        top = np.argpartition(-sc, kk - 1)[:kk]
        top = top[np.argsort(-sc[top], kind="stable")]
        out_s = np.full(k, -np.inf, np.float32)
        out_i = np.full(k, -1, np.int64)
        out_s[:kk] = sc[top]
        out_i[:kk] = ix[top]
        return out_s, out_i

    def search_group(
        self,
        q_vecs: np.ndarray,  # [mq, d]
        *,
        nprobe: int,
        k: int,
        bitmap: Optional[np.ndarray] = None,  # bool [n] in LOCAL vector order
        stats: Optional[ScanStats] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Multi-query host-side scan — ``search_single`` for a query group.

        Identical candidates and scores, but each probed posting list is
        gathered and bitmap-filtered ONCE for every group member probing it,
        and their distances come from one shared GEMM (``block @ Qᵀ``)
        instead of one matvec per (query, list). This is what makes the
        serving layer's micro-batches pay even on the adaptive executor's
        host path: queries of one template probing overlapping lists share
        the scan. Returns (scores f32 [mq, k] desc, local idx i64 [mq, k]).
        """
        mq = q_vecs.shape[0]
        out_s = np.full((mq, k), -np.inf, np.float32)
        out_i = np.full((mq, k), -1, np.int64)
        if mq == 0:
            return out_s, out_i
        probes = self.probe(q_vecs, nprobe)  # [mq, np_eff]
        np_eff = probes.shape[1]
        flat_l = probes.reshape(-1).astype(np.int64)
        flat_q = np.repeat(np.arange(mq, dtype=np.int64), np_eff)
        order = np.argsort(flat_l, kind="stable")
        flat_l, flat_q = flat_l[order], flat_q[order]
        uniq, starts = np.unique(flat_l, return_index=True)
        ends = np.append(starts[1:], len(flat_l))
        cand_s: list = [[] for _ in range(mq)]
        cand_i: list = [[] for _ in range(mq)]
        qn = (q_vecs * q_vecs).sum(axis=1) if self.metric == METRIC_L2 else None
        for l, g0, g1 in zip(uniq, starts, ends):
            s, e = int(self.offsets[l]), int(self.offsets[l + 1])
            if e == s:
                continue
            qs = flat_q[g0:g1]
            members = self.order[s:e]
            if stats is not None:
                stats.tuples_scanned += (e - s) * len(qs)
            if bitmap is not None:
                sel = bitmap[members]
                if not sel.any():
                    continue
                members = members[sel]
                block = self.packed[s:e][sel]
            else:
                block = self.packed[s:e]
            if stats is not None:
                stats.dists_computed += block.shape[0] * len(qs)
            ip = block @ q_vecs[qs].T  # [n_block, |qs|] — one GEMM per list
            if self.metric == METRIC_L2:
                sc = 2.0 * ip - (block * block).sum(axis=1)[:, None] - qn[qs][None, :]
            else:
                sc = ip
            for col, qi in enumerate(qs):
                cand_s[qi].append(sc[:, col])
                cand_i[qi].append(members)
        for qi in range(mq):
            if not cand_s[qi]:
                continue
            sc = np.concatenate(cand_s[qi])
            ix = np.concatenate(cand_i[qi])
            kk = min(k, len(sc))
            top = np.argpartition(-sc, kk - 1)[:kk]
            top = top[np.argsort(-sc[top], kind="stable")]
            out_s[qi, :kk] = sc[top]
            out_i[qi, :kk] = ix[top]
        return out_s, out_i
