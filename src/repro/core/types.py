"""Core data model for HQI: vector database, attributes, queries, workloads.

The vector database V is a set of tuples t = (id, e, a) — Definition 1 in the
paper. Attributes are columnar and typed; NULLs are first-class (the paper's
workloads lean heavily on IS NOT NULL checks). Everything host-side is numpy;
device-side compute (distance kernels, k-means) lives in jax under
``repro.kernels`` / ``repro.core.kmeans``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Columns
# ---------------------------------------------------------------------------

NUMERIC = "numeric"
CATEGORICAL = "categorical"
SETCAT = "setcat"  # set-valued categorical, e.g. entity "type" with many tags


@dataclasses.dataclass
class Column:
    """One attribute column.

    kind == NUMERIC:     values float32[n]; null_mask bool[n]
    kind == CATEGORICAL: values int32[n] (code), null_mask bool[n]
    kind == SETCAT:      values bool[n, cardinality] membership matrix;
                         null_mask bool[n] (empty set == NULL)
    """

    name: str
    kind: str
    values: np.ndarray
    null_mask: np.ndarray

    def __post_init__(self):
        if self.kind not in (NUMERIC, CATEGORICAL, SETCAT):
            raise ValueError(f"unknown column kind {self.kind!r}")
        n = self.values.shape[0]
        assert self.null_mask.shape == (n,), "null_mask must be [n]"

    @property
    def n(self) -> int:
        return int(self.values.shape[0])

    def take(self, idx: np.ndarray) -> "Column":
        return Column(self.name, self.kind, self.values[idx], self.null_mask[idx])

    @staticmethod
    def numeric(name: str, values: np.ndarray, null_mask: Optional[np.ndarray] = None) -> "Column":
        values = np.asarray(values, dtype=np.float32)
        if null_mask is None:
            null_mask = np.zeros(values.shape[0], dtype=bool)
        return Column(name, NUMERIC, values, np.asarray(null_mask, dtype=bool))

    @staticmethod
    def categorical(name: str, codes: np.ndarray, null_mask: Optional[np.ndarray] = None) -> "Column":
        codes = np.asarray(codes, dtype=np.int32)
        if null_mask is None:
            null_mask = codes < 0
        return Column(name, CATEGORICAL, codes, np.asarray(null_mask, dtype=bool))

    @staticmethod
    def setcat(name: str, membership: np.ndarray) -> "Column":
        membership = np.asarray(membership, dtype=bool)
        null_mask = ~membership.any(axis=1)
        return Column(name, SETCAT, membership, null_mask)

    @staticmethod
    def concat(a: "Column", b: "Column") -> "Column":
        """Row-wise concatenation (schema must match) — the live-insert path."""
        assert a.kind == b.kind and a.name == b.name, (a.name, b.name)
        if a.kind == SETCAT:
            assert a.values.shape[1] == b.values.shape[1], "setcat cardinality"
        values = np.concatenate([a.values, b.values], axis=0)
        return Column(a.name, a.kind, values, np.concatenate([a.null_mask, b.null_mask]))

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): arrays stay np.ndarray leaves."""
        return {
            "name": self.name,
            "kind": self.kind,
            "values": self.values,
            "null_mask": self.null_mask,
        }

    @staticmethod
    def from_state(state: dict) -> "Column":
        return Column(
            name=state["name"],
            kind=state["kind"],
            values=np.asarray(state["values"]),
            null_mask=np.asarray(state["null_mask"]),
        )

    @staticmethod
    def all_null(like: "Column", n: int) -> "Column":
        """n rows of NULL with ``like``'s schema (inserts omitting a column)."""
        if like.kind == SETCAT:
            values = np.zeros((n, like.values.shape[1]), dtype=bool)
        elif like.kind == CATEGORICAL:
            values = np.full(n, -1, dtype=np.int32)
        else:
            values = np.zeros(n, dtype=np.float32)
        return Column(like.name, like.kind, values, np.ones(n, dtype=bool))


# ---------------------------------------------------------------------------
# Vector database
# ---------------------------------------------------------------------------

METRIC_L2 = "l2"
METRIC_IP = "ip"


@dataclasses.dataclass
class VectorDatabase:
    """V: n tuples of (id, e: float32[d], a: columns)."""

    vectors: np.ndarray  # float32 [n, d]
    columns: Dict[str, Column]
    metric: str = METRIC_IP
    ids: Optional[np.ndarray] = None  # int64 [n]; defaults to arange

    def __post_init__(self):
        self.vectors = np.ascontiguousarray(self.vectors, dtype=np.float32)
        if self.ids is None:
            self.ids = np.arange(self.n, dtype=np.int64)
        for c in self.columns.values():
            assert c.n == self.n, f"column {c.name} has {c.n} rows, expected {self.n}"
        if self.metric not in (METRIC_L2, METRIC_IP):
            raise ValueError(f"unknown metric {self.metric!r}")

    @property
    def n(self) -> int:
        return int(self.vectors.shape[0])

    @property
    def d(self) -> int:
        return int(self.vectors.shape[1])

    def take(self, idx: np.ndarray) -> "VectorDatabase":
        return VectorDatabase(
            vectors=self.vectors[idx],
            columns={k: c.take(idx) for k, c in self.columns.items()},
            metric=self.metric,
            ids=self.ids[idx],
        )

    def to_state(self) -> dict:
        """Snapshot state (store/snapshot.py): arrays stay np.ndarray leaves."""
        return {
            "metric": self.metric,
            "vectors": self.vectors,
            "ids": self.ids,
            "columns": {name: c.to_state() for name, c in self.columns.items()},
        }

    @staticmethod
    def from_state(state: dict) -> "VectorDatabase":
        return VectorDatabase(
            vectors=np.asarray(state["vectors"]),
            columns={
                name: Column.from_state(cs) for name, cs in state["columns"].items()
            },
            metric=state["metric"],
            ids=np.asarray(state["ids"]),
        )

    @staticmethod
    def concat(a: "VectorDatabase", b: "VectorDatabase") -> "VectorDatabase":
        """Row-wise concatenation of two same-schema databases (live inserts)."""
        assert a.metric == b.metric, "mixed-metric concat"
        assert set(a.columns) == set(b.columns), "schema mismatch"
        assert a.d == b.d, "dimension mismatch"
        return VectorDatabase(
            vectors=np.concatenate([a.vectors, b.vectors], axis=0),
            columns={k: Column.concat(c, b.columns[k]) for k, c in a.columns.items()},
            metric=a.metric,
            ids=np.concatenate([a.ids, b.ids]),
        )


# ---------------------------------------------------------------------------
# Queries / workload
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HybridQuery:
    """q = (e, f): Definition 2. ``filter`` is a canonical tuple of predicates

    (see predicates.py); the empty tuple means pure vector search.
    """

    vector: np.ndarray  # float32 [d]
    filter: tuple  # tuple of Predicate (hashable, canonical order)


@dataclasses.dataclass
class Workload:
    """A batch HVQ workload: query vectors [m, d] + per-query filter template.

    Filters are interned: ``templates`` is the list of distinct filters and
    ``template_of`` maps each query to its template index. This mirrors the
    paper's observation that a few templates cover most queries (filter
    commonality) and is what Algorithm 3 groups by.
    """

    vectors: np.ndarray  # float32 [m, d]
    templates: List[tuple]  # distinct filters
    template_of: np.ndarray  # int32 [m]
    k: int = 10

    @property
    def m(self) -> int:
        return int(self.vectors.shape[0])

    @staticmethod
    def from_queries(queries: Sequence[HybridQuery], k: int = 10) -> "Workload":
        interned: Dict[tuple, int] = {}
        template_of = np.empty(len(queries), dtype=np.int32)
        vecs = np.stack([q.vector for q in queries]).astype(np.float32)
        for i, q in enumerate(queries):
            if q.filter not in interned:
                interned[q.filter] = len(interned)
            template_of[i] = interned[q.filter]
        templates = [None] * len(interned)
        for f, ti in interned.items():
            templates[ti] = f
        return Workload(vectors=vecs, templates=templates, template_of=template_of, k=k)

    def queries_for_template(self, ti: int) -> np.ndarray:
        return np.nonzero(self.template_of == ti)[0]

    def subset(self, qidx: np.ndarray) -> "Workload":
        used = sorted(set(int(t) for t in self.template_of[qidx]))
        remap = {t: i for i, t in enumerate(used)}
        return Workload(
            vectors=self.vectors[qidx],
            templates=[self.templates[t] for t in used],
            template_of=np.array([remap[int(t)] for t in self.template_of[qidx]], dtype=np.int32),
            k=self.k,
        )


@dataclasses.dataclass
class SearchResult:
    """Top-k results: ids int64 [m, k] (-1 padding), dists float32 [m, k].

    ``dists`` are *scores* ordered best-first: for IP higher-is-better stored
    as the raw inner product; for L2 we store negative squared distance so
    that best-first ordering is uniformly descending.
    """

    ids: np.ndarray
    scores: np.ndarray
    tuples_scanned: int = 0  # distance computations performed (paper metric 2)
    bytes_scanned: int = 0  # arena bytes gathered by the engine's scan stages
    # largest candidate merge buffer one execution allocated (scores + ids):
    # the memory figure the segmented layout exists to shrink
    peak_candidate_bytes: int = 0
    # ADC LUT bytes materialized on device (pq scans only): resident tables,
    # plus per-bucket expansions under merge_layout="dense"
    lut_bytes: int = 0
    # per-rank accounting when the search ran on a device mesh
    # (core.planner.ShardStats; annotated loosely so types stays import-light)
    shard_stats: Optional[object] = None
    # partition id -> number of queries the router sent there (engine tasks
    # plus adaptive per-query scans) — the drift monitor's probe-heat feed
    part_probes: Optional[Dict[int, int]] = None

    @property
    def k(self) -> int:
        return int(self.ids.shape[1])
