"""Predicates for hybrid queries (Definition 2) and qd-tree cuts.

A hybrid query's attribute constraint is a conjunction f = p1 ∧ … ∧ pk where
each p is one of:

  * ``Cmp(attr, op, x)``       — unary comparison, op ∈ {<, <=, >, >=, ==}
  * ``In(attr, {x1..xj})``     — categorical set membership
  * ``Contains(attr, x)``      — set-valued attribute contains value
                                  (the paper's `'Person' IN V.a['type']`)
  * ``NotNull(attr)``          — existence check
  * ``CentroidIn({c0..cm})``   — derived predicate over the k-means centroid
                                  assignment t.c (Section 4.1.1)

All predicates are frozen/hashable so filters can be interned into templates
and used as qd-tree cut predicates. ``evaluate`` produces the bitmap used for
pushdown (Section 4.2); ``implies`` provides the conservative subsumption test
used for semantic-description routing (Section 4.1.3).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, Tuple

import numpy as np

from .types import CATEGORICAL, NUMERIC, SETCAT, Column, VectorDatabase

_OPS = ("<", "<=", ">", ">=", "==")


@dataclasses.dataclass(frozen=True, order=True)
class Predicate:
    def evaluate(self, db: VectorDatabase, centroid_of: np.ndarray | None = None) -> np.ndarray:
        raise NotImplementedError

    def implies(self, other: "Predicate") -> bool:
        """True if self ⇒ other (every tuple satisfying self satisfies other).

        Conservative: False negatives are allowed, False positives are not.
        """
        return self == other


@dataclasses.dataclass(frozen=True, order=True)
class Cmp(Predicate):
    attr: str
    op: str
    value: float

    def __post_init__(self):
        assert self.op in _OPS, self.op

    def evaluate(self, db, centroid_of=None):
        col = db.columns[self.attr]
        assert col.kind == NUMERIC, f"Cmp on non-numeric column {self.attr}"
        v = col.values
        if self.op == "<":
            out = v < self.value
        elif self.op == "<=":
            out = v <= self.value
        elif self.op == ">":
            out = v > self.value
        elif self.op == ">=":
            out = v >= self.value
        else:
            out = v == self.value
        return out & ~col.null_mask

    def implies(self, other):
        if self == other:
            return True
        if isinstance(other, NotNull) and other.attr == self.attr:
            return True  # a comparison only passes on non-NULL values
        if not isinstance(other, Cmp) or other.attr != self.attr:
            return False
        s, o = self, other
        if o.op == "<":
            return (s.op in ("<", "<=", "==")) and (
                s.value < o.value or (s.op == "<" and s.value == o.value)
            )
        if o.op == "<=":
            return (s.op in ("<", "<=", "==")) and s.value <= o.value
        if o.op == ">":
            return (s.op in (">", ">=", "==")) and (
                s.value > o.value or (s.op == ">" and s.value == o.value)
            )
        if o.op == ">=":
            return (s.op in (">", ">=", "==")) and s.value >= o.value
        if o.op == "==":
            return s.op == "==" and s.value == o.value
        return False


@dataclasses.dataclass(frozen=True, order=True)
class Between(Predicate):
    """lo <= attr < hi — the range predicate used by the synthetic BIGANN-style

    workloads (selectivity 2^-i grids) and by Range partitioning (Strategy C).
    """

    attr: str
    lo: float
    hi: float

    def evaluate(self, db, centroid_of=None):
        col = db.columns[self.attr]
        assert col.kind == NUMERIC
        return (col.values >= self.lo) & (col.values < self.hi) & ~col.null_mask

    def implies(self, other):
        if self == other:
            return True
        if isinstance(other, NotNull) and other.attr == self.attr:
            return True
        if isinstance(other, Between) and other.attr == self.attr:
            return other.lo <= self.lo and self.hi <= other.hi
        if isinstance(other, Cmp) and other.attr == self.attr:
            if other.op in (">=",):
                return self.lo >= other.value
            if other.op in (">",):
                return self.lo > other.value
            if other.op in ("<",):
                return self.hi <= other.value
            if other.op in ("<=",):
                return self.hi <= other.value
        return False


@dataclasses.dataclass(frozen=True, order=True)
class In(Predicate):
    attr: str
    values: FrozenSet[int]

    def evaluate(self, db, centroid_of=None):
        col = db.columns[self.attr]
        assert col.kind == CATEGORICAL, f"In on non-categorical column {self.attr}"
        out = np.isin(col.values, np.fromiter(self.values, dtype=np.int32))
        return out & ~col.null_mask

    def implies(self, other):
        if self == other:
            return True
        if isinstance(other, NotNull) and other.attr == self.attr:
            return True
        if isinstance(other, In) and other.attr == self.attr:
            return self.values <= other.values
        return False


@dataclasses.dataclass(frozen=True, order=True)
class Contains(Predicate):
    attr: str
    value: int  # code of the contained element

    def evaluate(self, db, centroid_of=None):
        col = db.columns[self.attr]
        assert col.kind == SETCAT, f"Contains on non-setcat column {self.attr}"
        return col.values[:, self.value] & ~col.null_mask

    def implies(self, other):
        if self == other:
            return True
        if isinstance(other, NotNull) and other.attr == self.attr:
            return True
        return False


@dataclasses.dataclass(frozen=True, order=True)
class NotNull(Predicate):
    attr: str

    def evaluate(self, db, centroid_of=None):
        return ~db.columns[self.attr].null_mask


@dataclasses.dataclass(frozen=True, order=True)
class CentroidIn(Predicate):
    """t.c ∈ centroids — the vector-similarity constraint transformed into a

    categorical predicate over the k-means assignment (Section 4.1.1).
    Evaluation needs ``centroid_of`` (int32 [n]) which the index provides.
    """

    centroids: FrozenSet[int]

    def evaluate(self, db, centroid_of=None):
        assert centroid_of is not None, "CentroidIn needs centroid assignments"
        return np.isin(centroid_of, np.fromiter(self.centroids, dtype=np.int32))

    def implies(self, other):
        if isinstance(other, CentroidIn):
            return self.centroids <= other.centroids
        return False


# ---------------------------------------------------------------------------
# Conjunctive filters
# ---------------------------------------------------------------------------


def make_filter(*preds: Predicate) -> Tuple[Predicate, ...]:
    """Canonical (sorted, deduped) conjunction usable as a dict key."""
    return tuple(sorted(set(preds), key=repr))


def evaluate_filter(
    filter: Tuple[Predicate, ...],
    db: VectorDatabase,
    centroid_of: np.ndarray | None = None,
) -> np.ndarray:
    """Bitmap of tuples satisfying the conjunction (all-True for empty)."""
    out = np.ones(db.n, dtype=bool)
    for p in filter:
        out &= p.evaluate(db, centroid_of)
    return out


# ---------------------------------------------------------------------------
# Persistence (store/snapshot.py): predicates as JSON-safe state dicts
# ---------------------------------------------------------------------------

def predicate_to_state(p: Predicate) -> dict:
    """JSON-serializable description of one predicate (snapshot manifests)."""
    if isinstance(p, Cmp):
        return {"kind": "cmp", "attr": p.attr, "op": p.op, "value": float(p.value)}
    if isinstance(p, Between):
        return {"kind": "between", "attr": p.attr, "lo": float(p.lo), "hi": float(p.hi)}
    if isinstance(p, In):
        return {"kind": "in", "attr": p.attr, "values": sorted(int(v) for v in p.values)}
    if isinstance(p, Contains):
        return {"kind": "contains", "attr": p.attr, "value": int(p.value)}
    if isinstance(p, NotNull):
        return {"kind": "notnull", "attr": p.attr}
    if isinstance(p, CentroidIn):
        return {"kind": "centroid_in", "centroids": sorted(int(c) for c in p.centroids)}
    raise TypeError(f"unserializable predicate type {type(p).__name__}")


def predicate_from_state(state: dict) -> Predicate:
    kind = state["kind"]
    if kind == "cmp":
        return Cmp(state["attr"], state["op"], float(state["value"]))
    if kind == "between":
        return Between(state["attr"], float(state["lo"]), float(state["hi"]))
    if kind == "in":
        return In(state["attr"], frozenset(int(v) for v in state["values"]))
    if kind == "contains":
        return Contains(state["attr"], int(state["value"]))
    if kind == "notnull":
        return NotNull(state["attr"])
    if kind == "centroid_in":
        return CentroidIn(frozenset(int(c) for c in state["centroids"]))
    raise ValueError(f"unknown predicate kind {kind!r}")


def filter_to_state(filt: Tuple[Predicate, ...]) -> list:
    """A conjunctive filter as a JSON-safe list (order preserved)."""
    return [predicate_to_state(p) for p in filt]


def filter_from_state(state: list) -> Tuple[Predicate, ...]:
    return tuple(predicate_from_state(s) for s in state)


def filter_implies_empty(
    filter: Tuple[Predicate, ...],
    known_all_false: Tuple[Predicate, ...] | set,
) -> bool:
    """Routing test: the partition is provably empty for this filter iff some

    conjunct implies a predicate known to be all-false in the partition.
    (If p ⇒ q and no tuple satisfies q, no tuple satisfies p, hence none can
    satisfy the whole conjunction.)
    """
    for p in filter:
        for q in known_all_false:
            if p.implies(q):
                return True
    return False
