"""Batched k-means (Lloyd's) in JAX — used for IVF training and for the

centroid-assignment attribute of Section 4.1.1.

Matches FAISS's IVF training defaults in spirit: k = sqrt(n) by default,
a bounded number of Lloyd's iterations over a training sample, empty-cluster
re-seeding. Assignment (the hot part) is a tiled matmul; it reuses the same
masked-distance primitive as search (kernels/ops.py) so the Pallas path is
exercised by k-means too.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops


@functools.partial(jax.jit, static_argnames=("metric",))
def _assign(vectors: jax.Array, centroids: jax.Array, metric: str) -> jax.Array:
    """Nearest-centroid assignment. vectors [n,d], centroids [k,d] -> int32[n]."""
    scores = kops.pairwise_scores(vectors, centroids, metric=metric)  # [n, k] best=max
    return jnp.argmax(scores, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k",))
def _update(vectors: jax.Array, assign: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Mean of each cluster; returns (centroids [k,d], counts [k])."""
    one_hot = jax.nn.one_hot(assign, k, dtype=vectors.dtype)  # [n, k]
    counts = one_hot.sum(axis=0)  # [k]
    sums = one_hot.T @ vectors  # [k, d]
    return sums / jnp.maximum(counts, 1.0)[:, None], counts


def _pow2_pad(x: np.ndarray, lo: int = 256) -> np.ndarray:
    """Pad rows to the next power of two (repeating rows) so the jit'd

    k-means steps specialize on O(log n) shapes instead of one per
    partition — index build time is dominated by compiles otherwise."""
    n = x.shape[0]
    target = max(lo, 1 << (n - 1).bit_length())
    if target == n:
        return x
    reps = np.resize(np.arange(n), target - n)
    return np.concatenate([x, x[reps]], axis=0)


def train_kmeans(
    vectors: np.ndarray,
    k: int,
    *,
    iters: int = 10,
    metric: str = "l2",
    seed: int = 0,
    sample_cap: int = 262_144,
) -> np.ndarray:
    """Train k centroids; returns float32 [k, d]."""
    n, d = vectors.shape
    k = int(min(k, n))
    rng = np.random.default_rng(seed)
    if n > sample_cap:
        idx = rng.choice(n, size=sample_cap, replace=False)
        x = vectors[idx]
    else:
        x = vectors
    # padding with duplicate rows does not change cluster means materially
    # and keeps the jit cache small across many differently-sized partitions
    x = _pow2_pad(np.asarray(x, dtype=np.float32))
    x = jnp.asarray(x, dtype=jnp.float32)
    # k-means++-lite init: random distinct points.
    init_idx = rng.choice(x.shape[0], size=k, replace=False)
    centroids = x[jnp.asarray(init_idx)]
    for _ in range(iters):
        assign = _assign(x, centroids, metric)
        centroids, counts = _update(x, assign, k)
        # Re-seed empty clusters from random points (host-side; rare).
        empty = np.asarray(counts == 0)
        if empty.any():
            c = np.array(centroids)  # writable copy
            c[empty] = np.asarray(x)[rng.choice(x.shape[0], size=int(empty.sum()), replace=False)]
            centroids = jnp.asarray(c)
    return np.asarray(centroids, dtype=np.float32)


def assign_kmeans(vectors: np.ndarray, centroids: np.ndarray, *, metric: str = "l2", chunk: int = 65_536) -> np.ndarray:
    """Nearest-centroid id per vector (chunked to bound device memory;

    the tail chunk is pow2-padded so jit sees O(log n) shapes)."""
    n = vectors.shape[0]
    out = np.empty(n, dtype=np.int32)
    cents = jnp.asarray(centroids)
    for s in range(0, n, chunk):
        e = min(s + chunk, n)
        block = _pow2_pad(np.asarray(vectors[s:e], dtype=np.float32), lo=256)
        out[s:e] = np.asarray(_assign(jnp.asarray(block), cents, metric))[: e - s]
    return out


def topm_centroids(query_vectors: np.ndarray, centroids: np.ndarray, m: int, *, metric: str = "l2") -> np.ndarray:
    """m nearest centroids per query — int32 [nq, m] (Section 4.1.1 / Alg.3 line 6)."""
    scores = kops.pairwise_scores(jnp.asarray(query_vectors), jnp.asarray(centroids), metric=metric)
    m = int(min(m, centroids.shape[0]))
    _, idx = jax.lax.top_k(scores, m)
    return np.asarray(idx, dtype=np.int32)
